"""SIMD example: distributed inference of models too large for one host
(survey §4) — DLRM sharded-embedding inference (Fig. 7) executed for real
on a local mesh, plus the capacity/latency scale-out sweep at production
size from the cost model.

    PYTHONPATH=src python examples/distributed_inference.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.dlrm import CONFIG as DLRM
from repro.core.simd import batch_specs, dlrm_forward, init_dlrm, shard_specs
from repro.core.hardware import TPU_V5E


def main():
    # --- real sharded execution (scaled-down tables, local mesh) ----------
    cfg = dataclasses.replace(DLRM, num_tables=8, rows_per_table=4096,
                              embed_dim=32, bottom_mlp=(64, 32),
                              top_mlp=(64, 1))
    params = init_dlrm(cfg, jax.random.key(0))
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    with mesh:
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shard_specs(cfg),
                          is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, sh)
        rng = np.random.default_rng(0)
        batch = {
            "dense": jnp.asarray(rng.standard_normal((64, 13)), jnp.float32),
            "sparse": jnp.asarray(
                rng.integers(0, cfg.rows_per_table,
                             (64, cfg.num_tables, cfg.multi_hot)), jnp.int32),
        }
        bs = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs(cfg),
                          is_leaf=lambda x: isinstance(x, P))
        batch = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
        fwd = jax.jit(lambda p, b: dlrm_forward(cfg, p, b))
        out = fwd(params, batch)
        print(f"sharded DLRM inference: batch=64 -> logits {out.shape}, "
              f"mean={float(out.mean()):.4f}")

    # --- production-size capacity sweep (cost model) -----------------------
    table_gb = DLRM.embedding_params() * 4 / 2 ** 30
    print(f"\nproduction DLRM: {table_gb:.0f} GB of embeddings "
          f"({DLRM.num_tables} tables x {DLRM.rows_per_table:,} rows)")
    print(f"one v5e host holds {TPU_V5E.hbm_bytes/2**30:.0f} GB HBM -> "
          "capacity-driven scale-out (survey Fig. 7):")
    from benchmarks.fig7_dlrm import scale_out_estimate

    for n in (1, 4, 16, 64):
        r = scale_out_estimate(n)
        print(f"  nodes={n:3d}: {'fits' if r['fits'] else 'OOM '} "
              f"latency={r['latency_s']*1e6:9.1f}us "
              f"comm_share={r['comm_share']:.2f}")


if __name__ == "__main__":
    main()
