"""Quickstart: every quadrant of the survey's taxonomy in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Deployment, Paradigm, estimate, executor_for
from repro.configs import get_shape
from repro.models import init_params
from repro.serving import EngineConfig, Request, ServingEngine


def main():
    # --- pick an assigned architecture, reduced for the CPU container -----
    cfg = get_config("granite-8b").reduced()
    print(f"model: {cfg.name} ({cfg.arch_type}), "
          f"{cfg.param_count()/1e6:.1f}M params (reduced)")

    # --- SISD: single-instance serving with continuous batching -----------
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64))
    reqs = [Request(i, np.arange(8 + i, dtype=np.int32), max_new_tokens=6)
            for i in range(3)]
    queue, t = list(reqs), 0.0
    while queue or eng.n_active:
        while queue and eng.try_admit(queue[0], t):
            queue.pop(0)
        eng.step(t)
        t += 1.0
    print(f"SISD: served {eng.metrics.completed} requests, "
          f"tokens={eng.metrics.total_tokens}")

    # --- the taxonomy at production scale (full config, cost model) -------
    full = get_config("granite-8b")
    for dep in (Deployment(full.name, 1, 1), Deployment(full.name, 4, 1),
                Deployment(full.name, 1, 256), Deployment(full.name, 8, 256)):
        p = dep.paradigm
        print(f"{p.name}: I={dep.n_instances} D={dep.n_devices} -> "
              f"{executor_for(p)}")

    # --- roofline for one assigned shape ----------------------------------
    est = estimate(full, get_shape("decode_32k"), n_chips=256)
    print(f"decode_32k on 256 chips: compute={est.compute_s*1e3:.2f}ms "
          f"memory={est.memory_s*1e3:.2f}ms -> bottleneck={est.bottleneck}")


if __name__ == "__main__":
    main()
