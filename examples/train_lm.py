"""End-to-end training driver example: train a ~100M-param member of an
assigned family for a few hundred steps on synthetic structured data and
watch the loss drop, with checkpoint/restore.

    PYTHONPATH=src python examples/train_lm.py --steps 300

(This drives the same repro.launch.train entry the cluster launcher uses;
~100M params keeps a CPU run tractable. On real hardware drop --reduced
and add the production mesh.)
"""
import argparse
import dataclasses
import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.training import TokenPipeline, init_adamw, train_step
from repro.training.checkpoint import latest_step, restore_into, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param member of the granite (llama-arch) family
    cfg = dataclasses.replace(
        get_config("granite-8b"),
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, dtype="float32")
    print(f"training {cfg.param_count()/1e6:.1f}M-param {cfg.arch_type} model "
          f"for {args.steps} steps")

    params = init_params(cfg, jax.random.key(0))
    opt = init_adamw(params)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
    step_fn = jax.jit(functools.partial(
        train_step, cfg, peak_lr=6e-4, total_steps=args.steps))

    t0 = time.time()
    losses = []
    for step, batch in enumerate(pipe.batches()):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["ce"]))
        if step % 25 == 0:
            tok_s = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:4d}  ce={losses[-1]:.4f}  tok/s={tok_s:,.0f}")
    save_checkpoint(args.ckpt, args.steps, params)
    print(f"ce {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}  "
          f"(checkpoint at {args.ckpt})")
    # restore sanity
    r = restore_into(args.ckpt, latest_step(args.ckpt),
                     jax.eval_shape(lambda: params))
    assert all(np.allclose(a, b) for a, b in
               zip(jax.tree.leaves(r), jax.tree.leaves(params)))
    print("checkpoint restore verified")


if __name__ == "__main__":
    main()
