"""MISD example: multi-tenant serving with spatial meshlets + temporal
scheduling (survey §3) — partition a 256-chip pod for three tenant models,
then co-schedule a mixed query stream with each scheduler and compare.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import copy
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.core.costmodel import estimate_decode, stream_occupancy
from repro.core.misd import (
    SCHEDULERS,
    Device,
    Job,
    MeshPartitioner,
    MISDSimulator,
    adaptive_batch_size,
)


def main():
    tenants = [
        {"name": "chat", "cfg": get_config("chatglm3-6b"), "batch": 16,
         "context": 4096, "sla_s": 0.05},
        {"name": "code", "cfg": get_config("granite-8b"), "batch": 8,
         "context": 8192, "sla_s": 0.08},
        {"name": "vision", "cfg": get_config("qwen2-vl-7b"), "batch": 8,
         "context": 4096, "sla_s": 0.10},
    ]

    # --- spatial: gpulet-style meshlet partitioning ------------------------
    part = MeshPartitioner((16, 16))
    plan = part.plan(tenants)
    print("meshlet plan:")
    for m in plan.meshlets:
        users = [k for k, v in plan.assignment.items() if v == m.name]
        print(f"  {m.name}: {m.shape[0]}x{m.shape[1]} = {m.n_chips} chips "
              f"-> {users}")

    # --- adaptive batching per tenant --------------------------------------
    for t in tenants:
        mesh_name = plan.assignment[t["name"]]
        chips = next(m.n_chips for m in plan.meshlets if m.name == mesh_name)
        b, lat = adaptive_batch_size(t["cfg"], context=t["context"],
                                     sla_s=t["sla_s"], n_chips=chips)
        print(f"  {t['name']}: adaptive batch={b} "
              f"(step {lat*1e3:.1f}ms <= SLA {t['sla_s']*1e3:.0f}ms)")

    # --- temporal: scheduler comparison on one shared meshlet --------------
    rng = np.random.default_rng(0)
    jobs = []
    t_arr = 0.0
    for i in range(200):
        ten = tenants[int(rng.integers(3))]
        est = estimate_decode(ten["cfg"], 8, ten["context"], n_chips=64)
        t_arr += float(rng.exponential(est.latency_s / 2.5))
        jobs.append(Job(i, ten["name"], est.demand_at(stream_occupancy(8)),
                        est.latency_s, arrival=t_arr,
                        priority=5 if ten["name"] == "chat" else 0,
                        sla_s=est.latency_s * 5))
    print("\nscheduler comparison (one 64-chip meshlet, 4 tenants max):")
    for name, cls in SCHEDULERS.items():
        res = MISDSimulator([Device("meshlet", max_tenants=4)],
                            cls()).run(copy.deepcopy(jobs))
        print(f"  {name:20s} qps={res.qps:7.1f} jct={res.mean_jct()*1e3:7.1f}ms"
              f" p99={res.p99_latency()*1e3:7.1f}ms sla={res.sla_attainment():.2f}")


if __name__ == "__main__":
    main()
