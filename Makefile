# One-word entry points for the repo's verify + bench loops.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test lint bench bench-smoke bench-cluster bench-cluster-smoke \
	bench-prefix bench-prefix-smoke bench-sampling bench-sampling-smoke \
	bench-chaos bench-chaos-smoke bench-sharded bench-sharded-smoke \
	bench-observability bench-observability-smoke trace-demo \
	bench-overload bench-overload-smoke bench-quant bench-quant-smoke \
	span-diff span-baseline serve-bench micro

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# pyflakes-critical lint tier (ruff.toml); check-only — CI never autofixes
lint:
	ruff check --no-fix .

# serving perf trajectory: engine vs pre-refactor baseline -> BENCH_serving.json
bench:
	$(PY) benchmarks/serving_bench.py

# CI gate: tiny serving run failing on compile-count regressions
bench-smoke:
	$(PY) benchmarks/serving_bench.py --smoke

# cluster routing-policy A/B (virtual time) -> BENCH_cluster.json
bench-cluster:
	$(PY) benchmarks/cluster_bench.py

# CI gate: tiny 2-replica cluster run failing on routing-invariant,
# stream-identity, page-leak, or compile-count regressions
bench-cluster-smoke:
	$(PY) benchmarks/cluster_bench.py --smoke

# shared-prefix KV cache A/B (warm vs cold TTFT) -> BENCH_prefix.json
bench-prefix:
	$(PY) benchmarks/prefix_bench.py

# CI gate: tiny prefix-cache A/B failing on the >=5x warm-TTFT headline,
# stream identity, page/refcount leaks, or suffix-trace growth
bench-prefix-smoke:
	$(PY) benchmarks/prefix_bench.py --smoke --out BENCH_prefix_smoke.json

# stochastic vs greedy decode A/B (equal batch) -> BENCH_sampling.json
bench-sampling:
	$(PY) benchmarks/sampling_bench.py

# CI gate: seeded sampled workload replayed across slot orders + an
# engine restart; fails on stream divergence or decode-trace growth
bench-sampling-smoke:
	$(PY) benchmarks/sampling_bench.py --smoke

# chaos harness: kill/hang/slow one of four replicas mid-workload plus a
# preemption-churn round -> BENCH_chaos.json
bench-chaos:
	$(PY) benchmarks/chaos_bench.py

# CI gate: tiny chaos run failing on lost requests, non-bit-identical
# failed-over streams, survivor page/refcount leaks, unbounded retries,
# goodput retention < 0.70, or a watchdog mis-verdict (slow declared dead)
bench-chaos-smoke:
	$(PY) benchmarks/chaos_bench.py --smoke

# tensor/expert-parallel replica vs 1-chip on the same workload (the
# script forces 8 XLA host devices itself) -> BENCH_sharded.json
bench-sharded:
	$(PY) benchmarks/sharded_bench.py

# CI gate: fails on sharded-vs-1-chip stream divergence, compile-count
# growth under the mesh, page leaks, or MoE expert-parallel divergence
bench-sharded-smoke:
	$(PY) benchmarks/sharded_bench.py --smoke

# observability layer A/B: histogram-percentile parity, trace lifecycle
# accounting, bit-identity, tracing overhead -> BENCH_observability.json
bench-observability:
	$(PY) benchmarks/observability_bench.py

# CI gate: fails on percentile drift past one bucket, malformed or
# incomplete span traces, stream divergence with tracing on, or tracing
# overhead past the noise-tolerant 0.90 bound (acceptance: 0.97 full)
bench-observability-smoke:
	$(PY) benchmarks/observability_bench.py --smoke

# multi-tenant overload stack under a low-tier flood: SLO-tier goodput
# retention, DRR fairness bounds, ladder engagement, bit-identity of
# admitted streams, typed retry-after -> BENCH_overload.json
bench-overload:
	$(PY) benchmarks/overload_bench.py

# CI gate: fails on protected-tier goodput retention < 0.9 under the
# flood, a starved tenant (DRR wait past its provable bound), a ladder
# that never engaged, stream divergence vs the unloaded reference, or a
# rejection missing its finite retry_after_s
bench-overload-smoke:
	$(PY) benchmarks/overload_bench.py --smoke \
		--out BENCH_overload.json

# quantized-serving A/B: int8 KV pages vs the f32 pool on one workload
# (capacity, decode tok/s, stream divergence, kernel error-vs-bound)
# -> BENCH_quant.json
bench-quant:
	$(PY) benchmarks/quant_bench.py

# CI gate: fails on slots ratio < 1.8x at equal HBM, decode tok/s
# < 0.9x f32, a diverged FIRST token (prefill must stay exact), an
# unbounded stream rewrite, or kernel error past the closed-form bound
bench-quant-smoke:
	$(PY) benchmarks/quant_bench.py --smoke --out BENCH_quant_smoke.json

# span-phase triage gate: per-kind span rollups of a fixed virtual-time
# traced workload diffed against benchmarks/SPAN_BASELINE.json — fails
# NAMING the regressed phase; deliberate changes: make span-baseline
span-diff:
	$(PY) benchmarks/span_diff.py

span-baseline:
	$(PY) benchmarks/span_diff.py --update

# viewable trace artifact: a small chaos run (kill/hang/slow + churn)
# exported as TRACE_chaos.json — open it in https://ui.perfetto.dev
trace-demo:
	$(PY) benchmarks/chaos_bench.py --requests 24 \
		--trace-out TRACE_chaos.json --out ""

# wall-clock microbenchmarks of the jitted steps
micro:
	$(PY) -m benchmarks.run --only micro
