# One-word entry points for the repo's verify + bench loops.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test bench serve-bench micro

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# serving perf trajectory: engine vs pre-refactor baseline -> BENCH_serving.json
bench:
	$(PY) benchmarks/serving_bench.py

# wall-clock microbenchmarks of the jitted steps
micro:
	$(PY) -m benchmarks.run --only micro
