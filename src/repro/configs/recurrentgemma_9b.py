"""RecurrentGemma-9B — RG-LRU recurrent blocks + local attention, 2:1
pattern (two recurrent blocks per local-attention block) [arXiv:2402.19427]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,  # 38 temporal-mixing blocks; pattern tiles (r, r, a)
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,  # MQA in the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    lru_width=4096,
    attention="local",
    local_window=2048,
    rope_variant="standard",
    mlp_variant="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    sliding_window_decode=2048,  # native: local attention window
    citation="arXiv:2402.19427",
)
