"""DLRM — the survey's flagship SIMD workload (§4.3.1, Fig. 7): a deep
learning recommendation model whose embedding tables dominate memory
(80–95% of weights) and must be sharded across devices [26, 31].

This is not one of the 10 assigned transformer architectures; it exists so
the SIMD quadrant's distributed-embedding inference (RPC fan-out in the
survey, all_to_all under pjit here) is exercised by a faithful workload.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    num_tables: int = 26            # Criteo-style sparse features
    rows_per_table: int = 10_000_000  # production tables are 10M–100M rows
    embed_dim: int = 128
    num_dense_features: int = 13
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    multi_hot: int = 8              # lookups per table per query
    dtype: str = "float32"

    def embedding_params(self) -> int:
        return self.num_tables * self.rows_per_table * self.embed_dim

    def mlp_params(self) -> int:
        dims_b = (self.num_dense_features,) + self.bottom_mlp
        n = sum(a * b + b for a, b in zip(dims_b[:-1], dims_b[1:]))
        # pairwise interaction of (tables+1) embed-dim vectors + bottom out
        num_int = (self.num_tables + 1) * self.num_tables // 2
        top_in = num_int + self.embed_dim
        dims_t = (top_in,) + self.top_mlp
        n += sum(a * b + b for a, b in zip(dims_t[:-1], dims_t[1:]))
        return n

    def param_count(self) -> int:
        return self.embedding_params() + self.mlp_params()


CONFIG = DLRMConfig()
