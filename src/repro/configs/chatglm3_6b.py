"""ChatGLM3-6B — 2d (half-dim) RoPE, GQA kv=2 [arXiv:2406.12793]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    arch_type="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_variant="half",  # ChatGLM applies RoPE to half of each head dim
    mlp_variant="swiglu",
    norm="rmsnorm",
    citation="arXiv:2406.12793",
)
