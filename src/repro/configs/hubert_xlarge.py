"""HuBERT X-Large — encoder-only audio transformer (w2v2 arch)
[arXiv:2106.07447]. Conv feature extractor is a stubbed frontend:
input_specs() provides precomputed 1280-d frame embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,  # full MHA
    d_ff=5120,
    vocab_size=504,  # masked-unit prediction codebook
    is_encoder=True,
    causal=False,
    modality="audio",
    rope_variant="none",
    mlp_variant="gelu",
    norm="layernorm",
    sliding_window_decode=0,
    citation="arXiv:2106.07447",
)
