"""Granite-8B Code — llama-architecture dense code model [arXiv:2405.04324]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_variant="standard",
    rope_theta=10_000_000.0,
    mlp_variant="swiglu",
    norm="rmsnorm",
    citation="arXiv:2405.04324",
)
