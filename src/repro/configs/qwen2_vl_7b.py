"""Qwen2-VL-7B — VLM decoder backbone with M-RoPE (3-section multimodal
rotary positions) [arXiv:2409.12191]. The ViT vision encoder + projector is a
stubbed frontend: input_specs() provides precomputed patch embeddings and the
(3, B, S) M-RoPE position ids."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    modality="vision_text",
    rope_variant="mrope",
    mrope_sections=(16, 24, 24),  # temporal / height / width over head_dim/2
    mlp_variant="swiglu",
    norm="rmsnorm",
    citation="arXiv:2409.12191",
)
