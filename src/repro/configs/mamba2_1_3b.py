"""Mamba2-1.3B — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,  # attention-free, no separate MLP (SSD block has its own expand)
    vocab_size=50280,
    ssm_state_dim=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attention="none",
    rope_variant="none",
    norm="rmsnorm",
    tie_embeddings=True,
    sliding_window_decode=0,  # O(1) state; no KV cache at all
    citation="arXiv:2405.21060",
)
