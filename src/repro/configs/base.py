"""Config system for the LDS framework.

Every assigned architecture is an ``ArchConfig`` (one module per arch under
``repro.configs``). Input shapes are ``ShapeConfig``s. Both are hashable,
frozen dataclasses so they can key caches and be embedded in jit closures.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """Unified architecture description covering all assigned families.

    ``arch_type`` selects the block family:
      dense   — standard decoder (GQA attention + MLP)
      moe     — decoder with MoE MLPs (capacity-based top-k dispatch)
      ssm     — Mamba-2 SSD blocks (attention-free)
      hybrid  — RG-LRU recurrent blocks : local-attention blocks (ratio 2:1)
      audio   — encoder-only transformer over precomputed frame embeddings
      vlm     — decoder with M-RoPE over precomputed patch+text embeddings
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_expert_parallel: bool = False  # expert-parallel layout (vs ff-sharded)
    moe_shared_expert: bool = False
    moe_layer_period: int = 1  # every k-th layer is MoE (llama4: 2)
    dense_d_ff: int = 0  # ff width of interleaved dense layers; 0 -> d_ff

    # --- SSM (Mamba-2 SSD) ---
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # --- hybrid (RG-LRU) ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "local_attn")
    lru_width: int = 0  # 0 -> d_model

    # --- attention / positions ---
    attention: str = "full"  # full | local | none
    local_window: int = 4_096
    causal: bool = True
    rope_variant: str = "standard"  # standard | half | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # --- MLP / norm ---
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm

    # --- modality / mode ---
    is_encoder: bool = False
    modality: str = "text"  # text | audio | vision_text
    tie_embeddings: bool = False

    # --- serving ---
    # For `long_500k` decode of full-attention archs we use a bounded
    # sliding-window KV (sub-quadratic / O(window) decode). 0 disables.
    sliding_window_decode: int = 8_192

    dtype: str = "bfloat16"
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches the materialized pytree; see
        tests/test_configs.py)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q_dim = self.num_heads * hd
        kv_dim = self.num_kv_heads * hd

        def attn_params() -> int:
            return d * q_dim + 2 * d * kv_dim + q_dim * d

        def mlp_params(width: int) -> int:
            if self.mlp_variant in ("swiglu", "geglu"):
                return 3 * d * width
            return 2 * d * width

        def moe_params() -> int:
            p = d * self.num_experts  # router
            p += self.num_experts * mlp_params(ff) // 1
            if self.moe_shared_expert:
                p += mlp_params(ff)
            return p

        norm = 2 * d if self.norm == "layernorm" else d

        def block_params(btype: str) -> int:
            if btype in ("dense", "encoder", "local_attn"):
                width = ff
                if btype == "dense" and self.arch_type == "moe":
                    width = self.dense_d_ff or ff
                return attn_params() + mlp_params(width) + 2 * norm
            if btype == "moe":
                return attn_params() + moe_params() + 2 * norm
            if btype == "ssd":
                di, ns = self.d_inner, self.ssm_state_dim
                nh = self.ssm_num_heads
                # in_proj (z,x,B,C,dt) ; out_proj ; conv ; A,D,dt_bias ; norms
                return (d * (2 * di + 2 * ns + nh) + di * d
                        + self.conv_kernel * (di + 2 * ns) + 3 * nh
                        + di + norm)
            if btype == "rglru":
                lw = self.resolved_lru_width
                rec = (d * 2 * lw + lw * d + 2 * lw * lw + 3 * lw
                       + self.conv_kernel * lw)
                return rec + mlp_params(ff) + 2 * norm
            raise ValueError(btype)

        # exact block counts from the block program (handles tails)
        from collections import Counter

        if self.arch_type in ("dense", "vlm"):
            pattern = ("dense",)
        elif self.arch_type == "audio":
            pattern = ("encoder",)
        elif self.arch_type == "moe":
            pattern = ("dense",) * (self.moe_layer_period - 1) + ("moe",)
        elif self.arch_type == "ssm":
            pattern = ("ssd",)
        else:
            pattern = self.block_pattern or ("rglru", "rglru", "local_attn")
        n_rep, rem = divmod(self.num_layers, len(pattern))
        counts = Counter()
        for bt in pattern:
            counts[bt] += n_rep
        for bt in pattern[:rem]:
            counts[bt] += 1

        total = sum(block_params(bt) * n for bt, n in counts.items())
        total += norm  # final norm
        if self.modality != "audio":
            total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head / classifier
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        full = self.param_count()
        d, ff = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_variant in ("swiglu", "geglu") else 2) * d * ff
        inactive = (self.num_experts - self.experts_per_token) * per_expert
        num_moe_layers = self.num_layers // self.moe_layer_period
        return full - num_moe_layers * inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family: 2 layers, d_model<=512,
        <=4 experts, tiny vocab. Used by per-arch CPU smoke tests."""
        d = min(self.d_model, 256)
        hd = 32
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        changes = dict(
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) or self.d_ff,
            vocab_size=min(self.vocab_size, 512),
            local_window=min(self.local_window, 64),
            sliding_window_decode=min(self.sliding_window_decode, 128) if self.sliding_window_decode else 0,
            ssm_chunk=32,
            dtype="float32",
        )
        if self.num_experts:
            changes["num_experts"] = min(4, self.num_experts)
            changes["experts_per_token"] = min(self.experts_per_token, 2)
            # non-binding capacity so prefill/decode token grouping cannot
            # change which tokens are served (smoke-test determinism)
            changes["moe_capacity_factor"] = 8.0
        if self.ssm_state_dim:
            changes["ssm_state_dim"] = 16
            changes["ssm_head_dim"] = 16
        if self.lru_width:
            changes["lru_width"] = d
        if self.block_pattern:
            changes["block_pattern"] = self.block_pattern
        if self.rope_variant == "mrope":
            half = hd // 2
            t = half // 4
            changes["mrope_sections"] = (t, (half - t) // 2, half - t - (half - t) // 2)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ASSIGNED_ARCHS = (
    "starcoder2_15b",
    "grok_1_314b",
    "granite_8b",
    "chatglm3_6b",
    "mamba2_1_3b",
    "recurrentgemma_9b",
    "phi3_medium_14b",
    "llama4_maverick_400b",
    "hubert_xlarge",
    "qwen2_vl_7b",
)

_ALIAS = {
    "starcoder2-15b": "starcoder2_15b",
    "grok-1-314b": "grok_1_314b",
    "granite-8b": "granite_8b",
    "chatglm3-6b": "chatglm3_6b",
    "mamba2-1.3b": "mamba2_1_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "dlrm": "dlrm",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict:
    return {n: get_config(n) for n in ASSIGNED_ARCHS}


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def applicable_shapes(cfg: ArchConfig) -> list:
    """Shapes that apply to an arch (encoder-only archs have no decode)."""
    out = []
    for s in INPUT_SHAPES.values():
        if s.kind == "decode" and not cfg.supports_decode:
            continue  # encoder-only: no autoregressive decode (see DESIGN.md)
        out.append(s)
    return out
