"""StarCoder2-15B — dense code LLM with GQA + RoPE [arXiv:2402.19173]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    arch_type="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    rope_variant="standard",
    rope_theta=100_000.0,
    mlp_variant="gelu",
    norm="layernorm",
    citation="arXiv:2402.19173",
)
