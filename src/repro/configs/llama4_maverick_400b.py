"""Llama-4 Maverick 400B-A17B — MoE decoder, 128 experts top-1 with a
shared expert, early-fusion multimodal [hf:meta-llama/Llama-4-Scout-17B-16E].

128 experts divide the 16-way model axis exactly, so this config enables
the expert-parallel layout (the survey's 'efficient model sharding' space).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_shared_expert=True,
    moe_expert_parallel=True,
    moe_layer_period=2,  # MoE every other layer, dense (ff=16384) between
    dense_d_ff=16384,
    rope_variant="standard",
    mlp_variant="swiglu",
    norm="rmsnorm",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
