"""Grok-1 314B — MoE decoder, 8 experts top-2, GQA [hf:xai-org/grok-1]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    rope_variant="standard",
    mlp_variant="geglu",
    norm="rmsnorm",
    citation="hf:xai-org/grok-1",
)
