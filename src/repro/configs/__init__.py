from repro.configs.base import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    ArchConfig,
    ShapeConfig,
    all_configs,
    applicable_shapes,
    get_config,
    get_shape,
)

__all__ = [
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "all_configs",
    "applicable_shapes",
    "get_config",
    "get_shape",
]
