"""ShapeDtypeStruct stand-ins for every model input (dry-run: weak-type
correct, shardable, no device allocation).

``input_specs(cfg, shape)`` returns the *batch* inputs; decode shapes also
need ``decode_cache_specs``. VLM/audio frontends are stubbed here: the
specs carry precomputed patch/frame embeddings of the right shape (the one
allowed carve-out, DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import cache_specs
from repro.training.optimizer import init_adamw

VLM_PATCHES = 1024  # early-fusion vision prefix length (stub frontend)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def decode_window(cfg, seq_len: int) -> int:
    """KV window for a decode shape: full context at 32k; the sub-quadratic
    sliding window for 500k (full-attention archs); SSM/hybrid archs carry
    O(1) state regardless."""
    if seq_len > 100_000 and cfg.sliding_window_decode:
        return cfg.sliding_window_decode
    if cfg.arch_type == "ssm":
        return 1  # no attention blocks; window is vestigial
    return seq_len


def input_specs(cfg, shape) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    model_dtype = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.modality == "audio":
            batch = {"frames": _sds((b, s, cfg.d_model), model_dtype)}
            if shape.kind == "train":
                batch["labels"] = _sds((b, s), jnp.int32)
            return batch
        if cfg.modality == "vision_text":
            p = min(VLM_PATCHES, s // 2)
            batch = {
                "tokens": _sds((b, s - p), jnp.int32),
                "patches": _sds((b, p, cfg.d_model), model_dtype),
                "positions": _sds((3, b, s), jnp.int32),
            }
            if shape.kind == "train":
                batch["labels"] = _sds((b, s - p), jnp.int32)
            return batch
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
        return batch
    # decode: ONE new token against the KV cache
    batch = {"tokens": _sds((b, 1), jnp.int32)}
    if cfg.rope_variant == "mrope":
        batch["positions"] = _sds((3, b, 1), jnp.int32)
    return batch


def decode_cache_specs(cfg, shape, kv_dtype: str = ""):
    assert shape.kind == "decode"
    w = decode_window(cfg, shape.seq_len)
    return cache_specs(cfg, shape.global_batch, w, kv_dtype)


def opt_state_specs(cfg, params_sds):
    return jax.eval_shape(init_adamw, params_sds)
