"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --steps 200 --batch 8 --seq 256 --reduced

On the CPU container use ``--reduced`` (the smoke-scale family variant);
on a real pod drop it and pass ``--mesh single|multi`` to engage the
production sharding rules from repro.core.simd.sharding.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import init_params
from repro.training import TokenPipeline, init_adamw, train_step
from repro.training.checkpoint import latest_step, restore_into, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params will be "
          f"{cfg.param_count()/1e6:.1f}M ({cfg.arch_type})")

    params = init_params(cfg, jax.random.key(args.seed))
    opt = init_adamw(params)
    n_par = sum(x.size for x in jax.tree.leaves(params))
    print(f"materialized {n_par/1e6:.2f}M params")

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    step_fn = jax.jit(partial(
        train_step, cfg, accum=args.accum, peak_lr=args.lr,
        total_steps=args.steps))

    start = 0
    if args.ckpt:
        s = latest_step(args.ckpt)
        if s >= 0:
            params = restore_into(args.ckpt, s, jax.eval_shape(lambda: params))
            params = jax.tree.map(jnp.asarray, params)
            start = s
            print(f"restored step {s}")

    t0 = time.time()
    losses = []
    for step, batch in enumerate(pipe.batches(start), start=start):
        if step >= args.steps:
            break
        if cfg.modality == "vision_text":
            b, s = batch["tokens"].shape
            batch["positions"] = np.broadcast_to(
                np.arange(s, dtype=np.int32), (3, b, s)).copy()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["ce"]))
        if step % args.log_every == 0:
            dt = time.time() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d}  ce={losses[-1]:.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}  tok/s={tok_s:,.0f}")
        if args.ckpt and step and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step, params)
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps, params)
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"done: ce {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
