import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count on first init).
# Set only here — smoke tests and benches see the real single device.
# Extra flags append (e.g. the bf16-all-reduce perf lever passes
# XLA_FLAGS=--xla_allow_excess_precision=false; EXPERIMENTS.md §Perf).

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh with ShapeDtypeStruct inputs (no
allocation), then record memory/cost/collective analysis for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config, get_shape
from repro.core.simd.sharding import (
    batch_pspecs,
    cache_pspecs,
    make_policy,
    opt_pspecs,
    param_pspecs,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    decode_cache_specs,
    decode_window,
    input_specs,
    opt_state_specs,
)
from repro.models import param_specs
from repro.serving.engine import prefill_step, serve_step
from repro.training.train import train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_.-]+\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
# ring-algorithm traffic multiplier per collective kind
_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic (bytes) by op kind, from result buffer
    sizes of every collective op in the SPMD module (methodology in
    EXPERIMENTS.md §Dry-run)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        result_types, kind = m.group(1), m.group(2).lower()
        if m.group(3):  # -start; the matching bare op was already skipped
            pass
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(result_types):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes * _COLL_MULT[kind]
    return out


def sharded_bytes(sds_tree, pspec_tree, mesh) -> float:
    """Analytic per-device bytes of a sharded pytree."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0.0
    for sds, spec in zip(jax.tree.leaves(sds_tree),
                         jax.tree.leaves(pspec_tree, is_leaf=lambda x: isinstance(x, P))):
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                denom *= axes.get(a, 1)
        total += sds.size * sds.dtype.itemsize / denom
    return total


def build(cfg, shape, mesh, *, accum: int = 8, fsdp=None,
          opts: frozenset = frozenset()):
    """Returns (jitted_fn, example_args_SDS, arg_bytes_per_device)."""
    import dataclasses as _dc

    pol = make_policy(cfg, mesh, fsdp=fsdp)
    if "kv_seq" in opts:
        pol = _dc.replace(pol, kv_shard="seq")
    params_sds = param_specs(cfg)
    p_spec = param_pspecs(cfg, params_sds, pol)
    sh = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    batch_sds = input_specs(cfg, shape)
    b_spec = batch_pspecs(cfg, batch_sds, pol, mesh)

    if shape.kind == "train":
        opt_sds = opt_state_specs(cfg, params_sds)
        o_spec = opt_pspecs(cfg, opt_sds, pol)
        fn = jax.jit(
            partial(train_step, cfg, accum=accum),
            in_shardings=(sh(p_spec), sh(o_spec), sh(b_spec)),
            out_shardings=(sh(p_spec), sh(o_spec), None),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, batch_sds)
        arg_bytes = (sharded_bytes(params_sds, p_spec, mesh)
                     + sharded_bytes(opt_sds, o_spec, mesh)
                     + sharded_bytes(batch_sds, b_spec, mesh))
    elif shape.kind == "prefill":
        bdim = _batch_axis_entry(cfg, shape, pol, mesh)
        vax = _vocab_axis(cfg, mesh)
        if cfg.is_encoder:
            # encoder-only: whole-utterance inference, no KV cache
            from repro.models import forward

            def encode_step(params, batch):
                logits, _, _ = forward(cfg, params, batch, mode="prefill")
                return logits

            fn = jax.jit(
                encode_step,
                in_shardings=(sh(p_spec), sh(b_spec)),
                out_shardings=sh(P(bdim, None, vax)),
            )
            args = (params_sds, batch_sds)
            arg_bytes = (sharded_bytes(params_sds, p_spec, mesh)
                         + sharded_bytes(batch_sds, b_spec, mesh))
            return fn, args, arg_bytes
        w = shape.seq_len
        cache_sds = decode_cache_specs(
            cfg, type(shape)(shape.name, shape.seq_len, shape.global_batch,
                             "decode"))
        c_spec = cache_pspecs(cfg, cache_sds, pol, mesh)
        logits_spec = P(bdim, vax)
        fn = jax.jit(
            partial(prefill_step, cfg, window=w),
            in_shardings=(sh(p_spec), sh(b_spec)),
            out_shardings=(sh(logits_spec), sh(c_spec)),
        )
        args = (params_sds, batch_sds)
        arg_bytes = (sharded_bytes(params_sds, p_spec, mesh)
                     + sharded_bytes(batch_sds, b_spec, mesh))
    else:  # decode
        cache_sds = decode_cache_specs(
            cfg, shape, kv_dtype="int8" if "kv_int8" in opts else "")
        c_spec = cache_pspecs(cfg, cache_sds, pol, mesh)
        bdim = _batch_axis_entry(cfg, shape, pol, mesh)
        vax = _vocab_axis(cfg, mesh)
        fn = jax.jit(
            partial(serve_step, cfg),
            in_shardings=(sh(p_spec), sh(c_spec), sh(b_spec)),
            out_shardings=(sh(P(bdim)), sh(P(bdim, vax)), sh(c_spec)),
            donate_argnums=(1,),
        )
        args = (params_sds, cache_sds, batch_sds)
        arg_bytes = (sharded_bytes(params_sds, p_spec, mesh)
                     + sharded_bytes(cache_sds, c_spec, mesh)
                     + sharded_bytes(batch_sds, b_spec, mesh))
    return fn, args, arg_bytes


def _vocab_axis(cfg, mesh):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return "model" if cfg.vocab_size % axes.get("model", 1) == 0 else None


def _batch_axis_entry(cfg, shape, pol, mesh):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in pol.batch_axes:
        n *= axes.get(a, 1)
    if shape.global_batch % n == 0:
        return pol.batch_axes if len(pol.batch_axes) > 1 else pol.batch_axes[0]
    if shape.global_batch % axes.get("data", 1) == 0:
        return "data"
    return None


def _hints_ctx(mesh, opts):
    from repro.util import sharding_hints

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    div = 1
    for a in batch_axes:
        div *= axes[a]
    return sharding_hints(batch_axes=batch_axes, model_axis="model",
                          opts=opts, batch_div=div)


def _count_compile(cfg, shape, mesh, fsdp, opts=frozenset()):
    """Compile the fully-unrolled variant; return (flops, bytes, coll_dict)."""
    from repro.util import unrolled_scans

    with unrolled_scans(), _hints_ctx(mesh, opts):
        fn, args, _ = build(cfg, shape, mesh, accum=1, fsdp=fsdp, opts=opts)
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", -1)),
            float(cost.get("bytes accessed", -1)), coll)


def run_count(cfg, shape, mesh, opts=frozenset()):
    """Exact count-mode statistics.

    Full-sequence shapes (train/prefill) of deep stacks are measured by the
    AFFINE-PROBE method: compile unrolled variants at 2 and 4 pattern
    repeats; every cost statistic is affine in the repeat count (embed/head
    outside the stack, identical blocks inside), so the full-depth value is
    an exact linear extrapolation. Decode shapes unroll directly (cheap).
    """
    import dataclasses

    from repro.models import block_program

    fsdp = make_policy(cfg, mesh).fsdp
    pattern, n_repeat, tail = block_program(cfg)
    if shape.kind in ("train", "prefill") and n_repeat > 4:
        r1, r2 = 2, 4
        probes = []
        for r in (r1, r2):
            cfg_r = dataclasses.replace(
                cfg, num_layers=len(pattern) * r + len(tail))
            probes.append(_count_compile(cfg_r, shape, mesh, fsdp, opts))
        (f1, b1, c1), (f2, b2, c2) = probes

        def extra(v1, v2):
            slope = (v2 - v1) / (r2 - r1)
            return v2 + slope * (n_repeat - r2)

        coll = {k: extra(c1.get(k, 0.0), c2.get(k, 0.0))
                for k in set(c1) | set(c2)}
        cost = {"flops": extra(f1, f2), "bytes accessed": extra(b1, b2)}
        return cost, coll, f"affine-probe(r={r1},{r2}->{n_repeat})"
    f, b, coll = _count_compile(cfg, shape, mesh, fsdp, opts)
    return {"flops": f, "bytes accessed": b}, coll, "unrolled"


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            accum: int = 8, save: bool = True, count_mode: bool = True,
            opts: frozenset = frozenset(), tag: str = "") -> dict:
    """Two compiles per combo:
      exec pass  — production form (rolled scans, grad accumulation):
                   proves lowering/compilation + memory fit.
      count pass — every scan fully unrolled (util.unrolled_scans): XLA
                   cost_analysis counts while-loop bodies ONCE, so only the
                   unrolled module yields exact FLOPs/bytes/collectives.
    """
    from repro.util import unrolled_scans

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()
    with mesh:
        fn, args, arg_bytes = build(cfg, shape, mesh, accum=accum, opts=opts)
        with _hints_ctx(mesh, opts):
            lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:  # pragma: no cover
            mem_d = {"error": str(e)}
        del compiled, lowered
        # --- count pass ---
        t1 = time.time()
        if count_mode:
            cost, coll, count_meta = run_count(cfg, shape, mesh, opts)
        else:
            fn_c, args_c, _ = build(cfg, shape, mesh, accum=accum)
            compiled_c = fn_c.lower(*args_c).compile()
            cost = compiled_c.cost_analysis() or {}
            coll = collective_bytes(compiled_c.as_text())
            count_meta = "rolled"
            del compiled_c
        t_count = time.time() - t1
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "count_pass_s": round(t_count, 2),
        "count_mode": count_meta if count_mode else "rolled",
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes_per_device": coll,
        "collective_total_per_device": float(sum(coll.values())),
        "arg_bytes_per_device": arg_bytes,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory_analysis": mem_d,
        "opts": sorted(opts),
    }
    if save:
        out_dir = RESULTS_DIR if not tag else os.path.join(
            RESULTS_DIR, "..", "perf")
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--accum", type=int, default=8)
    ap.add_argument("--opt", default="",
                    help="comma list of perf levers: kv_seq,attn_carry,...")
    ap.add_argument("--tag", default="",
                    help="label; tagged runs save under results/perf/")
    args = ap.parse_args()
    opts = frozenset(x for x in args.opt.split(",") if x)

    combos = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            for shape in applicable_shapes(cfg):
                for m in meshes:
                    combos.append((arch, shape.name, m))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape_name, m in combos:
        path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{m}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {arch} {shape_name} {m}")
            continue
        try:
            # count-mode (unrolled, slow) only for the single-pod mesh: the
            # roofline table is single-pod; multi-pod proves lowering only.
            rec = run_one(arch, shape_name, m == "multi", accum=args.accum,
                          count_mode=(m == "single"), opts=opts,
                          tag=args.tag)
            print(f"[ok]   {arch:24s} {shape_name:12s} {m:6s} "
                  f"flops={rec['flops']:.3e} "
                  f"coll/dev={rec['collective_total_per_device']:.3e}B "
                  f"compile={rec['compile_s']:.1f}s")
        except Exception as e:
            failures += 1
            print(f"[FAIL] {arch} {shape_name} {m}: {type(e).__name__}: {e}")
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape_name, "mesh": m,
                           "ok": False, "error": str(e)[:2000]}, f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
