"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization. Single pod = 256 chips
as (data=16, model=16); multi-pod = 512 chips as (pod=2, data=16, model=16).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg when this jax exposes ``sharding.AxisType``
    (explicit Auto on newer jax; older releases default to the same)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh(*, data: int = 1, model: int = 1):
    """(data x model) mesh over the host's real local devices; the 1x1
    default serves tests/examples. Requesting more devices than the host
    exposes fails HERE with the fix in the message — previously this
    surfaced as an opaque XLA device-assignment error at first trace."""
    need = data * model
    have = jax.local_device_count()
    if need > have:
        raise ValueError(
            f"local mesh (data={data} x model={model}) needs {need} "
            f"devices but this host exposes {have}; on CPU hosts set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} in "
            f"the environment before jax initializes, or shrink the "
            f"requested topology")
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))


def make_serving_mesh(topology):
    """Mesh for one sharded ``ServingEngine`` replica
    (``repro.serving.config.DeviceTopology``)."""
    return make_local_mesh(data=topology.dp, model=topology.tp)
