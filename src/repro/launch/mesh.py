"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization. Single pod = 256 chips
as (data=16, model=16); multi-pod = 512 chips as (pod=2, data=16, model=16).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg when this jax exposes ``sharding.AxisType``
    (explicit Auto on newer jax; older releases default to the same)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh():
    """1x1 mesh over the real local device (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_type_kwargs(2))
