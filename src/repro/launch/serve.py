"""Serving driver: continuous-batching engine(s) fed by a synthetic
open-loop client, reporting the survey's serving metrics (QPS, latency
percentiles, TTFT, JCT, SLO attainment).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \
        --requests 32 --slots 4 --rate 8

``--slots 0`` derives the slot count and admission flush deadline from the
cost model (repro.core.misd.batching.plan_admission) instead of constants.

``--replicas N`` (N > 1) serves the same traffic through the multi-engine
cluster frontend (repro.serving.cluster): N ServingEngine replicas behind
one SLO-aware (EDF) frontend queue, routed by ``--route-policy``
(round-robin | least-loaded | p2c | predicted). ``--ttft-slo-ms`` tags
every request with a TTFT deadline so the report includes SLO goodput.

``--temperature`` > 0 switches every request to stochastic decode
(optionally bounded by ``--top-k`` / ``--top-p``); request i samples with
seed ``--sample-seed + i``, so a rerun — or the same workload routed to
different replicas — reproduces every stream bit-for-bit.

Observability exports (PR 8):

``--trace-out PATH`` turns on span tracing (engine + frontend stamp a
typed span trace on every request at existing host-sync points) and
writes the whole run as Chrome-trace JSON — open it at
https://ui.perfetto.dev. ``--metrics-out PATH`` writes the merged
metrics registry (counters + mergeable latency histograms) as
Prometheus-style text exposition plus a JSON snapshot at ``PATH.json``.
``--profile-dir DIR`` arms ``jax.profiler`` around the serving loop via
``EngineConfig.profile_dir`` (TensorBoard-loadable XLA trace).
``--trace-sample-n N`` keeps tracing affordable at rate: only every Nth
request (by rid) carries a span trace.

Multi-tenant overload control (PR 9, serving/overload.py):

``--tenants "gold=2:4,bulk=0:1:256:2048"`` declares SLO classes
(``name=tier:weight[:rate_tokens_s[:burst_tokens]]``); the synthetic
client tags requests round-robin across them, the frontend queue
becomes weighted-fair (DRR across tenants, EDF within), and over-rate
submits are refused with a finite ``retry_after_s``. ``--overload``
additionally arms the degradation-ladder detector (shed lowest tier →
brownout → reject-with-retry-after; pooled p99 TTFT vs ``--ttft-slo-ms``
plus cost-model backlog) and the failover circuit breaker.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.costmodel import suggest_health_timeout_s
from repro.core.mimd.router import POLICIES
from repro.models import init_params
from repro.serving import (
    CircuitBreaker,
    ClusterFrontend,
    DeviceTopology,
    EngineConfig,
    OverloadDetector,
    PrecisionConfig,
    Request,
    SamplingParams,
    ServingEngine,
    TenantClass,
)
from repro.serving.trace_export import request_traces, write_chrome_trace


def _parse_tenants(spec: str) -> dict:
    """``gold=2:4,bulk=0:1:256:2048`` ->
    ``{name: TenantClass}`` (name=tier:weight[:rate_tokens_s[:burst]])."""
    tenants = {}
    for part in filter(None, spec.split(",")):
        name, _, shape = part.partition("=")
        f = [x for x in shape.split(":")] if shape else []
        tenants[name] = TenantClass(
            name,
            tier=int(f[0]) if len(f) > 0 and f[0] else 0,
            weight=float(f[1]) if len(f) > 1 and f[1] else 1.0,
            rate_tokens_s=float(f[2]) if len(f) > 2 and f[2] else 0.0,
            burst_tokens=float(f[3]) if len(f) > 3 and f[3] else 0.0)
    return tenants


def _engine_config(args) -> EngineConfig:
    return EngineConfig(slots=args.slots, window=args.window,
                        sync_every=args.sync_every,
                        chunk_prefill=args.chunk_prefill,
                        sla_s=args.sla_ms / 1e3,
                        paged=None if not args.no_paged else False,
                        page_size=args.page_size,
                        max_seq=args.max_seq or None,
                        pool_pages=args.pool_pages or None,
                        prefix_cache=args.prefix_cache,
                        preemption=args.preemption,
                        topology=DeviceTopology(dp=args.dp, tp=args.tp),
                        moe_capacity_policy=args.moe_capacity or None,
                        precision=PrecisionConfig(
                            kv_cache_dtype=args.kv_dtype,
                            weight_dtype=args.weight_dtype),
                        tracing=bool(args.trace_out),
                        trace_sample_n=args.trace_sample_n,
                        profile_dir=args.profile_dir or None)


def _build_engine(cfg, params, args):
    return ServingEngine(cfg, params, _engine_config(args))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots; 0 = derive from the cost model")
    ap.add_argument("--window", type=int, default=256)
    ap.add_argument("--rate", type=float, default=8.0, help="arrivals/s")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode ticks per device->host token sync")
    ap.add_argument("--chunk-prefill", type=int, default=64,
                    help="chunked-prefill piece size; 0 = single-shot")
    ap.add_argument("--sla-ms", type=float, default=50.0,
                    help="per-step SLA budget for the admission plan")
    ap.add_argument("--no-paged", action="store_true",
                    help="force rolling-window KV (paged is the default "
                         "for pageable archs)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="per-request token cap / page-table width; "
                         "0 = window (raise to exceed the old window cap)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="shared KV pool size in pages; 0 = full headroom, "
                         "less oversubscribes (admission backpressure)")
    ap.add_argument("--kv-dtype", default="", choices=["", "int8"],
                    help="KV-cache page dtype: int8 stores pages as int8 "
                         "values + per-vector fp32 scales (paged only; "
                         "plan_admission converts the saving into slots)")
    ap.add_argument("--weight-dtype", default="", choices=["", "int8"],
                    help="weight-only int8 for the attention/MLP matmuls "
                         "(per-output-channel fp32 scales, f32 accumulation)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix KV cache: keep finished prompts' "
                         "pages in a radix index; later requests alias "
                         "them and prefill only their suffix (paged only)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor/expert-parallel ways per replica (the "
                         "mesh 'model' axis); needs tp*dp local devices — "
                         "on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel ways per replica (the mesh 'data' "
                         "axis)")
    ap.add_argument("--moe-capacity", default="",
                    choices=("", "strict", "backpressure", "drop"),
                    help="MoE capacity-overflow policy; empty = strict on "
                         "sharded MoE replicas, drop otherwise")
    ap.add_argument("--replicas", type=int, default=1,
                    help="ServingEngine replicas behind the cluster "
                         "frontend; 1 = single-engine path")
    ap.add_argument("--route-policy", default="predicted",
                    choices=POLICIES,
                    help="cluster routing policy (with --replicas > 1)")
    ap.add_argument("--ttft-slo-ms", type=float, default=0.0,
                    help="per-request TTFT deadline; 0 = untracked")
    ap.add_argument("--tpot-slo-ms", type=float, default=0.0,
                    help="per-request mean TPOT bound; 0 = untracked")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="decode sampling temperature; 0 = greedy argmax")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the k largest logits; 0 = no cut")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass; 1 = no cut")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base sampling seed; request i uses seed+i "
                         "(streams reproduce across runs and replicas)")
    ap.add_argument("--request-timeout-s", type=float, default=0.0,
                    help="per-request JCT deadline; overdue requests are "
                         "aborted and their slot/pages freed (0 = none)")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="per-request failover budget at the cluster "
                         "frontend (with --replicas > 1)")
    ap.add_argument("--preemption", action="store_true",
                    help="allow evicting a decoding slot for a more "
                         "urgent arrival; the victim's generated prefix "
                         "is cached and its stream restored bit-identical "
                         "(paged engines only)")
    ap.add_argument("--metrics-out", default="",
                    help="write the merged metrics registry here as "
                         "Prometheus-style text exposition, plus a JSON "
                         "snapshot at PATH.json")
    ap.add_argument("--trace-out", default="",
                    help="turn on request span tracing and write the run "
                         "as Chrome-trace JSON (ui.perfetto.dev)")
    ap.add_argument("--trace-sample-n", type=int, default=1,
                    help="with tracing on, trace only every Nth request "
                         "(rid %% N == 0); 1 = all")
    ap.add_argument("--tenants", default="",
                    help="SLO classes as name=tier:weight[:rate_tokens_s"
                         "[:burst_tokens]],... — requests are tagged "
                         "round-robin; the frontend queue turns "
                         "weighted-fair (DRR across tenants)")
    ap.add_argument("--overload", action="store_true",
                    help="arm the degradation-ladder overload detector "
                         "(uses --ttft-slo-ms as the pooled p99 target) "
                         "and the failover circuit breaker")
    ap.add_argument("--profile-dir", default="",
                    help="arm jax.profiler around the serving loop; the "
                         "XLA trace lands in this dir (TensorBoard)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch: no autoregressive serving")
    if args.temperature <= 0 and (args.top_k > 0 or args.top_p < 1.0):
        print("warning: --top-k/--top-p have no effect with "
              "--temperature 0 (greedy decode); pass --temperature > 0 "
              "to sample", file=sys.stderr)

    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.key(args.seed))
    eng = _build_engine(cfg, params, args)
    if not args.slots:
        print(f"admission plan: slots={eng.slots} "
              f"flush_deadline={eng.plan.flush_deadline_s*1e3:.2f}ms "
              f"(cost-model step={eng.plan.step_latency_s*1e3:.3f}ms)")
    if eng.paged:
        print(f"paged KV: page_size={eng.page_size} max_seq={eng.max_seq} "
              f"pool={eng.pool_pages} pages "
              f"({eng.allocator.capacity} usable + trash)")
    if eng.kv_dtype or args.weight_dtype:
        rep = eng.load_report()
        print(f"quantized: kv_cache_dtype={rep.kv_cache_dtype or 'f32'} "
              f"weight_dtype={rep.weight_dtype or 'f32'} "
              f"kv_bytes/token={rep.kv_bytes_per_token:.0f}")
    if eng.topology.sharded:
        rep = eng.load_report()
        print(f"sharded replica: mesh {dict(eng.topology.mesh_axes)} "
              f"({eng.topology.n_chips} devices), per-axis collective "
              f"s/tick {dict(rep.axis_collective_s)}"
              + (f", moe_capacity_policy={eng.moe_capacity_policy}"
                 if eng.moe_capacity_policy else ""))

    tenants = _parse_tenants(args.tenants)
    if args.overload and not tenants:
        raise SystemExit("--overload needs --tenants: the degradation "
                         "ladder defends SLO tiers")
    cluster = None
    engines = [eng]
    if args.replicas > 1 or tenants:
        # tenants force the cluster path even at 1 replica: the fair
        # queue, admission, and ladder live at the frontend
        engines = [eng] + [_build_engine(cfg, params, args)
                           for _ in range(args.replicas - 1)]
        # cost-model ticks model the target chip, not this host: floor the
        # wall-clock watchdog so a CPU run never trips on modeled speed
        health_s = max(1.0, suggest_health_timeout_s(cfg, slots=eng.slots,
                                                     context=eng.window,
                                                     n_chips=eng.n_chips))
        detector = (OverloadDetector(
            ttft_slo_s=(args.ttft_slo_ms / 1e3) or 1.0)
            if args.overload else None)
        cluster = ClusterFrontend(engines, policy=args.route_policy,
                                  seed=args.seed,
                                  health_timeout_s=health_s,
                                  max_retries=args.max_retries,
                                  tracing=bool(args.trace_out),
                                  tenants=tenants or None,
                                  overload=detector,
                                  breaker=(CircuitBreaker()
                                           if args.overload else None))
        print(f"cluster frontend: {len(engines)} replicas, "
              f"policy={args.route_policy}, "
              f"{'weighted-fair (DRR)' if tenants else 'EDF'} frontend "
              f"queue, health_timeout={health_s*1e3:.0f}ms "
              f"max_retries={args.max_retries}")
        if tenants:
            print("tenants: " + "  ".join(
                f"{tc.name}(tier={tc.tier} w={tc.weight:g}"
                + (f" rate={tc.rate_tokens_s:g}tok/s" if tc.rate_tokens_s
                   else "") + ")" for tc in tenants.values())
                + ("  [overload ladder armed]" if args.overload else ""))

    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    names = list(tenants)
    reqs = [
        Request(
            rid=i,
            tenant=names[i % len(names)] if names else "",
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            arrival_time=float(arrivals[i]),
            ttft_slo_s=args.ttft_slo_ms / 1e3,
            tpot_slo_s=args.tpot_slo_ms / 1e3,
            timeout_s=args.request_timeout_s,
            sampling=SamplingParams(temperature=args.temperature,
                                    top_k=args.top_k, top_p=args.top_p,
                                    seed=args.sample_seed + i),
        )
        for i in range(args.requests)
    ]
    server = cluster if cluster is not None else eng
    queue = list(reqs)
    t0 = time.time()
    done = 0
    if args.profile_dir:
        for e in engines:
            e.start_profile()
    try:
        while done < args.requests:
            now = time.time() - t0
            while queue and queue[0].arrival_time <= now:
                server.submit(queue.pop(0), now)
            finished = server.step(time.time() - t0)
            done += len(finished)
            if cluster is not None:
                busy = not cluster.idle
            else:
                busy = (eng.n_active or eng.backlog
                        or eng.admission.pending)
            if not busy and queue:
                # idle until the next arrival
                time.sleep(max(0.0,
                               queue[0].arrival_time - (time.time() - t0)))
        done += len(server.drain(time.time() - t0))
    finally:
        if args.profile_dir:
            for e in engines:
                e.stop_profile()
    wall = time.time() - t0
    m = cluster.merged_metrics() if cluster is not None else eng.metrics
    m.total_time = wall
    lats = [r.finish_time - r.arrival_time for r in reqs]
    ttfts = [r.ttft for r in reqs if r.ttft >= 0]
    print(f"served {args.requests} requests in {wall:.2f}s  "
          f"qps={args.requests/wall:.2f}  tok/s={m.total_tokens/wall:.1f}  "
          f"ticks={m.decode_ticks}  host_syncs={m.host_syncs}  "
          f"prefill_chunks={m.prefill_chunks}")
    if m.prefix_hits:
        print(f"prefix cache: {m.prefix_hits} hits, "
              f"{m.prefix_hit_tokens} prompt tokens skipped")
    if m.sampled_requests:
        print(f"sampled decode: {m.sampled_requests} requests "
              f"(T={args.temperature} top_k={args.top_k} "
              f"top_p={args.top_p}, seeds {args.sample_seed}+rid)")
    print(f"latency p50={np.percentile(lats,50)*1e3:.0f}ms "
          f"p99={np.percentile(lats,99)*1e3:.0f}ms  "
          f"mean_jct={np.mean(lats)*1e3:.0f}ms  "
          f"ttft p50={np.percentile(ttfts,50)*1e3:.0f}ms "
          f"p95={np.percentile(ttfts,95)*1e3:.0f}ms")
    if m.slo_tracked:
        print(f"SLO goodput={m.goodput:.3f} "
              f"({m.slo_met}/{m.slo_tracked} in SLO; "
              f"ttft_misses={m.ttft_slo_misses} "
              f"tpot_misses={m.tpot_slo_misses})")
    lifecycle = (m.rejected, m.cancelled, m.timed_out, m.shed, m.failed,
                 m.preempted, m.retried, m.failed_over)
    if any(lifecycle):
        print(f"lifecycle: rejected={m.rejected} cancelled={m.cancelled} "
              f"timed_out={m.timed_out} shed={m.shed} failed={m.failed} "
              f"preempted={m.preempted} (restored={m.preempt_restores}) "
              f"retried={m.retried} failed_over={m.failed_over}")
    if cluster is not None:
        for inst in cluster.instances:
            print(f"  {inst.name}: routed={inst.routed} "
                  f"utilization={inst.utilization:.2f} "
                  f"residual={inst.corrector.correction:+.3f}")
    for name, tm in sorted(m.tenants.items()):
        goodput = (f" goodput={tm.slo_met / tm.slo_tracked:.3f}"
                   if tm.slo_tracked else "")
        print(f"  tenant {name}: admitted={tm.admitted} "
              f"completed={tm.completed} tokens={tm.total_tokens} "
              f"shed={tm.shed} rejected={tm.rejected} "
              f"browned_out={tm.browned_out}"
              f"(-{tm.brownout_trimmed_tokens}tok){goodput}")

    if args.metrics_out:
        reg = (cluster.metrics_registry() if cluster is not None
               else eng.metrics_registry())
        with open(args.metrics_out, "w") as f:
            f.write(reg.exposition())
        with open(args.metrics_out + ".json", "w") as f:
            json.dump(reg.snapshot(), f, indent=2)
        print(f"metrics: {args.metrics_out} (+ .json snapshot)")
    if args.trace_out:
        doc = write_chrome_trace(args.trace_out, request_traces(reqs))
        print(f"trace: {args.trace_out} "
              f"({len(doc['traceEvents'])} events; open in "
              f"https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
