"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: within each chunk a quadratic (attention-like)
intra-chunk term; chunk-to-chunk states propagate through a linear scan.
Decode carries O(1) state: (conv window, per-head SSM state (H, P, N)).

Shapes follow the paper: d_inner = expand*d_model, heads = d_inner/head_dim,
scalar A per head, shared B/C of state size N across heads (multi-value).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.util import scan as uscan

F32 = jnp.float32


def init_ssd(cfg, key, dtype):
    d = cfg.d_model
    di, ns, nh = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    ck = cfg.conv_kernel
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    in_dim = 2 * di + 2 * ns + nh  # z, x, B, C, dt
    return {
        "in_proj": jax.random.normal(ks[0], (d, in_dim), dtype) * std,
        "out_proj": jax.random.normal(ks[1], (di, d), dtype) * (di ** -0.5),
        "conv_w": jax.random.normal(ks[2], (ck, di + 2 * ns), dtype) * 0.2,
        "A_log": jnp.zeros((nh,), F32),  # A = -exp(A_log) in (-inf, 0)
        "D": jnp.ones((nh,), F32),
        "dt_bias": jnp.zeros((nh,), F32),
        "norm_scale": jnp.zeros((di,), dtype),
    }


def _split_proj(cfg, xz):
    di, ns, nh = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    z = xz[..., :di]
    xbc = xz[..., di : 2 * di + 2 * ns]
    dt = xz[..., 2 * di + 2 * ns :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_state=None, activation=jax.nn.silu):
    """Depthwise causal conv over time. xbc (B, S, C); conv_w (K, C).
    If conv_state (B, K-1, C) given, prepend it (decode/streaming)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
    out = sum(
        xp[:, i : i + xbc.shape[1]] * conv_w[i][None, None] for i in range(k)
    )
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    if activation is not None:
        out = activation(out)
    return out, new_state


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD. x (b,S,H,P); dt (b,S,H) >=0; A (H) <0; B,C (b,S,N).

    Returns y (b,S,H,P) and final state (b,H,P,N).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk:
        # pad to a chunk multiple: dt=0 on padded steps => decay 1, zero
        # input => state and real outputs are unaffected.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A[None, None, None, :]  # (b,nc,c,h) log-decay per step (<0)
    cums = jnp.cumsum(dA, axis=2)  # cumulative within chunk

    # --- intra-chunk (quadratic) ---
    # L[i,j] = exp(cums_i - cums_j) for j<=i  (segment decay)
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (b,nc,c,c,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(F32), Bc.astype(F32))
    # weight each source token by dt
    xin = xc.astype(F32) * dtc[..., None]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", CB, L, xin)

    # --- chunk states ---
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)  # (b,nc,c,h)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc.astype(F32),
                     decay_to_end, xin)  # state contribution per chunk

    # --- inter-chunk scan ---
    chunk_decay = jnp.exp(cums[:, :, -1, :])  # (b,nc,h) total chunk decay

    def scan_fn(hstate, inp):
        s_c, dec = inp  # (b,h,p,n), (b,h)
        h_new = hstate * dec[..., None, None] + s_c
        return h_new, hstate  # emit state *entering* the chunk

    h0 = jnp.zeros((b, h, p, n), F32)
    hT, h_enter = uscan(
        scan_fn,
        h0,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # --- inter-chunk output: y += C_i * decay(0..i) * h_enter ---
    decay_from_start = jnp.exp(cums)  # (b,nc,c,h)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc.astype(F32),
                         decay_from_start, h_enter)

    y = y_intra + y_inter + D[None, None, None, :, None] * xc.astype(F32)
    y = y.reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), hT


def apply_ssd(cfg, p, x, *, cache=None):
    """Full-sequence SSD block. x (B,S,d) -> (y, new_cache).

    cache (decode/streaming): {"conv": (B,K-1,C), "state": (B,H,P,N)}.
    For S>1 with cache=None this is train/prefill; the returned cache makes
    the block resumable for decode.
    """
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, xz)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state)
    xs = xbc[..., :di].reshape(b, s, nh, hd)
    B = xbc[..., di : di + ns]
    C = xbc[..., di + ns :]
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if s == 1 and cache is not None:
        # --- single-step decode ---
        h_prev = cache["state"]  # (B,H,P,N)
        dA = jnp.exp(dt[:, 0] * A[None])  # (B,H)
        xin = xs[:, 0].astype(F32) * dt[:, 0][..., None]  # (B,H,P)
        h_new = h_prev * dA[..., None, None] + jnp.einsum(
            "bn,bhp->bhpn", B[:, 0].astype(F32), xin)
        y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(F32), h_new)
        y = y + p["D"][None, :, None] * xs[:, 0].astype(F32)
        y = y.reshape(b, 1, di)
        new_cache = {"conv": new_conv, "state": h_new}
    else:
        y4, hT = ssd_chunked(xs, dt, A, B, C, p["D"], cfg.ssm_chunk)
        y = y4.reshape(b, s, di)
        new_cache = {"conv": new_conv, "state": hT}

    from repro.models.layers import rmsnorm

    y = rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_cache


def init_ssd_cache(cfg, batch: int, dtype):
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    k = cfg.conv_kernel
    return {
        "conv": jnp.zeros((batch, k - 1, di + 2 * ns), dtype),
        "state": jnp.zeros((batch, nh, hd, ns), F32),
    }
