"""Mixture-of-Experts layer: grouped capacity-based top-k dispatch.

GShard/Switch-style einsum dispatch: tokens are partitioned into groups
(groups shard across the `data` mesh axis), each group routes its tokens to
experts with a per-group capacity C = ceil(g * k * capacity_factor / E);
overflow tokens are dropped (residual passes through untouched, standard
for serving). Compiled FLOPs are O(active experts), not O(all experts) —
this keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest for MoE archs.

Two sharding layouts (the survey §4.2's "efficient model sharding" space):
  * ff-sharded (default): expert ff dim on `model` axis — works for any
    expert count (grok-1's 8 experts < 16-way axis).
  * expert-parallel (`moe_expert_parallel`): expert dim on `model` axis —
    all-to-all dispatch, used by llama4 (128 experts).

Router uses fp32 logits + softmax; aux load-balance loss (Switch) returned
for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.util import hint_opt, hints, wsc

F32 = jnp.float32


def init_moe(cfg, key, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    std_in, std_out = d ** -0.5, ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), F32) * std_in,
        "w_gate": jax.random.normal(ks[1], (e, d, ff), dtype) * std_in,
        "w_up": jax.random.normal(ks[2], (e, d, ff), dtype) * std_in,
        "w_down": jax.random.normal(ks[3], (e, ff, d), dtype) * std_out,
    }
    if cfg.mlp_variant == "gelu":
        del p["w_gate"]
    if cfg.moe_shared_expert:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(cfg, ks[4], d, ff, dtype)
    return p


def _capacity(cfg, g: int, *, full: bool = False) -> int:
    """Per-expert capacity slots for a token group of ``g``. ``full``
    sizes the buffer to the whole group — no routing pattern can overflow
    it (an expert receives at most one slot per token), so dropping is
    impossible. Serving uses this for drop-free decode ("strict" capacity
    policy): the decode group is the slot count, so the (N, g, E, C)
    combine tensor stays tiny — unlike training, where C ~ g would square
    the dispatch memory."""
    if full:
        return g
    e, k = cfg.num_experts, cfg.experts_per_token
    c = int(g * k * cfg.moe_capacity_factor / e) + 1
    return max(c, k)


def drop_free_group(cfg, *, cap: int = 1 << 20) -> int:
    """Largest token group that can NEVER drop a token under the
    configured ``moe_capacity_factor``, even with adversarial routing
    (every token picks the same expert, which then needs capacity >= g).
    The serving engine's "backpressure" capacity policy clamps its decode
    batch to this bound and rejects larger prefill groups — surfacing
    capacity overflow as typed admission backpressure instead of silent
    quality loss. Returns ``cap`` when the factor covers every group size
    (k * capacity_factor >= E: capacity grows at least as fast as g)."""
    e, k = cfg.num_experts, cfg.experts_per_token
    if not e or k * cfg.moe_capacity_factor >= e:
        return cap
    g = 1
    while g < cap and _capacity(cfg, g + 1) >= g + 1:
        g += 1
    return g


def apply_moe(cfg, p, x, *, group_size: int = 2048):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar fp32)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = min(group_size, t)
    while t % g:
        g //= 2
    n_groups = t // g
    # Serving engines with the "strict" capacity policy trace under the
    # "moe_full_cap" hint: capacity covers the whole group, so decode can
    # never silently drop a routed token (see _capacity).
    c = _capacity(cfg, g, full=hint_opt("moe_full_cap"))

    # Perf lever "moe_pin" (EXPERIMENTS.md §Perf): GSPMD cannot propagate a
    # sharding through the cumsum/one_hot dispatch construction and
    # replicates the (N,g,E,C) combine tensor on every device, then
    # all-reduces it — tens of TB per step at grok-1 scale. Pinning the
    # group dim (N) to the batch axes keeps routing fully local.
    pin = hint_opt("moe_pin")
    bspec = None
    if pin:
        h_ = hints()
        ba = h_["batch_axes"]
        bspec = ba if len(ba) > 1 else ba[0]

    from jax.sharding import PartitionSpec as _P

    UNC = _P.UNCONSTRAINED

    def pin_tokens(t, *rest):
        """Pin the group dim N to the batch axes; other dims stay
        UNCONSTRAINED (None would force replication — an earlier iteration
        accidentally all-gathered the ff dim this way, see §Perf log)."""
        if not pin or n_groups % max(hints()["batch_div"], 1):
            return t
        spec = rest if rest else (UNC,) * (t.ndim - 1)
        return wsc(t, bspec, *spec)

    xg = pin_tokens(x.reshape(n_groups, g, d))
    logits = pin_tokens(
        jnp.einsum("Ngd,de->Nge", xg.astype(F32), p["router"]))
    probs = pin_tokens(jax.nn.softmax(logits, axis=-1))  # (N, g, E)

    # --- top-k routing with per-expert capacity positions ---
    combine = jnp.zeros((n_groups, g, e, c), F32)
    gates_so_far = probs
    position_base = jnp.zeros((n_groups, e), jnp.int32)
    aux_me = probs.mean(axis=1)  # (N, E) mean router prob per expert
    aux_ce_acc = jnp.zeros((n_groups, e), F32)
    for _ in range(k):
        idx = jnp.argmax(gates_so_far, axis=-1)  # (N, g)
        onehot = jax.nn.one_hot(idx, e, dtype=F32)  # (N, g, E)
        gate = (gates_so_far * onehot).sum(-1)  # (N, g)
        # position of each token within its expert's capacity buffer
        pos_in_e = (jnp.cumsum(onehot, axis=1) - onehot) + position_base[:, None, :]
        pos = (pos_in_e * onehot).sum(-1).astype(jnp.int32)  # (N, g)
        keep = pos < c
        pos_oh = jax.nn.one_hot(pos, c, dtype=F32) * keep[..., None]
        combine = combine + gate[..., None, None] * onehot[..., None] * pos_oh[:, :, None, :]
        combine = pin_tokens(combine)
        position_base = position_base + onehot.sum(axis=1).astype(jnp.int32)
        aux_ce_acc = aux_ce_acc + onehot.mean(axis=1)
        gates_so_far = gates_so_far * (1.0 - onehot)

    combine = combine.astype(x.dtype)  # bf16 combine: gate precision is ample
    dispatch = pin_tokens((combine > 0.0).astype(x.dtype))  # (N, g, E, C)

    # --- expert computation ---
    xe = pin_tokens(jnp.einsum("NgEC,Ngd->NECd", dispatch, xg))
    hints_ = hints() if pin else None
    ma = hints_["model_axis"] if pin else None
    if pin and not cfg.moe_expert_parallel and cfg.d_ff % 16 == 0:
        f_spec = (None, None, ma)  # ff-sharded experts: keep f on model
    else:
        f_spec = (UNC, UNC, UNC)  # expert-parallel: GSPMD places E on model
    if cfg.mlp_variant in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else jax.nn.gelu
        gt = jnp.einsum("NECd,Edf->NECf", xe, p["w_gate"])
        up = jnp.einsum("NECd,Edf->NECf", xe, p["w_up"])
        h = pin_tokens(act(gt) * up, *f_spec)
    else:
        h = pin_tokens(
            jax.nn.gelu(jnp.einsum("NECd,Edf->NECf", xe, p["w_up"])),
            *f_spec)
    ye = pin_tokens(jnp.einsum("NECf,Efd->NECd", h, p["w_down"]))

    y = jnp.einsum("NgEC,NECd->Ngd", combine, ye)
    y = y.reshape(b, s, d)

    if cfg.moe_shared_expert:
        from repro.models.layers import apply_mlp

        y = y + apply_mlp(cfg, p["shared"], x)

    # Switch aux load-balance loss: E * sum_e (frac_tokens_e * mean_prob_e)
    aux = (e * (aux_ce_acc / k) * aux_me).sum(-1).mean()
    return y, aux
