"""Primitive layers: norms, RoPE variants, MLPs, attention.

All functions are pure (params passed explicitly) and shape-polymorphic over
batch/sequence. Matmuls accumulate in fp32 via ``preferred_element_type``;
softmax/normalization statistics are computed in fp32.

The long-sequence attention path (``block_causal_attention``) is a
flat block-pair online-softmax scan: it enumerates only the (q_chunk,
kv_chunk) pairs allowed by the mask structure (causal lower-triangle or a
sliding-window band), so HLO FLOPs match the true masked FLOPs instead of
the 2x overcount of mask-and-discard flash variants. This is the jnp oracle
twin of the Pallas flash kernel in ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.util import attn_chunk_default, hint_opt, hints, scan as uscan, wsc

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def init_norm(cfg, d, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE (standard / half / M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions, dim_half: int, theta: float):
    """positions (...,) -> angles (..., dim_half) in fp32."""
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(dim_half, dtype=F32) / dim_half
    )
    return positions.astype(F32)[..., None] * freqs


def _rotate(x, angles):
    """x (..., 2*Dh) split-half rotation with angles (..., Dh)."""
    d_half = angles.shape[-1]
    x1, x2 = x[..., :d_half], x[..., d_half:]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1f, x2f = x1.astype(F32), x2.astype(F32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x1f * sin + x2f * cos], axis=-1)
    return out.astype(x.dtype)


def apply_rope(cfg, x, positions):
    """x: (B, S, H, D). positions: (B, S) int32, or (3, B, S) for M-RoPE."""
    variant = cfg.rope_variant
    if variant == "none":
        return x
    D = x.shape[-1]
    if variant == "standard":
        ang = _rope_angles(positions, D // 2, cfg.rope_theta)  # (B,S,Dh)
        return _rotate(x, ang[:, :, None, :])
    if variant == "half":  # ChatGLM 2d-rope: rotate first half of head dim
        d_rot = D // 2
        ang = _rope_angles(positions, d_rot // 2, cfg.rope_theta)
        rotated = _rotate(x[..., :d_rot], ang[:, :, None, :])
        return jnp.concatenate([rotated, x[..., d_rot:]], axis=-1)
    if variant == "mrope":  # Qwen2-VL: 3 position streams over freq sections
        assert positions.ndim == 3, "mrope needs (3, B, S) positions"
        sections = cfg.mrope_sections
        assert sum(sections) == D // 2, (sections, D)
        angs = []
        off = 0
        for i, sec in enumerate(sections):
            freqs = jnp.exp(
                -math.log(cfg.rope_theta)
                * (jnp.arange(sec, dtype=F32) + off)
                / (D // 2)
            )
            angs.append(positions[i].astype(F32)[..., None] * freqs)
            off += sec
        ang = jnp.concatenate(angs, axis=-1)  # (B, S, D//2)
        return _rotate(x, ang[:, :, None, :])
    raise ValueError(f"unknown rope variant {variant}")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg, key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, ff ** -0.5
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "w_gate": jax.random.normal(k1, (d, ff), dtype) * std_in,
            "w_up": jax.random.normal(k2, (d, ff), dtype) * std_in,
            "w_down": jax.random.normal(k3, (ff, d), dtype) * std_out,
        }
    return {
        "w_up": jax.random.normal(k1, (d, ff), dtype) * std_in,
        "w_down": jax.random.normal(k2, (ff, d), dtype) * std_out,
    }


def _ar_barrier(y):
    """Perf lever "bf16_ar": anchor the tensor-parallel partial-sum in the
    model dtype. Without the barrier XLA hoists the downstream fp32 norm
    upcast ABOVE the SPMD-inserted all-reduce, doubling every per-layer
    activation all-reduce (observed on starcoder2 prefill: f32[2,32768,6144]
    ARs; EXPERIMENTS.md §Perf H2)."""
    if hint_opt("bf16_ar"):
        return jax.lax.optimization_barrier(y)
    return y


def linear(x, w, eq: str):
    """Matmul that dispatches on the weight leaf: a plain array runs the
    ORIGINAL einsum untouched (byte-identical numerics to the pre-quant
    path); a ``{"w_q": int8, "scale": fp32}`` dict (see
    ``model.quantize_weights``) runs weight-only int8 with fp32
    accumulation and applies the per-output-channel scale AFTER the dot —
    the ``kernels/int8_matmul.py`` contract (matmul-then-scale is exact
    for per-column scales since each output column touches one scale)."""
    if isinstance(w, dict):
        y = jnp.einsum(eq, x.astype(F32), w["w_q"].astype(F32))
        return (y * w["scale"]).astype(x.dtype)
    return jnp.einsum(eq, x, w)


def apply_mlp(cfg, p, x):
    if cfg.mlp_variant in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else jax.nn.gelu
        g = linear(x, p["w_gate"], "...d,df->...f")
        u = linear(x, p["w_up"], "...d,df->...f")
        h = act(g) * u
    else:
        h = jax.nn.gelu(linear(x, p["w_up"], "...d,df->...f"))
    return _ar_barrier(linear(h, p["w_down"], "...f,fd->...d"))


# ---------------------------------------------------------------------------
# Attention — dense reference path (small sequences)
# ---------------------------------------------------------------------------


def _expand_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv * n_rep, D) by repeat (GQA)."""
    if n_rep == 1:
        return k
    b, s, hkv, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, n_rep, d))
    return k.reshape(b, s, hkv * n_rep, d)


def dense_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0):
    """Plain masked attention. q (B,Sq,H,D), k/v (B,Skv,Hkv,D).

    ``q_offset``: absolute position of q[0] relative to k[0] (decode=Skv-1).
    ``window``: if >0, keys further than `window` behind the query are masked.
    """
    n_rep = q.shape[2] // k.shape[2]
    k, v = _expand_kv(k, n_rep), _expand_kv(v, n_rep)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=F32)
    scores = scores * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention — flat block-pair online-softmax scan (long sequences)
# ---------------------------------------------------------------------------


def _block_pairs(n_chunks: int, causal: bool, window_chunks: int):
    """Static (i, j) q/kv chunk-pair list, row-major so each q row's pairs
    are contiguous and ascending in j (required by the online softmax)."""
    pairs = []
    for i in range(n_chunks):
        lo = 0
        if window_chunks:
            lo = max(0, i - window_chunks)
        hi = i if causal or window_chunks else n_chunks - 1
        for j in range(lo, hi + 1):
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


def block_attention(q, k, v, *, causal: bool, window: int = 0,
                    chunk: int = 1024):
    """Memory-efficient attention over long sequences.

    Scans a static list of (q_chunk, kv_chunk) block pairs, maintaining
    online-softmax statistics per q row, writing each finished row into the
    carried output. Only mask-allowed blocks are enumerated, so compiled
    FLOPs ~= true masked FLOPs. Peak memory is O(chunk^2) per head.
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    n_rep = h // hkv
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    wc = 0
    if window:
        assert window % chunk == 0 or window < chunk, (window, chunk)
        wc = max(1, window // chunk)
    pairs = _block_pairs(n, causal, wc)
    scale = d ** -0.5

    qc = q.reshape(b, n, chunk, h, d)
    kc = k.reshape(b, n, chunk, hkv, d)
    vc = v.reshape(b, n, chunk, hkv, d)

    # Perf lever "attn_carry" (EXPERIMENTS.md §Perf): pin the sharding of
    # the scanned q/k/v blocks and of the carried output/statistics. Without
    # this GSPMD cannot propagate a consistent sharding through the
    # dynamic-update on the carry and falls back to involuntary full
    # rematerialization — an all-gather of the whole output every scan step.
    pin = hint_opt("attn_carry")
    if pin:
        h_ = hints()
        ba, ma = h_["batch_axes"], h_["model_axis"]
        bspec = ba if len(ba) > 1 else ba[0]
        qc = wsc(qc, bspec, None, None, None, ma)
        kc = wsc(kc, bspec, None, None, None, ma)
        vc = wsc(vc, bspec, None, None, None, ma)

        def pin_carry(carry):
            out, m, l, acc = carry
            out = wsc(out, bspec, None, None, None, ma)
            m = wsc(m, bspec, None, None)
            l = wsc(l, bspec, None, None)
            acc = wsc(acc, bspec, None, None, ma)
            return out, m, l, acc
    else:
        def pin_carry(carry):
            return carry

    def step(carry, pair):
        out, m, l, acc = pin_carry(carry)
        i, j = pair[0], pair[1]
        is_row_start = (pair[2] == 1)
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        kj, vj = _expand_kv(kj, n_rep), _expand_kv(vj, n_rep)

        m0 = jnp.where(is_row_start, jnp.full_like(m, -1e30), m)
        l0 = jnp.where(is_row_start, jnp.zeros_like(l), l)
        a0 = jnp.where(is_row_start, jnp.zeros_like(acc), acc)

        s_ij = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                          preferred_element_type=F32) * scale
        qpos = i * chunk + jnp.arange(chunk)[:, None]
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((chunk, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s_ij = jnp.where(mask[None, None], s_ij, -1e30)

        m_new = jnp.maximum(m0, s_ij.max(axis=-1))
        alpha = jnp.exp(m0 - m_new)
        p = jnp.exp(s_ij - m_new[..., None])
        l_new = l0 * alpha + p.sum(axis=-1)
        a_new = a0 * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=F32)

        row_out = (a_new / jnp.maximum(l_new, 1e-30)[..., None]).astype(q.dtype)
        is_row_end = (pair[3] == 1)
        out = jax.lax.cond(
            is_row_end,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, row_out.transpose(0, 2, 1, 3), i, 1),
            lambda o: o,
            out,
        )
        return pin_carry((out, m_new, l_new, a_new)), None

    # annotate row starts / ends statically
    starts = np.zeros(len(pairs), np.int32)
    ends = np.zeros(len(pairs), np.int32)
    for idx, (i, j) in enumerate(pairs):
        if idx == 0 or pairs[idx - 1][0] != i:
            starts[idx] = 1
        if idx == len(pairs) - 1 or pairs[idx + 1][0] != i:
            ends[idx] = 1
    xs = jnp.concatenate(
        [jnp.asarray(pairs), starts[:, None], ends[:, None]], axis=1)

    out0 = jnp.zeros((b, n, chunk, h, d), q.dtype)
    m0 = jnp.full((b, h, chunk), -1e30, F32)
    l0 = jnp.zeros((b, h, chunk), F32)
    acc0 = jnp.zeros((b, h, chunk, d), F32)
    (out, _, _, _), _ = uscan(step, pin_carry((out0, m0, l0, acc0)), xs)
    return out.reshape(b, s, h, d)


def attention(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0,
              chunk: int = 0, dense_threshold: int = 2048):
    """Dispatch: dense path for short sequences, block scan for long.
    chunk=0 uses the context default (bigger under the dry-run's unrolled
    count-mode to bound the enumerated block-pair count)."""
    if not chunk:
        chunk = attn_chunk_default()
    s = q.shape[1]
    if s <= dense_threshold or s % chunk or q_offset:
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    return block_attention(q, k, v, causal=causal, window=window, chunk=chunk)


# ---------------------------------------------------------------------------
# Attention — single-token decode against a (possibly rolling) KV cache
# ---------------------------------------------------------------------------


def paged_decode_attention(q, k_pool, v_pool, page_table, pos):
    """Decode attention through a paged KV cache.

    q (B,S,H,D); k/v_pool (P,page_size,Hkv,D) — the device-resident page
    pool shared by every slot; page_table (B,n_pages) int32 maps a slot's
    logical page i (tokens [i*ps, (i+1)*ps)) to a physical pool page;
    pos (B,) counts tokens written including the S queries.

    Gathers the slot's pages into a (B, n_pages*ps, Hkv, D) view and
    reuses the rolling-cache masked softmax (``decode_attention``), so the
    numerics are identical to a rolling window of width n_pages*ps —
    garbage in not-yet-written page slots is hidden by the same per-query
    validity mask. This is the jnp oracle twin of the block-sparse Pallas
    kernel in ``repro.kernels.decode_attention.paged_decode_attention``.
    """
    b = q.shape[0]
    _, ps, hkv, d = k_pool.shape
    n_pages = page_table.shape[1]
    k = jnp.take(k_pool, page_table, axis=0).reshape(b, n_pages * ps, hkv, d)
    v = jnp.take(v_pool, page_table, axis=0).reshape(b, n_pages * ps, hkv, d)
    return decode_attention(q, k, v, pos)


def paged_decode_attention_int8(q, k_pool, v_pool, k_scale, v_scale,
                                page_table, pos):
    """Quantized-pool twin of ``paged_decode_attention``: pools hold int8
    values and per-(token, kv-head) fp32 scales (P, ps, Hkv, 1) addressed
    by the SAME page ids. Gathers values and scales through the page
    table, dequantizes to the compute dtype and runs the identical masked
    softmax — the jnp oracle twin of the fused-dequant Pallas kernel in
    ``repro.kernels.decode_attention.paged_decode_attention_int8`` (both
    dequantize-then-attend, so their numerics agree up to dot-order).
    Trash-page garbage is hidden by the same per-query validity mask."""
    b = q.shape[0]
    _, ps, hkv, d = k_pool.shape
    n_pages = page_table.shape[1]

    def gather(pool, scale):
        vals = jnp.take(pool, page_table, axis=0)
        sc = jnp.take(scale, page_table, axis=0)
        deq = (vals.astype(F32) * sc).astype(q.dtype)
        return deq.reshape(b, n_pages * ps, hkv, d)

    return decode_attention(q, gather(k_pool, k_scale),
                            gather(v_pool, v_scale), pos)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """q (B,S,H,D); k/v_cache (B,W,Hkv,D); pos (B,) int32 = per-slot count
    of tokens already written INCLUDING all S queries. S=1 is the decode
    step; S>1 is a chunked-prefill chunk whose keys were just written at
    slots [pos-S, pos): query i attends cache slots < pos-S+1+i, which is
    causal within the chunk because chunk keys sit at their own positions.
    Valid cache slots cap at W (rolling buffers overwrite at pos % W, so
    all W slots are valid once pos >= W)."""
    b, w, hkv, d = k_cache.shape
    sq = q.shape[1]
    h = q.shape[2]
    g = h // hkv
    # grouped-GQA einsum: q reshaped to (B, S, Hkv, G, D) contracts the
    # shared kv heads directly — the KV cache is never materialized at
    # q-head multiplicity (a 6x HBM-traffic saving for 48q/8kv configs).
    qg = q.reshape(b, sq, hkv, g, d)
    # Perf lever "kv_seq" (flash-decoding style): the cache is sharded
    # along the sequence dim, so scores/probs inherit a seq-sharded layout
    # and softmax statistics reduce across shards — pin the intermediates
    # so GSPMD keeps everything length-parallel instead of replicating.
    pin_seq = hint_opt("kv_seq")
    k, v = k_cache, v_cache
    if pin_seq:
        h_ = hints()
        ba, ma = h_["batch_axes"], h_["model_axis"]
        bspec = ba if len(ba) > 1 else ba[0]
        k = wsc(k, bspec, ma, None, None)
        v = wsc(v, bspec, ma, None, None)
    scale = d ** -0.5
    scores = jnp.einsum("bqcgd,bwcd->bcgqw", qg, k,
                        preferred_element_type=F32) * scale
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    # per-query valid count: query i (of S) sees pos - S + 1 + i slots
    n_valid = jnp.minimum(
        pos[:, None] - (sq - 1) + jnp.arange(sq, dtype=jnp.int32)[None, :],
        w)  # (B, S)
    valid = (jnp.arange(w)[None, None, None, None, :]
             < n_valid[:, None, None, :, None])
    scores = jnp.where(valid, scores, -1e30)
    if pin_seq:
        scores = wsc(scores, bspec, None, None, None, ma)
    probs = jax.nn.softmax(scores, axis=-1)
    if pin_seq:
        probs = wsc(probs, bspec, None, None, None, ma)
    out = jnp.einsum("bcgqw,bwcd->bqcgd", probs.astype(q.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype).reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# Logit processor — per-slot stochastic decode (temperature / top-k / top-p)
# ---------------------------------------------------------------------------


def _float_bits_descending(x):
    """Order-isomorphic uint32 image of f32: bigger float <=> bigger
    unsigned int (sign bit flipped for positives, all bits inverted for
    negatives; +0.0 canonicalizes -0.0 first)."""
    bits = jax.lax.bitcast_convert_type(x + 0.0, jnp.uint32)
    return jnp.where(bits >> 31 == 0, bits | jnp.uint32(0x80000000), ~bits)


def _radix_threshold(weights, mapped, target):
    """Per row, the maximal representable value t (as a mapped uint32)
    with ``sum(weights where mapped >= t) >= target``: 32 rounds of
    MSB-first bit building over the float-bit image — an exact order
    statistic in O(32 V) vector work, no sort (XLA's CPU sort is ~15x
    slower and this is the decode hot path). ``weights`` of 1 recover
    "count >= k" (the k-th largest); softmax probs recover the nucleus
    boundary (smallest probability the top-p mass still needs)."""

    def body(b, t):
        cand = t | jax.lax.shift_left(jnp.uint32(1), jnp.uint32(31 - b))
        acc = jnp.sum(jnp.where(mapped >= cand[:, None], weights, 0.0),
                      axis=-1)
        return jnp.where(acc >= target, cand, t)

    t0 = jnp.zeros((weights.shape[0],), jnp.uint32)
    return jax.lax.fori_loop(0, 32, body, t0)


def _restricted_probs(x, top_k, top_p):
    """The shared restriction recipe, both cuts as thresholds over ONE
    LOGIT-bit image: the k-th largest logit by a count radix, then the
    nucleus boundary by a mass radix — the maximal logit value whose
    restricted tail still carries ``top_p`` of the restricted mass
    (entries outside the top-k carry zero weight, so candidates below
    the k-th threshold see no mass). Cutting in logit space matters:
    float32 softmax collapses near-tied logits to bit-equal
    probabilities, so a probability-space cut could not separate them.
    Returns (keep mask, softmax weights with 0 outside the mask — the
    restricted distribution up to one shared normalizer).
    ``process_logits`` and the ``sample_tokens`` hot path both call
    this, so their masks are identical by construction."""
    v = x.shape[1]
    b = x.shape[0]
    mapped = _float_bits_descending(x)
    no_thresh = jnp.zeros((b,), jnp.uint32)  # mapped >= 0: keeps all
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v).astype(F32)
    # a batch with no top-k (or no top-p) rows skips that 32-round radix
    # at runtime — temperature-only sampling pays for neither loop — while
    # staying inside the same trace (lax.cond, not a retrace)
    kth = jax.lax.cond(
        jnp.all(top_k <= 0), lambda _: no_thresh,
        lambda _: _radix_threshold(jnp.ones_like(x), mapped, k), None)
    keep = mapped >= kth[:, None]
    w = jnp.where(keep, jax.nn.softmax(x, axis=-1), 0.0)
    pth = jax.lax.cond(
        jnp.all(top_p >= 1.0), lambda _: no_thresh,
        lambda _: _radix_threshold(w, mapped, jnp.clip(top_p, 1e-30, 1.0)
                                   * jnp.sum(w, axis=-1)), None)
    keep &= (mapped >= pth[:, None]) | (top_p >= 1.0)[:, None]
    return keep, jnp.where(keep, w, 0.0)


def process_logits(logits, temperature, top_k, top_p):
    """Per-row logit processor: temperature scale, then top-k and top-p
    (nucleus) restriction. logits (B,V); temperature (B,) > 0; top_k (B,)
    int32 (0 = no top-k cut); top_p (B,) (>= 1 = no top-p cut). Every
    parameter is a traced per-row array, so one trace serves any mix of
    restrictions in the batch. Removed entries come back -inf; each row
    keeps at least its argmax (top-k clamps to >= 1, the nucleus boundary
    never exceeds the largest probability).

    Both cuts are value thresholds found by radix select over float bits
    (same algorithm as the fused Pallas op in ``kernels/topk_sample.py``;
    the sort-based oracle is ``kernels/ref.py``): entries tied with the
    k-th largest logit / the nucleus-boundary probability all survive,
    and the thresholds are exact bit patterns — no epsilon, so every
    engine configuration computes the identical mask."""
    x = logits.astype(F32) / jnp.maximum(temperature, 1e-6)[:, None]
    keep, _ = _restricted_probs(x, top_k, top_p)
    return jnp.where(keep, x, -jnp.inf)


def sample_tokens(logits, samp, pos):
    """Engine-facing masked composition: greedy rows take pure argmax,
    stochastic rows draw one token from the temperature-scaled,
    top-k/top-p-restricted softmax — ONE trace for any greedy/stochastic
    mix (every parameter is a per-slot traced array). Semantics twin of
    ``process_logits`` + a categorical draw (and of the fused Pallas op
    ``repro.kernels.ops.topk_sample``), but built for the decode hot
    path: the kept set is computed by the exact ``process_logits``
    recipe (top-k radix over LOGIT bits — a prob-space cut would merge
    near-tied logits that float32 softmax collapses to bit-equal
    probabilities — then the nucleus radix over the renormalized
    restricted probabilities), and the draw is inverse-CDF — ONE uniform
    per row against the cumulative masked distribution, instead of a
    vocab-wide Gumbel field (the per-slot threefry work was the single
    biggest cost of the stochastic tick).

    logits (B,V); pos (B,) absolute position of the token being drawn;
    ``samp`` leaves (all (B,...)): greedy bool, temperature f32, top_k
    i32, top_p f32, key uint32 (B,2) per-slot PRNG key material. The
    uniform is keyed by ``fold_in(key, pos)`` — a pure function of (seed,
    position), never of slot index, batch composition, or tick count —
    which is what makes seeded streams bit-reproducible across restarts,
    slot assignments, and cluster replicas. An all-greedy batch skips the
    whole branch at runtime (lax.cond), so deterministic serving pays
    nothing per tick."""
    last = logits.astype(F32)
    greedy_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)

    def draw(_):
        x = last / jnp.maximum(samp["temperature"], 1e-6)[:, None]
        _, pk = _restricted_probs(x, samp["top_k"], samp["top_p"])

        def row_u(key, pp):
            return jax.random.uniform(jax.random.fold_in(key, pp), (), F32)

        u = jax.vmap(row_u)(samp["key"], pos.astype(jnp.int32))
        c = jnp.cumsum(pk, axis=-1)
        total = c[:, -1]
        # u * total can round UP to total (leaving no CDF entry strictly
        # above the threshold -> argmax of all-False would emit token 0);
        # cap at the largest float below total — bias bounded by one ulp,
        # not a truncated tail of the distribution
        thresh = jnp.minimum(u * total, jnp.nextafter(total, 0.0))
        stoch = jnp.argmax(c > thresh[:, None], axis=-1).astype(jnp.int32)
        return jnp.where(samp["greedy"], greedy_tok, stoch)

    return jax.lax.cond(jnp.all(samp["greedy"]),
                        lambda _: greedy_tok, draw, None)
