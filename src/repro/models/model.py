"""Unified model: block program -> scan-over-layers forward/decode.

The layer stack is expressed as a *block program*: a repeating ``pattern``
of block types scanned ``n_repeat`` times (stacked weights, O(1) HLO in
depth) plus an unrolled ``tail`` when ``num_layers`` is not a multiple of
the pattern length (e.g. recurrentgemma's 38 = 12*(r,r,a) + (r,r)).

Public API:
  block_program(cfg)                   -> (pattern, n_repeat, tail)
  init_params(cfg, key)                -> params pytree (real arrays)
  param_specs(cfg)                     -> ShapeDtypeStruct pytree (dry-run)
  forward(cfg, params, batch, mode)    -> (logits, aux, cache_or_None)
  init_cache(cfg, batch, window)       -> decode cache pytree
  cache_specs(cfg, batch, window)      -> ShapeDtypeStruct cache (dry-run)
  decode_step(cfg, params, cache, batch) -> (logits, new_cache)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.blocks import (
    PAGED_BLOCKS,
    apply_block,
    init_block,
    init_block_cache,
    init_paged_block_cache,
)
from repro.util import scan as uscan

F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# block program
# ---------------------------------------------------------------------------


def block_program(cfg) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    if cfg.arch_type in ("dense", "vlm"):
        pattern = ("dense",)
    elif cfg.arch_type == "audio":
        pattern = ("encoder",)
    elif cfg.arch_type == "moe":
        k = cfg.moe_layer_period
        pattern = ("dense",) * (k - 1) + ("moe",)
    elif cfg.arch_type == "ssm":
        pattern = ("ssd",)
    elif cfg.arch_type == "hybrid":
        pattern = cfg.block_pattern or ("rglru", "rglru", "local_attn")
    else:
        raise ValueError(cfg.arch_type)
    n_repeat = cfg.num_layers // len(pattern)
    tail = cfg.block_pattern[: cfg.num_layers % len(pattern)] if cfg.num_layers % len(pattern) else ()
    if cfg.num_layers % len(pattern):
        tail = pattern[: cfg.num_layers % len(pattern)]
    return pattern, n_repeat, tail


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg, key):
    dtype = _dtype(cfg)
    pattern, n_repeat, tail = block_program(cfg)
    keys = jax.random.split(key, 4)

    def stacked_block(btype, k):
        ks = jax.random.split(k, n_repeat)
        return jax.vmap(lambda kk: init_block(cfg, btype, kk, dtype))(ks)

    body_keys = jax.random.split(keys[0], len(pattern))
    body = [stacked_block(bt, bk) for bt, bk in zip(pattern, body_keys)]
    tail_keys = jax.random.split(keys[1], max(len(tail), 1))
    tail_p = [init_block(cfg, bt, tk, dtype) for bt, tk in zip(tail, tail_keys)]

    d, v = cfg.d_model, cfg.vocab_size
    params = {
        "body": body,
        "tail": tail_p,
        "final_norm": L.init_norm(cfg, d, dtype),
    }
    if cfg.modality != "audio":  # audio: stubbed frontend, no token embed
        params["embed"] = jax.random.normal(keys[2], (v, d), dtype) * (d ** -0.5)
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[3], (d, v), dtype) * (d ** -0.5)
    return params


def param_specs(cfg):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def param_count_tree(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, window: int, kv_dtype: str = ""):
    """Decode cache: per-block state + per-slot position. ``kv_dtype``
    "int8" enables the quantized serving cache (values + per-vector
    scales; EXPERIMENTS.md §Perf H1 it.3)."""
    dtype = _dtype(cfg)
    pattern, n_repeat, tail = block_program(cfg)

    def stacked_cache(btype):
        c = init_block_cache(cfg, btype, batch, window, dtype, kv_dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_repeat,) + x.shape), c)

    return {
        "body": [stacked_cache(bt) for bt in pattern],
        "tail": [init_block_cache(cfg, bt, batch, window, dtype, kv_dtype)
                 for bt in tail],
        "pos": jnp.zeros((batch,), jnp.int32),  # per-slot decode position
    }


def cache_specs(cfg, batch: int, window: int, kv_dtype: str = ""):
    return jax.eval_shape(lambda: init_cache(cfg, batch, window, kv_dtype))


def paged_ok(cfg) -> bool:
    """True when every block can serve from a paged KV cache."""
    pattern, _, tail = block_program(cfg)
    return all(bt in PAGED_BLOCKS for bt in pattern + tail)


def init_paged_cache(cfg, batch: int, n_pages: int, page_size: int,
                     max_pages_per_slot: int, kv_dtype: str = ""):
    """Paged decode cache: one page POOL per attention block (shared by all
    slots, stacked over ``n_repeat`` for the scanned body) + one page-table
    row and position per slot. Table entries start at 0 — the reserved
    trash page — so uninitialized slots can never write into a live page.
    ``kv_dtype`` "int8" quantizes the pools (int8 values + fp32 scale
    pages addressed by the same page ids).
    """
    assert paged_ok(cfg), f"{cfg.name}: arch has non-pageable blocks"
    dtype = _dtype(cfg)
    pattern, n_repeat, tail = block_program(cfg)

    def stacked_pool(btype):
        c = init_paged_block_cache(cfg, btype, n_pages, page_size, dtype,
                                   kv_dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_repeat,) + x.shape), c)

    return {
        "body": [stacked_pool(bt) for bt in pattern],
        "tail": [init_paged_block_cache(cfg, bt, n_pages, page_size, dtype,
                                        kv_dtype)
                 for bt in tail],
        "pos": jnp.zeros((batch,), jnp.int32),
        "page_table": jnp.zeros((batch, max_pages_per_slot), jnp.int32),
    }


# ---------------------------------------------------------------------------
# weight-only int8 quantization
# ---------------------------------------------------------------------------

#: attention/MLP matmul weights eligible for weight-only int8. Embeddings,
#: lm_head and norms stay in the model dtype (quality-critical, tiny).
QUANT_WEIGHT_KEYS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def quantize_weights(cfg, params):
    """Weight-only int8: replace each attention/MLP matmul weight with a
    ``{"w_q": int8, "scale": fp32}`` leaf pair — symmetric per-OUTPUT-
    channel scales (``kernels/int8_matmul.py`` semantics: int8 values,
    fp32 accumulation, scale applied per output column after the dot).
    ``layers.linear`` dispatches on the dict. The scale reduction is over
    the contraction dim (axis=-2, keepdims), so stacked body weights
    (leading layer axis) quantize layer-by-layer and still slice
    correctly under the scan."""

    def _q_leaf(w):
        a = jnp.max(jnp.abs(w.astype(F32)), axis=-2, keepdims=True)
        scale = jnp.maximum(a / 127.0, 1e-12)
        q8 = jnp.clip(jnp.round(w.astype(F32) / scale), -127, 127)
        return {"w_q": q8.astype(jnp.int8), "scale": scale}

    def _q_block(p):
        p = dict(p)
        for sub in ("attn", "mlp"):
            if sub in p:
                p[sub] = {k: (_q_leaf(v) if k in QUANT_WEIGHT_KEYS else v)
                          for k, v in p[sub].items()}
        return p

    out = dict(params)
    out["body"] = [_q_block(b) for b in params["body"]]
    out["tail"] = [_q_block(b) for b in params["tail"]]
    return out


# ---------------------------------------------------------------------------
# embedding / frontends
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, batch):
    """Returns (x, rope_pos). Stubbed modality frontends (see DESIGN.md):
    audio gets precomputed frame embeddings; VLM gets patch embeddings
    fused (early fusion) ahead of text token embeddings."""
    if cfg.modality == "audio":
        x = batch["frames"].astype(_dtype(cfg))
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        return x, pos
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.modality == "vision_text" and "patches" in batch:
        patches = batch["patches"].astype(_dtype(cfg))
        x = jnp.concatenate([patches, x], axis=1)  # early fusion prefix
    b, s = x.shape[:2]
    if cfg.rope_variant == "mrope":
        pos = batch["positions"]  # (3, B, S) from the (stubbed) frontend
    else:
        if "pos" in batch:  # decode: per-slot absolute start positions (B,)
            p = jnp.broadcast_to(jnp.asarray(batch["pos"], jnp.int32), (b,))
            pos = p[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        else:
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, pos


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(cfg, params, batch, *, mode: str = "train",
            cache: Optional[dict] = None, remat: bool = None):
    """Full-sequence forward. mode: "train" | "prefill".

    If ``cache`` is given (prefill), it is filled and returned; otherwise
    cache out is None. Returns (logits, aux_loss, cache_out).
    """
    pattern, n_repeat, tail = block_program(cfg)
    if remat is None:
        remat = mode == "train"
    x, rope_pos = _embed_inputs(cfg, params, batch)
    pos0 = jnp.zeros((), jnp.int32)

    def blockset(x, p_slices, c_slices):
        aux_sum = jnp.zeros((), F32)
        new_cs = []
        for bt, p, c in zip(pattern, p_slices, c_slices):
            x, c_new, aux = apply_block(
                cfg, bt, p, x, rope_pos, mode=mode,
                cache=c, pos=pos0)
            new_cs.append(c_new if c_new is not None else c)
            aux_sum = aux_sum + aux
        return x, new_cs, aux_sum

    if remat:
        blockset = jax.checkpoint(
            blockset, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(carry, slices):
        x, aux_acc = carry
        p_slices, c_slices = slices
        x, new_cs, aux = blockset(x, p_slices, c_slices)
        return (x, aux_acc + aux), new_cs

    if cache is None:
        (x, aux), _ = uscan(
            lambda c, p: (scan_body(c, (p, [None] * len(pattern)))[0], None),
            (x, jnp.zeros((), F32)), params["body"])
        new_body = None
    else:
        (x, aux), new_body = uscan(
            scan_body, (x, jnp.zeros((), F32)),
            (params["body"], cache["body"]))

    new_tail = []
    for bt, p, c in zip(tail, params["tail"],
                        (cache["tail"] if cache is not None else [None] * len(tail))):
        x, c_new, aux_t = apply_block(cfg, bt, p, x, rope_pos, mode=mode,
                                      cache=c, pos=pos0)
        new_tail.append(c_new)
        aux = aux + aux_t

    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=F32)

    cache_out = None
    if cache is not None:
        b = x.shape[0]
        cache_out = {"body": new_body, "tail": new_tail,
                     "pos": jnp.full((b,), x.shape[1], jnp.int32)}
    return logits, aux, cache_out


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def decode_step(cfg, params, cache, batch):
    """Incremental decode against the cache. batch: {"tokens": (B,S)}
    (+ positions for mrope). S=1 is the classic one-token decode step;
    S>1 is a chunked-prefill chunk (attention-block archs only: recurrent
    mixers carry single-step state). Returns (logits (B,S,V), new_cache)
    with pos advanced by S."""
    pattern, n_repeat, tail = block_program(cfg)
    pos = cache["pos"]
    pages = cache.get("page_table")  # paged serving cache (shared pools)
    batch = dict(batch)
    batch.setdefault("pos", pos)
    x, rope_pos = _embed_inputs(cfg, params, batch)

    def scan_body(carry, slices):
        x, aux_acc = carry
        p_slices, c_slices = slices
        new_cs = []
        for bt, p, c in zip(pattern, p_slices, c_slices):
            x, c_new, aux = apply_block(cfg, bt, p, x, rope_pos,
                                        mode="decode", cache=c, pos=pos,
                                        pages=pages)
            new_cs.append(c_new)
            aux_acc = aux_acc + aux
        return (x, aux_acc), new_cs

    (x, _), new_body = uscan(
        scan_body, (x, jnp.zeros((), F32)),
        (params["body"], cache["body"]))

    new_tail = []
    for bt, p, c in zip(tail, params["tail"], cache["tail"]):
        x, c_new, _ = apply_block(cfg, bt, p, x, rope_pos, mode="decode",
                                  cache=c, pos=pos, pages=pages)
        new_tail.append(c_new)

    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=F32)
    new_cache = {"body": new_body, "tail": new_tail, "pos": pos + x.shape[1]}
    if pages is not None:
        new_cache["page_table"] = pages
    return logits, new_cache
