from repro.models.layers import process_logits, sample_tokens
from repro.models.model import (
    block_program,
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    init_params,
    paged_ok,
    param_count_tree,
    param_specs,
    quantize_weights,
)

__all__ = [
    "block_program",
    "cache_specs",
    "decode_step",
    "forward",
    "init_cache",
    "init_paged_cache",
    "init_params",
    "paged_ok",
    "param_count_tree",
    "param_specs",
    "process_logits",
    "quantize_weights",
    "sample_tokens",
]
