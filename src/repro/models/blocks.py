"""Transformer/SSM/recurrent blocks with a unified apply interface.

Block types:
  dense      — GQA attention + MLP (pre-norm residual)
  moe        — GQA attention + MoE MLP
  encoder    — bidirectional attention + MLP (audio encoder)
  local_attn — sliding-window attention + MLP (recurrentgemma)
  rglru      — RG-LRU temporal mixing + MLP
  ssd        — Mamba-2 SSD mixing (no separate MLP)

``apply_block(cfg, btype, p, x, rope_pos, mode, cache)`` returns
``(x, new_cache, aux_loss)``. Caches are dict pytrees; ``None`` cache means
train/prefill-from-scratch. Position bookkeeping (`pos` scalar) lives in the
model-level cache, passed down here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import apply_rglru_block, init_rglru, init_rglru_cache
from repro.models.ssm import apply_ssd, init_ssd, init_ssd_cache

F32 = jnp.float32

# Block types whose decode cache is a KV ring buffer (vs recurrent state).
# The serving engine keys bucketed/chunked prefill eligibility off this.
KV_CACHE_BLOCKS = ("dense", "moe", "encoder", "local_attn")

# Block types servable from a paged KV cache. local_attn is excluded: its
# ring IS the sliding window (slot index != absolute position), while pages
# address tokens by absolute position; recurrent mixers have no KV at all.
PAGED_BLOCKS = ("dense", "moe")


# ---------------------------------------------------------------------------
# KV quantization (int8 values + per-vector fp32 scales)
# ---------------------------------------------------------------------------


def quantize_kv(t, group: int = 0):
    """Symmetric int8 quantization of a (..., S, kv, hd) K/V tensor: one
    fp32 scale per (token, kv-head) vector, shaped (..., S, kv, 1) so
    scale leaves ride the same rank-4 tree transforms (page scatter /
    gather) as the value leaves. ``group`` > 0 coarsens to one scale per
    ``group`` consecutive tokens (the "page" scale granularity — every
    token of a page shares one dequant multiplier) when the token axis
    divides evenly; otherwise falls back to per-token scales, which only
    tightens the error bound."""
    a = jnp.max(jnp.abs(t.astype(F32)), axis=-1, keepdims=True)
    s = t.shape[-3]
    if group and group > 1 and s % group == 0:
        shp = a.shape
        g = a.reshape(shp[:-3] + (s // group, group) + shp[-2:])
        g = jnp.max(g, axis=-3, keepdims=True)
        a = jnp.broadcast_to(
            g, shp[:-3] + (s // group, group) + shp[-2:]).reshape(shp)
    scale = jnp.maximum(a / 127.0, 1e-8)
    q8 = jnp.clip(jnp.round(t.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q8, scale


def dequantize_kv(q8, scale, dtype):
    return (q8.astype(F32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_attn(cfg, key, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q_dim, kv_dim = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, q_dim), dtype) * std,
        "wk": jax.random.normal(ks[1], (d, kv_dim), dtype) * std,
        "wv": jax.random.normal(ks[2], (d, kv_dim), dtype) * std,
        "wo": jax.random.normal(ks[3], (q_dim, d), dtype) * (q_dim ** -0.5),
    }


def init_block(cfg, btype: str, key, dtype):
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": L.init_norm(cfg, d, dtype)}
    if btype in ("dense", "encoder", "local_attn"):
        p["attn"] = init_attn(cfg, k1, dtype)
        p["norm2"] = L.init_norm(cfg, d, dtype)
        ff = cfg.dense_d_ff or cfg.d_ff
        p["mlp"] = L.init_mlp(cfg, k2, d, ff, dtype)
    elif btype == "moe":
        p["attn"] = init_attn(cfg, k1, dtype)
        p["norm2"] = L.init_norm(cfg, d, dtype)
        p["moe"] = init_moe(cfg, k2, dtype)
    elif btype == "rglru":
        p["mixer"] = init_rglru(cfg, k1, dtype)
        p["norm2"] = L.init_norm(cfg, d, dtype)
        p["mlp"] = L.init_mlp(cfg, k2, d, cfg.d_ff, dtype)
    elif btype == "ssd":
        p["mixer"] = init_ssd(cfg, k1, dtype)
    else:
        raise ValueError(btype)
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def attn_cache_window(cfg, btype: str, seq_len: int) -> int:
    """KV window for decode: local blocks use their native window; full
    attention uses the full seq unless the model-level sliding window is
    engaged (long_500k)."""
    if btype == "local_attn":
        return min(cfg.local_window, seq_len)
    return seq_len


def init_block_cache(cfg, btype: str, batch: int, window: int, dtype,
                     kv_dtype: str = ""):
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    if btype in KV_CACHE_BLOCKS:
        w = min(window, cfg.local_window) if btype == "local_attn" else window
        if kv_dtype == "int8":
            # quantized serving cache: per-(token, kv-head) symmetric scale;
            # the trailing singleton keeps scale leaves rank-4 so every
            # page scatter/gather treats them exactly like value leaves
            return {
                "k": jnp.zeros((batch, w, kv, hd), jnp.int8),
                "v": jnp.zeros((batch, w, kv, hd), jnp.int8),
                "k_scale": jnp.zeros((batch, w, kv, 1), jnp.float32),
                "v_scale": jnp.zeros((batch, w, kv, 1), jnp.float32),
            }
        return {
            "k": jnp.zeros((batch, w, kv, hd), dtype),
            "v": jnp.zeros((batch, w, kv, hd), dtype),
        }
    if btype == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    if btype == "ssd":
        return init_ssd_cache(cfg, batch, dtype)
    raise ValueError(btype)


# ---------------------------------------------------------------------------
# attention block body
# ---------------------------------------------------------------------------


def init_paged_block_cache(cfg, btype: str, n_pages: int, page_size: int,
                           dtype, kv_dtype: str = ""):
    """Paged serving cache for one attention block: a page POOL shared by
    every decode slot (no batch axis — slots own disjoint page sets via the
    model-level page table). Only KV blocks are pageable; recurrent mixers
    keep their per-slot state and the engine falls back to rolling windows
    for archs that contain them. ``kv_dtype`` "int8" stores int8 values
    plus per-vector fp32 scale pages addressed by the SAME page ids (the
    host-side allocator and page tables are unchanged)."""
    if btype not in KV_CACHE_BLOCKS:
        raise ValueError(f"{btype} blocks have no pageable KV cache")
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    if kv_dtype == "int8":
        return {
            "k": jnp.zeros((n_pages, page_size, kv, hd), jnp.int8),
            "v": jnp.zeros((n_pages, page_size, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((n_pages, page_size, kv, 1), jnp.float32),
            "v_scale": jnp.zeros((n_pages, page_size, kv, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((n_pages, page_size, kv, hd), dtype),
        "v": jnp.zeros((n_pages, page_size, kv, hd), dtype),
    }


def _paged_attn_decode(cfg, q, k, v, cache, pages, pos):
    """Write the chunk's K/V through the page table and attend.

    ``cache`` holds the shared pools (P, ps, kv, hd); ``pages`` is the
    (B, n_pages) page table; token t of slot b lands in physical page
    ``pages[b, t // ps]`` at offset ``t % ps``. The allocator guarantees
    live slots own disjoint pages, so the batched scatter has no
    cross-slot collisions (freed/inactive slots all alias the reserved
    trash page 0, whose contents are never attended with weight)."""
    b, s = q.shape[:2]
    ps = cache["k"].shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    t = pos_b[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # (B, S)
    phys = jnp.take_along_axis(pages, t // ps, axis=1)  # (B, S)
    off = t % ps
    if cache["k"].dtype == jnp.int8:
        # quantized pools: scatter int8 values AND their per-token scales
        # at the same (page, offset) addresses — decode-time appends are
        # always per-token regardless of the prefill scale granularity
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": cache["k"].at[phys, off].set(kq),
            "v": cache["v"].at[phys, off].set(vq),
            "k_scale": cache["k_scale"].at[phys, off].set(ks),
            "v_scale": cache["v_scale"].at[phys, off].set(vs),
        }
        out = L.paged_decode_attention_int8(
            q, new_cache["k"], new_cache["v"], new_cache["k_scale"],
            new_cache["v_scale"], pages, pos_b + s)
        return out, new_cache
    kc = cache["k"].at[phys, off].set(k.astype(cache["k"].dtype))
    vc = cache["v"].at[phys, off].set(v.astype(cache["v"].dtype))
    out = L.paged_decode_attention(q, kc, vc, pages, pos_b + s)
    return out, {"k": kc, "v": vc}


def _attn_apply(cfg, p, x, rope_pos, *, mode: str, cache, pos, window: int,
                causal: bool, project: bool = True, pages=None):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    q = L.linear(x, p["wq"], "bsd,de->bse").reshape(b, s, h, hd)
    k = L.linear(x, p["wk"], "bsd,de->bse").reshape(b, s, kv, hd)
    v = L.linear(x, p["wv"], "bsd,de->bse").reshape(b, s, kv, hd)
    q = L.apply_rope(cfg, q, rope_pos)
    k = L.apply_rope(cfg, k, rope_pos)

    quantized = cache is not None and cache["k"].dtype == jnp.int8

    new_cache = cache
    if mode == "decode" and pages is not None:
        out, new_cache = _paged_attn_decode(cfg, q, k, v, cache, pages, pos)
    elif mode == "decode":
        # s == 1: one decode step. s > 1: one chunked-prefill chunk — the
        # chunk's keys are written at their rolling slots and the per-query
        # validity mask in decode_attention makes attention causal within
        # the chunk (chunk i must satisfy pos + s <= W; the engine
        # guarantees this by falling back to single-shot prefill).
        assert cache is not None
        w = cache["k"].shape[1]
        pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        slots = jax.lax.rem(
            pos_b[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :], w)
        rows = jnp.arange(b)[:, None]
        if quantized:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            new_cache = {
                "k": cache["k"].at[rows, slots].set(kq),
                "v": cache["v"].at[rows, slots].set(vq),
                "k_scale": cache["k_scale"].at[rows, slots].set(ks),
                "v_scale": cache["v_scale"].at[rows, slots].set(vs),
            }
            kc = dequantize_kv(new_cache["k"], new_cache["k_scale"], k.dtype)
            vc = dequantize_kv(new_cache["v"], new_cache["v_scale"], v.dtype)
        else:
            kc = cache["k"].at[rows, slots].set(k)
            vc = cache["v"].at[rows, slots].set(v)
            new_cache = {"k": kc, "v": vc}
        out = L.decode_attention(q, kc, vc, pos_b + s, window=window)
    else:
        out = L.attention(q, k, v, causal=causal, window=window)
        if cache is not None:  # prefill: fill the cache with the last W keys
            w = cache["k"].shape[1]
            k_w, v_w = (k[:, -w:], v[:, -w:]) if s >= w else (k, v)
            if quantized:
                from repro.util import hint_val

                # single-shot prefill is the one write whose token
                # positions are guaranteed page-aligned from 0, so the
                # "page" scale granularity groups here (hint_val is 0 =
                # per-token otherwise); a truncated window (s > w) starts
                # mid-page and keeps per-token scales, which only
                # tightens the error bound
                group = hint_val("kv_scale_page") if s <= w else 0
                kq, ks = quantize_kv(k_w, group=group)
                vq, vs = quantize_kv(v_w, group=group)
                writes = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                writes = {"k": k_w, "v": v_w}
            if s >= w:
                new_cache = writes
            else:
                new_cache = {
                    name: jax.lax.dynamic_update_slice_in_dim(
                        cache[name], val, 0, 1)
                    for name, val in writes.items()
                }
    out = out.reshape(b, s, h * hd)
    if not project:
        return out, new_cache
    return L._ar_barrier(L.linear(out, p["wo"], "bse,ed->bsd")), new_cache


def apply_block(cfg, btype: str, p, x, rope_pos, *, mode: str, cache=None,
                pos=None, pages=None):
    """Returns (x, new_cache, aux_loss). ``pages`` (B, n_pages) switches
    attention blocks to the paged KV cache (decode mode only)."""
    from repro.util import hint_opt

    aux = jnp.zeros((), F32)
    if btype in KV_CACHE_BLOCKS:
        causal = cfg.causal and btype != "encoder"
        window = cfg.local_window if btype == "local_attn" else 0
        if (hint_opt("parallel_block") and btype != "moe"
                and not isinstance(p["attn"]["wo"], dict)):
            # (int8 weight leaves are {"w_q", "scale"} dicts — the fused
            # wo/w_down concat below needs plain matrices, so quantized
            # weights take the unfused path)
            # PaLM-style parallel attention+MLP with FUSED output
            # projection: concat the attention context and the MLP hidden
            # along the (model-sharded) contraction dim and project with
            # one dot — one partial sum, hence ONE tensor-parallel
            # all-reduce per layer instead of two. (Perf lever; a serving
            # variant for models trained with parallel blocks.)
            h = L.apply_norm(cfg, p["norm1"], x)
            a_ctx, new_attn_cache = _attn_apply(
                cfg, p["attn"], h, rope_pos, mode=mode, cache=cache,
                pos=pos, window=window, causal=causal, project=False,
                pages=pages)
            h2 = L.apply_norm(cfg, p["norm2"], x)
            if cfg.mlp_variant in ("swiglu", "geglu"):
                act = jax.nn.silu if cfg.mlp_variant == "swiglu" else jax.nn.gelu
                hid = act(jnp.einsum("...d,df->...f", h2, p["mlp"]["w_gate"])) \
                    * jnp.einsum("...d,df->...f", h2, p["mlp"]["w_up"])
            else:
                hid = jax.nn.gelu(
                    jnp.einsum("...d,df->...f", h2, p["mlp"]["w_up"]))
            z = jnp.concatenate([a_ctx, hid], axis=-1)
            w_cat = jnp.concatenate([p["attn"]["wo"], p["mlp"]["w_down"]],
                                    axis=0)
            out = jnp.einsum("bsz,zd->bsd", z, w_cat)
            return x + out, new_attn_cache, aux
        h = L.apply_norm(cfg, p["norm1"], x)
        a, new_attn_cache = _attn_apply(
            cfg, p["attn"], h, rope_pos, mode=mode, cache=cache, pos=pos,
            window=window, causal=causal, pages=pages)
        x = x + a
        h = L.apply_norm(cfg, p["norm2"], x)
        if btype == "moe":
            m, aux = apply_moe(cfg, p["moe"], h)
        else:
            m = L.apply_mlp(cfg, p["mlp"], h)
        x = x + m
        return x, new_attn_cache, aux
    if btype == "rglru":
        h = L.apply_norm(cfg, p["norm1"], x)
        m, new_cache = apply_rglru_block(cfg, p["mixer"], h, cache=cache)
        x = x + m
        h = L.apply_norm(cfg, p["norm2"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h)
        return x, new_cache, aux
    if btype == "ssd":
        h = L.apply_norm(cfg, p["norm1"], x)
        m, new_cache = apply_ssd(cfg, p["mixer"], h, cache=cache)
        return x + m, new_cache, aux
    raise ValueError(btype)
