"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

y = out_proj( GeLU(gate_branch(x)) * RGLRU(conv1d(lin_branch(x))) )

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a u_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x u_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Full-sequence path uses ``jax.lax.associative_scan`` (log-depth — the right
shape for 32k/500k sequences on TPU); decode is a single recurrence step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
_C = 8.0


def init_rglru(cfg, key, dtype):
    d, lw = cfg.d_model, cfg.resolved_lru_width
    ck = cfg.conv_kernel
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    # Lambda init so that a ~ uniform(0.9, 0.999) at r=1 (paper init)
    u = jax.random.uniform(ks[5], (lw,), F32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # inverse softplus
    return {
        "w_gate_branch": jax.random.normal(ks[0], (d, lw), dtype) * std,
        "w_lin_branch": jax.random.normal(ks[1], (d, lw), dtype) * std,
        "w_out": jax.random.normal(ks[2], (lw, d), dtype) * (lw ** -0.5),
        "conv_w": jax.random.normal(ks[3], (ck, lw), dtype) * 0.2,
        "w_a": jax.random.normal(ks[4], (lw, lw), dtype) * (lw ** -0.5),
        "b_a": jnp.zeros((lw,), F32),
        "w_x": jax.random.normal(ks[6], (lw, lw), dtype) * (lw ** -0.5),
        "b_x": jnp.zeros((lw,), F32),
        "Lambda": lam,
    }


def _rglru_gates(p, u):
    r = jax.nn.sigmoid(
        jnp.einsum("bsl,lm->bsm", u, p["w_a"]).astype(F32) + p["b_a"])
    i = jax.nn.sigmoid(
        jnp.einsum("bsl,lm->bsm", u, p["w_x"]).astype(F32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["Lambda"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * u.astype(F32))
    return a, gated_in


def rglru_scan(p, u, h0=None):
    """u (B,S,L) -> (y (B,S,L), h_last (B,L)). Associative scan over S."""
    a, x = _rglru_gates(p, u)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + x_1
        x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    a_c, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_step(p, u, h_prev):
    """Single decode step. u (B,1,L), h_prev (B,L) -> (y (B,1,L), h)."""
    a, x = _rglru_gates(p, u)
    h = a[:, 0] * h_prev + x[:, 0]
    return h[:, None].astype(u.dtype), h


def apply_rglru_block(cfg, p, x, *, cache=None):
    """Temporal-mixing block. x (B,S,d) -> (y, new_cache).

    cache: {"conv": (B,K-1,L), "state": (B,L)} or None (train/prefill start).
    """
    from repro.models.ssm import _causal_conv

    b, s, _ = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,dl->bsl", x, p["w_gate_branch"]))
    u = jnp.einsum("bsd,dl->bsl", x, p["w_lin_branch"])
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"], conv_state, activation=None)
    if s == 1 and cache is not None:
        y, h = rglru_step(p, u, cache["state"])
    else:
        h0 = cache["state"] if cache is not None else None
        y, h = rglru_scan(p, u, h0)
    y = y * gate
    out = jnp.einsum("bsl,ld->bsd", y, p["w_out"])
    return out, {"conv": new_conv, "state": h}


def init_rglru_cache(cfg, batch: int, dtype):
    lw, k = cfg.resolved_lru_width, cfg.conv_kernel
    return {
        "conv": jnp.zeros((batch, k - 1, lw), dtype),
        "state": jnp.zeros((batch, lw), F32),
    }
