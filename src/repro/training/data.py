"""Synthetic data pipeline: deterministic, seekable, infinite.

Produces batches for every modality the assigned archs need. Sequences are
Zipf-distributed token streams with local n-gram structure (so the LM loss
actually decreases — used by examples/train_lm.py) rather than uniform
noise. The pipeline is host-side numpy (per-host sharding in the launcher
maps batches onto the data axis).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class TokenPipeline:
    """Markov-ish synthetic token stream with learnable structure."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, order: int = 2):
        self.v = vocab_size
        self.s = seq_len
        self.b = batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        # sparse transition table: each context maps to a few likely tokens
        self._ctx_next = rng.integers(0, vocab_size, size=(4096, 4))

    def _gen_row(self, rng) -> np.ndarray:
        out = np.empty(self.s + 1, np.int64)
        out[0] = rng.integers(0, self.v)
        for t in range(1, self.s + 1):
            ctx = int(out[t - 1]) % 4096
            if rng.random() < 0.8:  # predictable branch
                out[t] = self._ctx_next[ctx][rng.integers(0, 4)]
            else:
                out[t] = min(int(rng.zipf(1.3)), self.v - 1)
        return out

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            rng = np.random.default_rng((self.seed, step))
            toks = np.stack([self._gen_row(rng) for _ in range(self.b)])
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, :-1].astype(np.int32),
            }
            step += 1


def synthetic_batch(cfg, shape, rng=None) -> Dict[str, np.ndarray]:
    """One random batch matching input_specs(cfg, shape) — smoke tests."""
    rng = rng or np.random.default_rng(0)
    b, s = shape.global_batch, shape.seq_len
    if cfg.modality == "audio":
        return {
            "frames": rng.standard_normal((b, s, cfg.d_model)).astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
        }
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
    }
    if cfg.rope_variant == "mrope":
        batch["positions"] = np.broadcast_to(
            np.arange(s, dtype=np.int32), (3, b, s)).copy()
    return batch
