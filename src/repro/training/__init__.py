from repro.training.checkpoint import latest_step, restore_into, save_checkpoint
from repro.training.data import TokenPipeline, synthetic_batch
from repro.training.optimizer import AdamWState, adamw_update, init_adamw
from repro.training.train import grads_fn, loss_fn, train_step

__all__ = [
    "AdamWState",
    "TokenPipeline",
    "adamw_update",
    "grads_fn",
    "init_adamw",
    "latest_step",
    "loss_fn",
    "restore_into",
    "save_checkpoint",
    "synthetic_batch",
    "train_step",
]
