"""Train step: loss, grads, microbatch gradient accumulation, update.

``train_step`` is the unit the dry-run lowers for the ``train_4k`` shape.
Microbatch accumulation runs as a scan over the leading accumulation axis —
per-device activation memory is O(microbatch), independent of global batch.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.util import scan as uscan
from repro.training.optimizer import AdamWState, adamw_update, cast_params

F32 = jnp.float32


def loss_fn(cfg, params, batch, *, aux_weight: float = 0.01):
    """Next-token (or frame-label) cross entropy. labels==-100 are masked."""
    logits, aux, _ = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    if not cfg.is_encoder and cfg.modality == "text":
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    elif cfg.modality == "vision_text":
        # early-fusion prefix has no labels; logits cover [patches + text]
        p = logits.shape[1] - labels.shape[1]
        logits = logits[:, p:]
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    mask = labels != -100
    labels = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    n = jnp.maximum(mask.sum(), 1)
    ce = -(ll * mask).sum() / n
    return ce + aux_weight * aux, (ce, aux)


def grads_fn(cfg, params, batch, *, accum: int = 1):
    """Gradients with optional microbatch accumulation (scan over accum)."""
    vg = jax.value_and_grad(lambda p, b: loss_fn(cfg, p, b), has_aux=True)
    if accum <= 1:
        (loss, (ce, aux)), grads = vg(params, batch)
        return loss, ce, grads

    def split(x):
        b = x.shape[0] if x.ndim else 0
        # positions for mrope carry a leading 3-axis; split on axis 1
        if x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] % accum == 0:
            return x.reshape((3, accum, x.shape[1] // accum) + x.shape[2:]).transpose(1, 0, *range(2, x.ndim + 1))
        return x.reshape((accum, b // accum) + x.shape[1:])

    mb = jax.tree.map(split, batch)

    def body(carry, microbatch):
        loss_acc, ce_acc, g_acc = carry
        (loss, (ce, aux)), grads = vg(params, microbatch)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(F32), g_acc, grads)
        return (loss_acc + loss, ce_acc + ce, g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    (loss, ce, grads), _ = uscan(
        body, (jnp.zeros((), F32), jnp.zeros((), F32), g0), mb)
    inv = 1.0 / accum
    grads = jax.tree.map(lambda g: g * inv, grads)
    return loss * inv, ce * inv, grads


def train_step(cfg, params, opt_state: AdamWState, batch, *, accum: int = 1,
               peak_lr: float = 3e-4, total_steps: int = 10_000):
    """One optimizer step. Returns (params, opt_state, metrics)."""
    loss, ce, grads = grads_fn(cfg, params, batch, accum=accum)
    opt_state, gnorm = adamw_update(
        opt_state, grads, peak_lr=peak_lr, total=total_steps)
    params = cast_params(opt_state, params)
    return params, opt_state, {"loss": loss, "ce": ce, "grad_norm": gnorm}
