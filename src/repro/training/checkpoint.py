"""Checkpointing: flat-key npz save/restore of arbitrary pytrees.

No external deps (no orbax in this container): pytrees are flattened to
``path/to/leaf`` keys. Shardings are reapplied by the caller on restore
(device_put with the launcher's NamedShardings).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, step: int, params, opt_state=None,
                    extra: dict = None):
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, f"params_{step}.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, f"opt_{step}.npz"), **_flatten(opt_state))
    meta = {"step": step, **(extra or {})}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def latest_step(path: str) -> int:
    if not os.path.isdir(path):
        return -1
    steps = [
        int(m.group(1))
        for f in os.listdir(path)
        if (m := re.match(r"params_(\d+)\.npz", f))
    ]
    return max(steps) if steps else -1


def restore_into(path: str, step: int, template):
    """Restore a checkpoint into the structure of `template` (a pytree of
    arrays or ShapeDtypeStructs). Returns the restored pytree."""
    data = np.load(os.path.join(path, f"params_{step}.npz"))
    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_k, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(tdef, leaves)
