"""AdamW with fp32 master weights + cosine LR schedule.

Optimizer state is a pytree mirroring the params; in the distributed
launcher the m/v/master leaves are sharded over (data, model) — ZeRO-style
state partitioning (see repro.core.simd.sharding.opt_state_specs).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    master: dict  # fp32 master copy of params
    m: dict
    v: dict


def init_adamw(params) -> AdamWState:
    master = jax.tree.map(lambda p: p.astype(F32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return AdamWState(jnp.zeros((), jnp.int32), master, zeros,
                      jax.tree.map(jnp.copy, zeros))


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(F32)
    warm = peak_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(state: AdamWState, grads, *, peak_lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, warmup: int = 100,
                 total: int = 10_000, grad_clip: float = 1.0):
    """Returns (new_params_in_model_dtype, new_state)."""
    step = state.step + 1
    lr = cosine_schedule(step, peak_lr=peak_lr, warmup=warmup, total=total)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    b1t = 1 - b1 ** step.astype(F32)
    b2t = 1 - b2 ** step.astype(F32)

    def upd(master, m, v, g):
        g = g.astype(F32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / b1t) / (jnp.sqrt(v_new / b2t) + eps)
        master_new = master - lr * (update + weight_decay * master)
        return master_new, m_new, v_new

    flat_master, tdef = jax.tree.flatten(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_g = jax.tree.leaves(grads)
    out = [upd(mm, m, v, g) for mm, m, v, g in zip(flat_master, flat_m, flat_v, flat_g)]
    master = tdef.unflatten([o[0] for o in out])
    m = tdef.unflatten([o[1] for o in out])
    v = tdef.unflatten([o[2] for o in out])
    new_state = AdamWState(step, master, m, v)
    return new_state, gnorm


def cast_params(state: AdamWState, like_params):
    return jax.tree.map(lambda mw, p: mw.astype(p.dtype), state.master,
                        like_params)
