"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ref_attention(q, k, v, *, causal: bool = True):
    """q/k/v: (BH, S, D)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(F32), k.astype(F32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(F32)).astype(q.dtype)


def ref_decode_attention(q, k, v, n_valid):
    """q: (BH, S, D); k/v: (BH, W, D); n_valid: (BH,) valid slots for the
    LAST query row; row i sees n_valid - (S-1) + i (causal within chunk)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(F32), k.astype(F32)) * scale
    w, sq = k.shape[1], q.shape[1]
    limit = (n_valid[:, None] - (sq - 1)
             + jnp.arange(sq, dtype=jnp.int32)[None, :])  # (BH, S)
    valid = jnp.arange(w)[None, None, :] < limit[:, :, None]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(F32)).astype(q.dtype)


def ref_paged_decode_attention(q, k_pool, v_pool, page_table, n_valid):
    """Oracle for the paged kernel: gather the slot's pages into a linear
    cache view, then mask exactly like ``ref_decode_attention``.
    q: (B, S, H, D); pools: (P, ps, Hkv, D); page_table: (B, n_pages);
    n_valid: (B,) valid slots for the LAST query row."""
    b, sq, h, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    n_pages = page_table.shape[1]
    w = n_pages * ps
    k = jnp.take(k_pool, page_table, axis=0).reshape(b, w, hkv, d)
    v = jnp.take(v_pool, page_table, axis=0).reshape(b, w, hkv, d)
    rep = h // hkv
    kk = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, w, d)
    vv = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, w, d)
    qq = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    nv = jnp.repeat(jnp.minimum(n_valid, w), h)
    out = ref_decode_attention(qq, kk, vv, nv)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def ref_paged_decode_attention_int8(q, k_pool, v_pool, k_scale, v_scale,
                                    page_table, n_valid):
    """Oracle for the fused-dequant int8 paged kernel: dequantize the
    pools in fp32, then run the exact f32 paged oracle. k/v_pool int8
    (P, ps, Hkv, D); k/v_scale fp32 (P, ps, Hkv, 1)."""
    kd = (k_pool.astype(F32) * k_scale.astype(F32)).astype(q.dtype)
    vd = (v_pool.astype(F32) * v_scale.astype(F32)).astype(q.dtype)
    return ref_paged_decode_attention(q, kd, vd, page_table, n_valid)


def int8_attention_score_bound(q, k_scale):
    """Sort-free bound on the max absolute SCALED-LOGIT error of int8-KV
    attention vs exact-K attention. Symmetric rounding gives per-element
    K error <= scale/2, so for query row x the score error is
    |x . dK| * d^-1/2 <= (max_scale / 2) * ||x||_1 * d^-1/2. The max is
    over every scale in the pool and every query row — no sorting, no
    per-pair matching, valid for ANY page table/mask (masked scores are
    identical -inf on both sides). Returns a scalar (eps)."""
    d = q.shape[-1]
    q1 = jnp.sum(jnp.abs(q.astype(F32)), axis=-1)  # row-wise ||q||_1
    return (0.5 * jnp.max(k_scale.astype(F32)) * jnp.max(q1)
            * (float(d) ** -0.5))


def int8_attention_output_bound(q, k_scale, v_scale, v_deq):
    """Sort-free bound on the max absolute OUTPUT error of int8-KV/V
    attention vs exact attention, composed from the score bound: a
    uniform score perturbation |ds| <= eps moves each softmax weight by a
    factor in [e^-2eps, e^2eps], so ||dp||_1 <= e^{2 eps} - 1 and the
    convex combination of values moves by at most (e^{2 eps} - 1) * vmax;
    V's own quantization adds at most max(v_scale)/2 per element.
    ``v_deq`` is the dequantized V the quantized path actually attends
    over (vmax = its max |value|). Conservative (worst-case alignment of
    both effects) but cheap and mask-agnostic."""
    eps = int8_attention_score_bound(q, k_scale)
    vmax = jnp.max(jnp.abs(v_deq.astype(F32)))
    return ((jnp.exp(2.0 * eps) - 1.0) * vmax
            + 0.5 * jnp.max(v_scale.astype(F32)))


def ref_rglru_scan(a, x, h0):
    """h_t = a_t h_{t-1} + x_t via associative scan. a/x: (B,S,L)."""
    af, xf = a.astype(F32), x.astype(F32)
    xf = xf.at[:, 0].add(af[:, 0] * h0.astype(F32))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (af, xf), axis=1)
    return h.astype(a.dtype), h[:, -1].astype(h0.dtype)


def ref_topk_sample(logits, k, temperature, uniform):
    """Sort-based oracle for the radix-select sampling kernel: one
    categorical draw per row from the temperature-scaled softmax
    restricted to the k largest logits, via Gumbel argmax. Threshold
    semantics are ``x >= kth`` (value ties all survive), and the noise is
    an input — kernel-vs-oracle equality is exact, not distributional.
    logits (B, V); k (B,) int32 in [1, V]; temperature (B,) > 0;
    uniform (B, V) in [0, 1)."""
    x = logits.astype(F32) / temperature.astype(F32)[:, None]
    srt = jnp.sort(x, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(srt, (k.astype(jnp.int32) - 1)[:, None],
                              axis=-1)
    g = -jnp.log(-jnp.log(jnp.maximum(uniform.astype(F32), 1e-12)))
    z = jnp.where(x >= kth, x + g, -jnp.inf)
    return jnp.argmax(z, axis=-1).astype(jnp.int32)


def ref_int8_matmul(x, w_q, scales):
    w = w_q.astype(F32) * scales[None, :].astype(F32)
    return (x.astype(F32) @ w).astype(x.dtype)
