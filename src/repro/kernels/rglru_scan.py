"""Pallas TPU RG-LRU linear-recurrence scan.

TPU adaptation (DESIGN.md §4): instead of a log-depth associative scan
(whose intermediate (a, x) pairs round-trip HBM log(S) times on TPU), the
kernel keeps the hidden state h resident in VMEM and walks time
sequentially in channel-blocked tiles: grid (batch, channel_blocks,
time_blocks) with time innermost; each step applies ``block_t`` recurrence
iterations on-chip. Bandwidth = one read of (a, x) + one write of y —
optimal for this memory-bound op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _rglru_kernel(a_ref, x_ref, h0_ref, y_ref, hT_ref, h_scr, *,
                  block_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(F32)

    a = a_ref[0].astype(F32)  # (block_t, block_l)
    x = x_ref[0].astype(F32)

    def step(t, h):
        h_new = a[t] * h + x[t]
        y_ref[0, t] = h_new.astype(y_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _final():
        hT_ref[0] = h.astype(hT_ref.dtype)


def rglru_scan_kernel(a, x, h0, *, block_l: int = 128, block_t: int = 128,
                      interpret: bool = False):
    """Linear recurrence h_t = a_t * h_{t-1} + x_t.

    a/x: (B, S, L); h0: (B, L). Returns (y (B, S, L), h_last (B, L))."""
    b, s, l = a.shape
    block_l = min(block_l, l)
    block_t = min(block_t, s)
    assert l % block_l == 0 and s % block_t == 0, (l, s, block_l, block_t)
    grid = (b, l // block_l, s // block_t)
    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_l), lambda b_, c, t: (b_, t, c)),
            pl.BlockSpec((1, block_t, block_l), lambda b_, c, t: (b_, t, c)),
            pl.BlockSpec((1, block_l), lambda b_, c, t: (b_, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_l), lambda b_, c, t: (b_, t, c)),
            pl.BlockSpec((1, block_l), lambda b_, c, t: (b_, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct(h0.shape, h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_l,), F32)],
        interpret=interpret,
    )(a, x, h0)
    return y, hT
