"""Pallas TPU flash attention (prefill hot-spot).

Grid (batch*heads, q_blocks, kv_blocks), kv innermost. Online softmax
statistics (m, l) and the output accumulator live in VMEM scratch and are
carried across kv blocks; causal block skipping uses pl.when so skipped
blocks cost nothing (contrast with the masked jnp path's full compute).
BlockSpecs tile q/k/v into (block, head_dim) VMEM tiles; block sizes are
multiples of 128 to keep MXU matmul dims hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, block_q: int, block_kv: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0].astype(F32)  # (bq, d)
        k = k_ref[0].astype(F32)  # (bkv, d)
        v = v_ref[0].astype(F32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=F32) * scale  # (bq, bkv)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=F32))
        m_scr[...] = m_new

    if causal:
        # skip fully-masked blocks: only run when the block intersects the
        # causal lower triangle
        @pl.when(qi * block_q + block_q - 1 >= ki * block_kv)
        def _run():
            _body()
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False):
    """q/k/v: (BH, S, D) with matching head counts (GQA expansion happens in
    ops.py). Returns (BH, S, D)."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    scale = d ** -0.5
    grid = (bh, s // block_q, s // block_kv)
    kernel = functools.partial(
        _flash_kernel, causal=causal, block_q=block_q, block_kv=block_kv,
        scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q, d), F32),
        ],
        interpret=interpret,
    )(q, k, v)
