"""Pallas TPU kernels for the serving hot-spots (see DESIGN.md §7):
flash_attention (prefill), decode_attention (memory-bound decode),
rglru_scan (recurrent hybrid), int8_matmul (weight-only quantization).

Each kernel: <name>.py (pl.pallas_call + BlockSpec) with its jit wrapper in
ops.py and pure-jnp oracle in ref.py.
"""
