"""Pallas TPU fused top-k + softmax sampling (the decode logit tail).

One grid step = one batch row: the row's logits live in VMEM, the k-th
largest scaled logit is found by a 32-step radix select over the
order-isomorphic uint32 image of float32 (no sort — Pallas has none, and
a full sort would be O(V log V) of serial work for a single order
statistic), and the categorical draw over the surviving top-k softmax is
taken as a Gumbel argmax in the same pass. Threshold semantics are
``x >= kth`` (value ties all survive), exactly matching the sort-based
oracle ``repro.kernels.ref.ref_topk_sample``; ``-0.0`` is canonicalized
to ``+0.0`` before the bit mapping so the uint32 order agrees with IEEE
float order everywhere the oracle can reach.

Uniform noise is an input (the serving engine derives it from per-slot
PRNG keys folded with the absolute token position; see
``repro.models.layers.sample_tokens``, the model-layout twin that adds
top-p and the greedy mask), which also makes kernel-vs-oracle equality
exact instead of distributional.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _topk_sample_kernel(k_ref, temp_ref, x_ref, u_ref, o_ref):
    v = x_ref.shape[1]
    # temperature scale (+0.0 canonicalizes -0.0 for the bit mapping)
    x = x_ref[...].astype(F32) / temp_ref[0] + 0.0  # (1, V)
    # order-isomorphic uint32 image of float32: descending float order ==
    # descending unsigned order (sign bit flipped for positives, all bits
    # inverted for negatives)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    mapped = jnp.where(bits >> 31 == 0,
                       bits | jnp.uint32(0x80000000), ~bits)
    k = k_ref[0]

    # radix select: build the k-th largest mapped value MSB-first; a bit
    # stays set iff at least k elements still reach the candidate prefix
    def body(b, t):
        cand = t | jax.lax.shift_left(jnp.uint32(1),
                                      jnp.uint32(31 - b))
        cnt = jnp.sum(jnp.where(mapped >= cand, 1, 0))
        return jnp.where(cnt >= k, cand, t)

    kth = jax.lax.fori_loop(0, 32, body, jnp.uint32(0))
    keep = mapped >= kth

    # Gumbel argmax over the surviving entries == one categorical draw
    # from their softmax (temperature already applied)
    u = jnp.maximum(u_ref[...].astype(F32), 1e-12)
    z = jnp.where(keep, x - jnp.log(-jnp.log(u)), NEG_INF)
    m = jnp.max(z)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, v), 1)
    o_ref[0, 0] = jnp.min(jnp.where(z == m, idx, v))


def topk_sample(logits, k, temperature, uniform, *, interpret: bool = False):
    """logits (B, V) float; k (B,) int32 in [1, V]; temperature (B,) > 0;
    uniform (B, V) in [0, 1). Returns (B,) int32 — one token per row drawn
    from the temperature-scaled, top-k-restricted softmax."""
    b, v = logits.shape
    return pl.pallas_call(
        _topk_sample_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((1, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(k.astype(jnp.int32), temperature.astype(F32), logits, uniform)[:, 0]
