"""Pallas TPU decode attention (the memory-bound serving hot-spot).

Attention for a small number of new queries against a (rolling) KV cache.
S=1 is the classic decode step: bandwidth-bound (survey §3: the
memory-intensive tenant class), so the kernel's job is streaming K/V
through VMEM exactly once per step at full HBM bandwidth. S>1 is a
chunked-prefill chunk whose keys were just written at slots
[n_valid - S, n_valid): per-query validity (query i sees
``n_valid - (S-1) + i`` slots) makes the mask causal within the chunk.
Grid (batch*heads, kv_blocks): online softmax over kv blocks; invalid
cache slots are masked per query row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _decode_kernel(nvalid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_kv: int, scale: float):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    sq = q_ref.shape[1]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(F32)  # (sq, d)
    k = k_ref[0].astype(F32)  # (bkv, d)
    v = v_ref[0].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # (sq, bkv)
    slot = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (sq, block_kv), 1)
    # per-query valid slot count: row i sees n_valid - (sq - 1) + i slots
    row = jax.lax.broadcasted_iota(jnp.int32, (sq, block_kv), 0)
    limit = nvalid_ref[0] - (sq - 1) + row
    s = jnp.where(slot < limit, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=F32))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(table_ref, nvalid_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, page_size: int, s_q: int,
                         scale: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    rows = q_ref.shape[2]  # G * S query rows sharing this kv head

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    nv = nvalid_ref[bi]

    # Block-sparsity: logical page ki covers cache slots [ki*ps, (ki+1)*ps);
    # pages entirely past the last valid slot contribute nothing and are
    # skipped (their DMA is still scheduled by the grid, but no FLOPs run).
    @pl.when(ki * page_size < nv)
    def _compute():
        q = q_ref[0, 0].astype(F32)  # (rows, d)
        k = k_ref[0, 0].astype(F32)  # (ps, d) — gathered via the page table
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        slot = (ki * page_size
                + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 1))
        # rows are ordered (g, s): query s of chunk S sees
        # n_valid - (S - 1) + s slots, identically for each of the g heads
        row = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0)
        limit = nv - (s_q - 1) + jax.lax.rem(row, s_q)
        s = jnp.where(slot < limit, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=F32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel_int8(table_ref, nvalid_ref, q_ref, k_ref, v_ref,
                              ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr,
                              *, page_size: int, s_q: int, scale: float):
    """Fused-dequant variant of ``_paged_decode_kernel``: K/V pages arrive
    in VMEM as int8 plus one fp32 scale per (slot, kv-head) vector —
    gathered through the SAME scalar-prefetched page-table index map — and
    dequantize inline right before the dots, so HBM traffic per resident
    token is the int8 payload + one fp32 scalar instead of the full-width
    vector (the memory-bound decode step's win)."""
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    rows = q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    nv = nvalid_ref[bi]

    @pl.when(ki * page_size < nv)
    def _compute():
        q = q_ref[0, 0].astype(F32)  # (rows, d)
        # inline dequant in VMEM: int8 page values * per-slot fp32 scale
        k = k_ref[0, 0].astype(F32) * ks_ref[0, 0][:, None]  # (ps, d)
        v = v_ref[0, 0].astype(F32) * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        slot = (ki * page_size
                + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 1))
        row = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0)
        limit = nv - (s_q - 1) + jax.lax.rem(row, s_q)
        s = jnp.where(slot < limit, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=F32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, table_flat, n_valid, *,
                           s_q: int, interpret: bool = False):
    """Block-sparse decode attention through a paged KV cache.

    q: (B, KVH, R, D) with R = G*S query rows per kv head, ordered (g, s);
    k/v_pool: (KVH, P, ps, D) — the shared page pool, kv-head major;
    table_flat: (B * n_pages,) int32 — slot b's logical page j lives in
    physical pool page ``table_flat[b * n_pages + j]``;
    n_valid: (B,) valid cache slots for the LAST query row of the chunk.

    The page table is a scalar-prefetch operand: the grid's kv step j
    resolves its physical page in the BlockSpec index map, so the kernel
    streams exactly the slot's pages (plus skips compute on pages past
    ``n_valid`` — the block-sparse fast path). Returns (B, KVH, R, D)."""
    b, hkv, rows, d = q.shape
    _, _, ps, _ = k_pool.shape
    n_pages = table_flat.shape[0] // b
    scale = d ** -0.5
    kernel = functools.partial(_paged_decode_kernel, page_size=ps, s_q=s_q,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda bi, hi, ji, t, nv: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bi, hi, ji, t, nv: (hi, t[bi * n_pages + ji],
                                                    0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bi, hi, ji, t, nv: (hi, t[bi * n_pages + ji],
                                                    0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda bi, hi, ji, t, nv: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows,), F32),
            pltpu.VMEM((rows,), F32),
            pltpu.VMEM((rows, d), F32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(table_flat, n_valid, q, k_pool, v_pool)


def paged_decode_attention_int8(q, k_pool, v_pool, k_scale, v_scale,
                                table_flat, n_valid, *, s_q: int,
                                interpret: bool = False):
    """Quantized-pool paged decode attention with fused inline dequant.

    Same layout contract as ``paged_decode_attention`` except the pools
    are int8 and each carries a scale pool: k/v_scale (KVH, P, ps) fp32 —
    one symmetric scale per (slot, kv-head) vector, living in pages
    addressed by the SAME page ids, so the scalar-prefetched table
    resolves both the value page and its scale page in the BlockSpec
    index maps. Dequantization happens in VMEM right before the QK/PV
    dots (``kernels/ref.ref_paged_decode_attention_int8`` is the oracle;
    ``ref.int8_attention_error_bound`` bounds the logit error)."""
    b, hkv, rows, d = q.shape
    _, _, ps, _ = k_pool.shape
    n_pages = table_flat.shape[0] // b
    scale = d ** -0.5
    kernel = functools.partial(_paged_decode_kernel_int8, page_size=ps,
                               s_q=s_q, scale=scale)
    page_map = lambda bi, hi, ji, t, nv: (hi, t[bi * n_pages + ji], 0, 0)
    scale_map = lambda bi, hi, ji, t, nv: (hi, t[bi * n_pages + ji], 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda bi, hi, ji, t, nv: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, ps, d), page_map),
            pl.BlockSpec((1, 1, ps, d), page_map),
            pl.BlockSpec((1, 1, ps), scale_map),
            pl.BlockSpec((1, 1, ps), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda bi, hi, ji, t, nv: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows,), F32),
            pltpu.VMEM((rows,), F32),
            pltpu.VMEM((rows, d), F32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(table_flat, n_valid, q, k_pool, v_pool, k_scale, v_scale)


def decode_attention(q, k, v, n_valid, *, block_kv: int = 256,
                     interpret: bool = False):
    """q: (BH, S, D); k/v: (BH, W, D); n_valid: (BH,) int32 — number of
    valid cache slots for the LAST query row (row i of S sees
    ``n_valid - (S-1) + i``; S=1 recovers the classic per-row count).
    Returns (BH, S, D)."""
    bh, w, d = k.shape
    sq = q.shape[1]
    block_kv = min(block_kv, w)
    assert w % block_kv == 0, (w, block_kv)
    scale = d ** -0.5
    grid = (bh, w // block_kv)
    kernel = functools.partial(_decode_kernel, block_kv=block_kv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, sq, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((sq,), F32),
            pltpu.VMEM((sq,), F32),
            pltpu.VMEM((sq, d), F32),
        ],
        interpret=interpret,
    )(n_valid, q, k, v)
