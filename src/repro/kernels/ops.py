"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels run natively; on the CPU dry-run container
``interpret=True`` executes the kernel bodies in Python for correctness
validation (the models' default compute path stays pure-jnp — see
DESIGN.md §7).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import int8_matmul as _im
from repro.kernels import rglru_scan as _rs
from repro.kernels import topk_sample as _ts


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    interpret: bool = None):
    """q: (B, S, H, D); k/v: (B, S, Hkv, D) — GQA heads expanded here."""
    if interpret is None:
        interpret = _default_interpret()
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, interpret=interpret)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, pos, *, interpret: bool = None):
    """q: (B, S, H, D); caches: (B, W, Hkv, D); pos: (B,) tokens written
    INCLUDING the S queries (S=1: classic decode; S>1: chunked-prefill
    chunk with per-query causal validity)."""
    if interpret is None:
        interpret = _default_interpret()
    b, sq, h, d = q.shape
    w, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    k = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    v = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, w, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, w, d)
    nv = jnp.repeat(jnp.minimum(pos, w).astype(jnp.int32), h)
    o = _da.decode_attention(qf, kf, vf, nv, interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, page_table, pos, *,
                           interpret: bool = None):
    """q: (B, S, H, D); k/v_pool: (P, ps, Hkv, D) shared page pools;
    page_table: (B, n_pages) int32; pos: (B,) tokens written INCLUDING the
    S queries. Model-layout twin of ``repro.models.layers.
    paged_decode_attention`` running the block-sparse Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    b, sq, h, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = h // hkv
    n_pages = page_table.shape[1]
    # (B, S, H, D) -> (B, KVH, G*S, D), rows (g, s)-ordered
    qf = (q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
          .reshape(b, hkv, g * sq, d))
    kf = k_pool.transpose(2, 0, 1, 3)  # (KVH, P, ps, D)
    vf = v_pool.transpose(2, 0, 1, 3)
    nv = jnp.minimum(pos, n_pages * ps).astype(jnp.int32)
    o = _da.paged_decode_attention(
        qf, kf, vf, page_table.reshape(-1).astype(jnp.int32), nv, s_q=sq,
        interpret=interpret)
    return (o.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, h, d))


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_int8(q, k_pool, v_pool, k_scale, v_scale,
                                page_table, pos, *, interpret: bool = None):
    """Quantized-pool twin of ``paged_decode_attention``: pools are int8
    (P, ps, Hkv, D) with per-vector fp32 scales (P, ps, Hkv, 1) addressed
    by the same page ids; dequantization is fused into the kernel (inline
    in VMEM, right before the dots). Model-layout twin:
    ``repro.models.layers.paged_decode_attention_int8``."""
    if interpret is None:
        interpret = _default_interpret()
    b, sq, h, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = h // hkv
    n_pages = page_table.shape[1]
    qf = (q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
          .reshape(b, hkv, g * sq, d))
    kf = k_pool.transpose(2, 0, 1, 3)  # (KVH, P, ps, D)
    vf = v_pool.transpose(2, 0, 1, 3)
    ksf = k_scale[..., 0].transpose(2, 0, 1)  # (KVH, P, ps)
    vsf = v_scale[..., 0].transpose(2, 0, 1)
    nv = jnp.minimum(pos, n_pages * ps).astype(jnp.int32)
    o = _da.paged_decode_attention_int8(
        qf, kf, vf, ksf, vsf, page_table.reshape(-1).astype(jnp.int32), nv,
        s_q=sq, interpret=interpret)
    return (o.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, h, d))


@partial(jax.jit, static_argnames=("interpret",))
def topk_sample(logits, k, temperature, uniform, *, interpret: bool = None):
    """Fused top-k + softmax sampling: one categorical draw per row from
    the temperature-scaled softmax restricted to the ``k`` largest logits
    (radix select over float bits + Gumbel argmax, one VMEM pass — no
    sort). logits (B, V); k (B,) int32 in [1, V]; temperature (B,) > 0;
    uniform (B, V) noise in [0, 1) — the caller keys it (the engine uses
    per-slot PRNG keys folded with the token position). Returns (B,)
    int32. Model-layout twin with top-p and the greedy mask:
    ``repro.models.layers.sample_tokens``."""
    if interpret is None:
        interpret = _default_interpret()
    return _ts.topk_sample(logits, k, temperature, uniform,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, x, h0, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _rs.rglru_scan_kernel(a, x, h0, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(x, w_q, scales, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _im.int8_matmul(x, w_q, scales, interpret=interpret)


quantize_int8 = _im.quantize_int8
