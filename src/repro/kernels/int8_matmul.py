"""Pallas TPU int8-weight matmul (serving-side weight quantization).

Weight-only int8 halves the decode step's dominant HBM term (the survey's
TB-scale DLRM remark and the memory-bound §3 tenant class). Per-output-
channel fp32 scales; accumulation in fp32 on the MXU; dequantize once per
output tile. Grid (M/bm, N/bn, K/bk), K innermost with a VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _int8_mm_kernel(x_ref, w_ref, scale_ref, o_ref, acc_scr):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(F32)  # (bm, bk)
    w = w_ref[...].astype(F32)  # (bk, bn) int8 -> f32
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[...] = (acc_scr[...] * scale_ref[...][None, :]).astype(o_ref.dtype)


def int8_matmul(x, w_q, scales, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 128, interpret: bool = False):
    """x: (M, K) float; w_q: (K, N) int8; scales: (N,) fp32 per-channel.
    Returns (M, N) in x.dtype."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _int8_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), F32)],
        interpret=interpret,
    )(x, w_q, scales)


def quantize_int8(w, axis: int = 0):
    """Symmetric per-output-channel int8 quantization. w: (K, N)."""
    amax = jnp.max(jnp.abs(w.astype(F32)), axis=axis, keepdims=True)
    scale = (amax / 127.0).clip(1e-12)
    w_q = jnp.clip(jnp.round(w.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return w_q, scale.reshape(-1)
