"""Adaptive batching under SLA (survey §3.3.2, [8][4]).

Batching raises device utilization (throughput) but inflates per-query
latency; the right batch size depends on the model's roofline position and
the SLA. ``adaptive_batch_size`` searches the batch dimension with the cost
model; ``BatchAccumulator`` is the runtime piece: accumulate queries until
either the target batch or the SLA-derived deadline is hit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.costmodel import (
    estimate_decode,
    estimate_prefill,
    kv_bytes_per_token,
)


def adaptive_batch_size(cfg, *, context: int, sla_s: float,
                        kind: str = "decode", n_chips: int = 1,
                        max_batch: int = 512) -> Tuple[int, float]:
    """Largest batch whose step latency stays within the SLA budget.
    Returns (batch, latency_s). Batch 1 is returned even if it misses."""
    best, best_lat = 1, None
    b = 1
    while b <= max_batch:
        est = (estimate_decode(cfg, b, context, n_chips=n_chips)
               if kind == "decode"
               else estimate_prefill(cfg, b, context, n_chips=n_chips))
        if best_lat is None:
            best, best_lat = b, est.latency_s
        if est.latency_s <= sla_s:
            best, best_lat = b, est.latency_s
        else:
            break
        b *= 2
    return best, best_lat


@dataclass(frozen=True)
class AdmissionPlan:
    """Cost-model-derived admission policy for the serving engine: how many
    decode slots to run and how long queued requests may wait to batch up
    before being force-admitted (survey §3.3.2: batch occupancy is the
    first-order throughput knob; the deadline bounds the latency cost)."""

    slots: int
    flush_deadline_s: float
    step_latency_s: float


def plan_admission(cfg, *, context: int, sla_s: float, n_chips: int = 1,
                   max_slots: int = 256,
                   kv_hbm_budget_bytes: Optional[float] = None,
                   mean_context: Optional[int] = None,
                   kv_cache_dtype: str = "") -> AdmissionPlan:
    """Derive (slot count, admission flush deadline) from the cost model:
    slots = largest decode batch meeting the per-step SLA budget; deadline =
    SLA headroom left after one decode step (floored at 10% of the SLA so a
    mis-modeled step cannot zero the accumulation window).

    ``kv_hbm_budget_bytes`` additionally caps slots by KV memory:
    each slot reserves ``mean_context`` cached tokens (a paged cache's
    *expected* resident length; a rolling cache pays the full ``context``
    window, so pass mean_context=context for it). Defaults to ``context``
    when unset — the conservative rolling-cache bound.

    ``kv_cache_dtype`` is the dtype THIS pool actually stores ("" = model
    dtype, "int8" = quantized pages) — the per-token byte cost is a
    per-pool property, not a global constant, and a mismatched estimate
    over-admits (``kv_bytes_per_token`` asserts on unknown dtypes)."""
    slots, lat = adaptive_batch_size(
        cfg, context=context, sla_s=sla_s, kind="decode", n_chips=n_chips,
        max_batch=max_slots)
    if kv_hbm_budget_bytes:
        per_tok = kv_bytes_per_token(cfg, kv_cache_dtype)
        resident = max(1, mean_context or context)
        if per_tok > 0:
            slots = min(slots, max(1, int(kv_hbm_budget_bytes
                                          // (per_tok * resident))))
    lat = lat or 0.0
    deadline = max(sla_s - lat, 0.1 * sla_s)
    return AdmissionPlan(slots=slots, flush_deadline_s=deadline,
                         step_latency_s=lat)


@dataclass
class BatchAccumulator:
    """Deadline-bounded query accumulator."""

    target_batch: int
    deadline_s: float
    pending: List = field(default_factory=list)
    window_open: float = -1.0

    def add(self, query, now: float) -> Optional[List]:
        if not self.pending:
            self.window_open = now
        self.pending.append(query)
        if len(self.pending) >= self.target_batch:
            return self.flush()
        return None

    def poll(self, now: float) -> Optional[List]:
        if self.pending and now - self.window_open >= self.deadline_s:
            return self.flush()
        return None

    def flush(self) -> List:
        out, self.pending = self.pending, []
        return out
