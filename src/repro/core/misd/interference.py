"""Inter-tenant interference model (survey §3.2.1, Fig. 3).

Co-located jobs on one device (or meshlet) contend for compute units and
memory bandwidth. Each job carries a demand vector (c_i, m_i) from the cost
model. A proportional-share model gives each job a progress rate:

    C = sum_i c_i          (aggregate compute demand)
    M = sum_i m_i          (aggregate bandwidth demand)
    rate_i = 1 / max(1, C, M)

so a compute-bound job pairs with a memory-bound job nearly for free
(max(C, M) ~ 1: the survey's "perfectly interleaving compute-intensive and
memory-intensive queries"), while two same-class jobs halve each other.
An extra ``cross_penalty`` models imperfect overlap (cache thrash, operator
concurrency limits) — calibrated so bi-model co-location shows the 5–17%
degradation band of Fig. 3.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

CROSS_PENALTY = 0.07  # fractional slowdown per co-tenant beyond the first


def progress_rates(demands: Sequence[Tuple[float, float]],
                   cross_penalty: float = CROSS_PENALTY) -> List[float]:
    """Progress rate in (0, 1] for each co-located job."""
    if not demands:
        return []
    agg_c = sum(d[0] for d in demands)
    agg_m = sum(d[1] for d in demands)
    base = max(1.0, agg_c, agg_m)
    overhead = 1.0 + cross_penalty * (len(demands) - 1)
    return [1.0 / (base * overhead) for _ in demands]


def pairwise_degradation(d1: Tuple[float, float],
                         d2: Tuple[float, float]) -> float:
    """Latency inflation factor for job1 when co-run with job2 (>= 1)."""
    r = progress_rates([d1, d2])[0]
    return 1.0 / r


class InterferencePredictor:
    """ML-style latency predictor ([28]): here a calibrated analytic model
    with a learned residual hook. ``observe`` accumulates (predicted,
    actual) pairs; ``predict`` applies the mean residual correction —
    the survey's online-learning feedback loop in miniature.

    The latency-domain twins (``observe_latency`` / ``corrected_latency``)
    serve the cluster frontend's predicted-completion routing: the cost
    model predicts a completion latency, the frontend observes the real
    TTFT/JCT, and the mean multiplicative residual closes the loop (rates
    are reciprocal latencies, so the same accumulator serves both views).

    Residuals live in a bounded ``repro.serving.metrics.Histogram``: the
    ``correction`` mean comes from its EXACT raw-sum accumulator (bit-
    identical to a bare running mean — routing behavior is unchanged),
    while the bucket counts give the observability layer the residual
    *distribution* each replica has learned, for free.
    """

    def __init__(self):
        # lazy import: repro.serving imports this module via cluster.py,
        # so a top-level import back into repro.serving would cycle
        from repro.serving.metrics import residual_histogram
        self.residuals = residual_histogram()

    # bare-accumulator views, kept for callers/tests of the old fields
    @property
    def _resid_sum(self) -> float:
        return self.residuals.sum

    @property
    def _n(self) -> int:
        return self.residuals.count

    @property
    def correction(self) -> float:
        """Mean fractional residual: positive when reality runs slower
        than predicted (rates were over-estimated)."""
        h = self.residuals
        return h.sum / h.count if h.count else 0.0

    def predict(self, demands: Sequence[Tuple[float, float]]) -> List[float]:
        rates = progress_rates(demands)
        corr = self.correction
        return [max(1e-3, r * (1.0 - corr)) for r in rates]

    def observe(self, predicted_rate: float, actual_rate: float):
        if predicted_rate > 0:
            self.residuals.observe(
                (actual_rate - predicted_rate) / predicted_rate * -1.0)

    def observe_latency(self, predicted_s: float, actual_s: float):
        """Record one (predicted, observed) latency pair (seconds).

        Outlier rejection keeps the residual a *model correction*, not a
        noise accumulator: a pair more than 32x apart (an instant first
        token on an idle engine, a host stall, mismatched clocks) is a
        different regime from model error and is dropped entirely; pairs
        within band are clamped to 4x so one tail observation nudges the
        mean instead of dominating it. Persistent in-band bias still
        converges, one clamped step per observation."""
        p = max(predicted_s, 1e-9)
        if not (p / 32.0 <= actual_s <= 32.0 * p):
            return
        a = min(max(actual_s, 0.25 * p), 4.0 * p)
        self.observe(1.0 / p, 1.0 / a)

    def corrected_latency(self, predicted_s: float) -> float:
        """Apply the learned residual to a cost-model latency estimate.
        The correction is clamped so a burst of pathological observations
        can never flip the rate negative or amplify it without bound."""
        corr = min(0.95, max(-20.0, self.correction))
        rate = (1.0 / max(predicted_s, 1e-9)) * (1.0 - corr)
        return 1.0 / max(rate, 1e-9)
