from repro.core.misd.batching import BatchAccumulator, adaptive_batch_size
from repro.core.misd.interference import (
    InterferencePredictor,
    pairwise_degradation,
    progress_rates,
)
from repro.core.misd.partition import MeshPartitioner, Meshlet, PartitionPlan
from repro.core.misd.scheduler import (
    SCHEDULERS,
    Device,
    FIFOScheduler,
    InterferenceAwareScheduler,
    Job,
    MISDSimulator,
    PremaScheduler,
    SJFScheduler,
    SimResult,
)
