from repro.core.misd.batching import (
    AdmissionPlan,
    BatchAccumulator,
    adaptive_batch_size,
    plan_admission,
)
from repro.core.misd.interference import (
    InterferencePredictor,
    pairwise_degradation,
    progress_rates,
)
from repro.core.misd.partition import MeshPartitioner, Meshlet, PartitionPlan
from repro.core.misd.scheduler import (
    SCHEDULERS,
    ChunkedPrefillPolicy,
    Device,
    FIFOScheduler,
    InterferenceAwareScheduler,
    Job,
    MISDSimulator,
    PremaScheduler,
    SJFScheduler,
    SimResult,
)
