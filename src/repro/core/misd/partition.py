"""MISD spatial resource management: meshlets (survey §3.3.2).

GPU-side mechanisms (MPS SM-partitioning, MIG slices, gpulets [4]) map on
TPU to partitioning the pod mesh into disjoint submeshes. A ``Meshlet`` is
a rectangular slice of the device grid serving one tenant class in
isolation (no interference across meshlets — that is the point of spatial
partitioning). Reconfiguration carries a real cost (recompile + weight
resharding), modelled after the survey's "several seconds" observation.

``MeshPartitioner`` implements gpulet-style best-fit sizing: pick for each
model the smallest meshlet whose predicted latency meets the SLA, then pack
meshlets into the pod.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import WorkEstimate, estimate_decode, estimate_prefill
from repro.core.hardware import RECONFIG_COST_S, TPU_V5E
from repro.core.misd.scheduler import Device


@dataclass(frozen=True)
class Meshlet:
    """A rectangular submesh slice: (rows, cols) within the pod grid."""

    name: str
    shape: Tuple[int, int]
    origin: Tuple[int, int] = (0, 0)

    @property
    def n_chips(self) -> int:
        return self.shape[0] * self.shape[1]

    def as_device(self, max_tenants: int = 4) -> Device:
        # speed scales with chips (model-parallel within the meshlet)
        return Device(self.name, max_tenants=max_tenants,
                      speed=self.n_chips / 1.0)


def _splits(pod_shape: Tuple[int, int], sizes: Sequence[int]) -> List[Meshlet]:
    """Greedy guillotine packing of power-of-two meshlets into the pod."""
    total = pod_shape[0] * pod_shape[1]
    assert sum(sizes) <= total, (sizes, pod_shape)
    out = []
    row, col = 0, 0
    for i, n in enumerate(sorted(sizes, reverse=True)):
        rows = 2 ** (int(math.log2(n)) // 2)
        cols = n // rows
        if col + cols > pod_shape[1]:
            row += rows
            col = 0
        assert row + rows <= pod_shape[0], "packing overflow"
        out.append(Meshlet(f"meshlet{i}", (rows, cols), (row, col)))
        col += cols
    return out


@dataclass
class PartitionPlan:
    meshlets: List[Meshlet]
    assignment: Dict[str, str]  # model name -> meshlet name
    reconfig_cost_s: float = 0.0


class MeshPartitioner:
    """gpulet-style spatial partitioner for a pod."""

    def __init__(self, pod_shape: Tuple[int, int] = (16, 16)):
        self.pod_shape = pod_shape
        self.current: Optional[PartitionPlan] = None

    def size_for_sla(self, cfg, *, batch: int, context: int,
                     sla_s: float, kind: str = "decode") -> int:
        """Smallest power-of-two chip count meeting the SLA (cost model)."""
        n = 1
        total = self.pod_shape[0] * self.pod_shape[1]
        while n <= total:
            est = (estimate_decode(cfg, batch, context, n_chips=n)
                   if kind == "decode"
                   else estimate_prefill(cfg, batch, context, n_chips=n))
            # weights must also fit
            wb = 2 if cfg.dtype == "bfloat16" else 4
            fits = cfg.param_count() * wb <= n * TPU_V5E.hbm_bytes * 0.8
            if est.latency_s <= sla_s and fits:
                return n
            n *= 2
        return total

    def plan(self, tenants: List[dict]) -> PartitionPlan:
        """tenants: [{"name", "cfg", "batch", "context", "sla_s", "kind"}]"""
        sizes, names = [], []
        for t in tenants:
            n = self.size_for_sla(
                t["cfg"], batch=t["batch"], context=t["context"],
                sla_s=t["sla_s"], kind=t.get("kind", "decode"))
            sizes.append(n)
            names.append(t["name"])
        total = self.pod_shape[0] * self.pod_shape[1]
        while sum(sizes) > total:  # shrink the largest ask until it packs
            k = sizes.index(max(sizes))
            sizes[k] //= 2
        meshlets = _splits(self.pod_shape, sizes)
        order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
        assignment = {names[i]: meshlets[rank].name
                      for rank, i in enumerate(order)}
        cost = RECONFIG_COST_S if self.current is not None else 0.0
        plan = PartitionPlan(meshlets, assignment, cost)
        self.current = plan
        return plan

    def devices(self, max_tenants: int = 4) -> List[Device]:
        assert self.current is not None
        return [m.as_device(max_tenants) for m in self.current.meshlets]
