"""MISD temporal scheduling: event-driven multi-tenant simulator + the
scheduler family of survey Table 1.

Schedulers:
  FIFOScheduler              — baseline co-location, admit in arrival order
  SJFScheduler               — shortest-job-first (makespan-oriented, [52])
  PremaScheduler             — token-based predictive priority + preemption
                               (PREMA [5])
  InterferenceAwareScheduler — admit only placements whose predicted mutual
                               slowdown is acceptable ([28] Mendoza et al.)

The simulator is event-driven: between events every running job progresses
at the rate given by the interference model over the demands co-located on
its device. Service times come from the analytic cost model; this is the
TPU-adapted, query-granularity analogue of the survey's GPU schedulers
(operator-level scheduling does not transfer — DESIGN.md §4).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.misd.interference import InterferencePredictor, progress_rates


@dataclass
class Job:
    jid: int
    model: str
    demand: Tuple[float, float]  # (compute, memory) fractions
    service_s: float  # isolated latency on the target device
    arrival: float = 0.0
    priority: int = 0
    sla_s: float = 0.0
    # token-level shape (0/None when the caller only knows service_s):
    # lets per-replica routing re-estimate service for heterogeneous
    # hardware (n_chips) and probe prefix-cache affinity on the prompt
    prompt_tokens: int = 0
    new_tokens: int = 0
    tokens: Optional[Sequence[int]] = None  # prompt ids (affinity probe)
    # runtime state
    remaining: float = -1.0
    start: float = -1.0
    finish: float = -1.0
    device: Optional[str] = None
    preemptions: int = 0

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.service_s


@dataclass
class Device:
    """One schedulable hardware unit (whole chip, or a meshlet slice)."""

    name: str
    max_tenants: int = 4
    speed: float = 1.0  # relative to the reference chip (meshlet fraction)
    running: List[Job] = field(default_factory=list)

    @property
    def free_slots(self) -> int:
        return self.max_tenants - len(self.running)

    def rates(self) -> List[float]:
        r = progress_rates([j.demand for j in self.running])
        return [x * self.speed for x in r]


class Scheduler:
    """Base: admission decisions on every event. Override ``place``."""

    name = "base"

    def order(self, queue: List[Job], now: float) -> List[Job]:
        return queue

    def place(self, job: Job, devices: List[Device], now: float) -> Optional[Device]:
        for d in devices:
            if d.free_slots > 0:
                return d
        return None

    def preempt(self, queue: List[Job], devices: List[Device], now: float) -> List[Tuple[Job, Device]]:
        return []


class FIFOScheduler(Scheduler):
    name = "fifo"


class SJFScheduler(Scheduler):
    name = "sjf"

    def order(self, queue, now):
        return sorted(queue, key=lambda j: j.service_s)


class PremaScheduler(Scheduler):
    """PREMA [5]: token-based scheduling. Each waiting job accumulates
    tokens proportional to priority and waiting time; highest-token job is
    served first and may preempt the lowest-token running job when its
    tokens exceed a threshold multiple."""

    name = "prema"

    def __init__(self, token_threshold: float = 2.0):
        self.th = token_threshold

    def _tokens(self, j: Job, now: float) -> float:
        wait = max(0.0, now - j.arrival)
        return (1 + j.priority) * (1.0 + wait / max(j.service_s, 1e-6))

    def order(self, queue, now):
        return sorted(queue, key=lambda j: -self._tokens(j, now))

    def preempt(self, queue, devices, now):
        if not queue:
            return []
        top = max(queue, key=lambda j: self._tokens(j, now))
        top_tok = self._tokens(top, now)
        actions = []
        for d in devices:
            if d.free_slots > 0 or not d.running:
                continue
            victim = min(d.running, key=lambda j: self._tokens(j, now))
            if top_tok > self.th * self._tokens(victim, now):
                actions.append((victim, d))
                break
        return actions


class InterferenceAwareScheduler(Scheduler):
    """[28]: predict co-location slowdown before placing; place on the
    device minimizing predicted mutual degradation, refusing placements
    whose predicted slowdown exceeds ``max_slowdown``."""

    name = "interference-aware"

    def __init__(self, max_slowdown: float = 1.35):
        self.max_slowdown = max_slowdown
        self.predictor = InterferencePredictor()

    def place(self, job, devices, now):
        best, best_rate = None, 0.0
        for d in devices:
            if d.free_slots <= 0:
                continue
            demands = [j.demand for j in d.running] + [job.demand]
            rates = self.predictor.predict(demands)
            if 1.0 / max(rates[-1], 1e-6) > self.max_slowdown and d.running:
                continue  # would interfere too much
            if rates[-1] > best_rate:
                best, best_rate = d, rates[-1]
        if best is None:  # fall back to an empty device if any
            for d in devices:
                if not d.running and d.free_slots > 0:
                    return d
        return best


SCHEDULERS = {
    "fifo": FIFOScheduler,
    "sjf": SJFScheduler,
    "prema": PremaScheduler,
    "interference-aware": InterferenceAwareScheduler,
}


# ---------------------------------------------------------------------------
# chunked prefill <-> decode interleaving (serving-engine hook)
# ---------------------------------------------------------------------------


@dataclass
class ChunkedPrefillPolicy:
    """Decide how many prefill chunks to run ahead of each decode tick.

    Admitting a long prompt as one prefill stalls every in-flight decode
    stream for the whole prompt (head-of-line blocking — the survey's
    batching/latency tension in its sharpest form). The serving engine
    instead splits prompts into ``chunk``-token pieces and asks this policy,
    each tick, how many pieces fit: the budget is a multiple of the decode
    step's cost-model latency, so decode tick inflation is bounded by
    ``budget_ratio`` regardless of prompt length. With no active decode
    streams there is nothing to starve and prefill runs nearly unthrottled.
    """

    chunk: int = 64
    budget_ratio: float = 2.0  # max decode-tick inflation while prefilling
    max_chunks: int = 4        # hard cap per tick with active decodes
    idle_burst: int = 16       # chunks per tick when no decode is active

    def chunks_this_tick(self, cfg, *, n_decoding: int, pending_chunks: int,
                         context: int, n_chips: int = 1) -> int:
        if pending_chunks <= 0:
            return 0
        if n_decoding <= 0:
            return min(pending_chunks, self.idle_burst)
        from repro.core.costmodel import estimate_decode, estimate_prefill

        dec = estimate_decode(cfg, n_decoding, context,
                              n_chips=n_chips).latency_s
        pre = estimate_prefill(cfg, 1, self.chunk,
                               n_chips=n_chips).latency_s
        budget = max(self.budget_ratio - 1.0, 0.0) * dec
        n = int(budget // max(pre, 1e-12))
        return max(1, min(n, self.max_chunks, pending_chunks))


# ---------------------------------------------------------------------------
# event-driven simulator
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    completed: List[Job]
    makespan: float

    @property
    def qps(self) -> float:
        return len(self.completed) / self.makespan if self.makespan else 0.0

    def latencies(self) -> List[float]:
        return [j.finish - j.arrival for j in self.completed]

    def mean_latency(self) -> float:
        ls = self.latencies()
        return sum(ls) / len(ls) if ls else 0.0

    def p99_latency(self) -> float:
        ls = sorted(self.latencies())
        return ls[int(0.99 * (len(ls) - 1))] if ls else 0.0

    def mean_jct(self) -> float:
        return self.mean_latency()

    def sla_attainment(self) -> float:
        with_sla = [j for j in self.completed if j.sla_s > 0]
        if not with_sla:
            return 1.0
        ok = sum(1 for j in with_sla if j.finish - j.arrival <= j.sla_s)
        return ok / len(with_sla)

    def mean_slowdown(self) -> float:
        """Mean (observed service / isolated service) for completed jobs —
        Fig. 3's 'latency degradation'."""
        vals = [
            (j.finish - j.start) / j.service_s
            for j in self.completed
            if j.start >= 0 and j.service_s > 0
        ]
        return sum(vals) / len(vals) if vals else 1.0


class MISDSimulator:
    """Event-driven co-location simulator over a set of Devices."""

    def __init__(self, devices: List[Device], scheduler: Scheduler):
        self.devices = devices
        self.scheduler = scheduler

    def run(self, jobs: Sequence[Job], until: float = float("inf")) -> SimResult:
        arrivals = sorted(jobs, key=lambda j: j.arrival)
        queue: List[Job] = []
        completed: List[Job] = []
        now = 0.0
        ai = 0
        n_jobs = len(arrivals)

        def try_schedule():
            nonlocal queue
            # preemptions first
            for victim, dev in self.scheduler.preempt(queue, self.devices, now):
                dev.running.remove(victim)
                victim.preemptions += 1
                victim.device = None
                queue.append(victim)
            remaining_q = []
            for job in self.scheduler.order(queue, now):
                dev = self.scheduler.place(job, self.devices, now)
                if dev is not None and dev.free_slots > 0:
                    if job.start < 0:
                        job.start = now
                    job.device = dev.name
                    dev.running.append(job)
                else:
                    remaining_q.append(job)
            queue = remaining_q

        while len(completed) < n_jobs and now < until:
            try_schedule()
            # next arrival time
            t_arr = arrivals[ai].arrival if ai < n_jobs else float("inf")
            # next finish time under current rates
            t_fin = float("inf")
            for d in self.devices:
                rates = d.rates()
                for j, r in zip(d.running, rates):
                    if r > 0:
                        t_fin = min(t_fin, now + j.remaining / r)
            t_next = min(t_arr, t_fin)
            if t_next == float("inf"):
                break  # deadlock: nothing running, nothing arriving
            dt = t_next - now
            # advance progress
            for d in self.devices:
                rates = d.rates()
                for j, r in zip(d.running, rates):
                    j.remaining -= dt * r
            now = t_next
            # arrivals
            while ai < n_jobs and arrivals[ai].arrival <= now + 1e-12:
                queue.append(arrivals[ai])
                ai += 1
            # completions
            for d in self.devices:
                done = [j for j in d.running if j.remaining <= 1e-9]
                for j in done:
                    d.running.remove(j)
                    j.finish = now
                    completed.append(j)
        return SimResult(completed, now)
