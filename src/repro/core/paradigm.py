"""The survey's taxonomy (Fig. 2) as a first-class object.

Instance (I) x Device (D) cardinality picks the computing paradigm; each
paradigm maps to an executor in this framework. ``classify`` routes a
deployment description to its quadrant; ``describe`` documents the mapping
(also used by the README generator and tests).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Tuple


class Paradigm(enum.Enum):
    SISD = "single-instance single-device"
    MISD = "multi-instance single-device"
    SIMD = "single-instance multi-device"
    MIMD = "multi-instance multi-device"


_EXECUTORS = {
    Paradigm.SISD: "repro.serving.engine.ServingEngine (one model, one chip/meshlet)",
    Paradigm.MISD: "repro.core.misd: MISDSimulator + MeshPartitioner (multi-tenant co-location)",
    Paradigm.SIMD: "repro.core.simd: pjit sharding rules + DLRM distributed embedding",
    Paradigm.MIMD: "repro.core.mimd.ServiceRouter over instance pools",
}


def classify(n_instances: int, n_devices: int) -> Paradigm:
    if n_instances <= 1 and n_devices <= 1:
        return Paradigm.SISD
    if n_instances > 1 and n_devices <= 1:
        return Paradigm.MISD
    if n_instances <= 1 and n_devices > 1:
        return Paradigm.SIMD
    return Paradigm.MIMD


def executor_for(p: Paradigm) -> str:
    return _EXECUTORS[p]


@dataclass(frozen=True)
class Deployment:
    """A deployment point in the taxonomy plane."""

    model: str
    n_instances: int
    n_devices: int

    @property
    def paradigm(self) -> Paradigm:
        return classify(self.n_instances, self.n_devices)
