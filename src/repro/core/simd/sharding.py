"""SIMD model-parallel sharding rules (survey §4: "efficient model
sharding" is the crux of distributed inference).

Maps every param/cache/batch leaf to a ``PartitionSpec`` over the
production mesh axes:

  * `model`  — tensor-parallel axis: FFN hidden, attention projections,
    vocab, expert hidden (or the expert axis under expert-parallel).
  * `data`   — batch for activations; FSDP-style second weight axis for
    models too large for 1-D sharding (grok-1, llama4: params/16 > HBM).
  * `pod`    — outer data-parallel axis (multi-pod); params replicated
    across pods.

Dims are sharded only when divisible by the axis size — the fallback is
replication, which keeps every (arch x shape x mesh) combination lowering;
the roofline pass then shows where replication hurts (hillclimb targets).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hardware import TPU_V5E


@dataclass(frozen=True)
class ShardingPolicy:
    model_axis: str = "model"
    data_axis: str = "data"
    batch_axes: Tuple[str, ...] = ("data",)  # ("pod","data") multi-pod
    fsdp: bool = False  # 2-D weight sharding (data x model)
    expert_parallel: bool = False
    model_size: int = 16
    data_size: int = 16
    # "hd" | "seq" (flash-decoding length-parallel) | "kv_head" (serving:
    # pool pages partition over kv heads — GQA einsums keep the kv-head
    # dim as a batch dim, so per-shard attention math is bit-identical to
    # the single-device trace)
    kv_shard: str = "hd"
    # Bit-exact profile (sharded serving): shard ONLY leaves whose
    # per-device math reproduces the single-device reduction order —
    # output-dim (_COL) projections, the vocab axis of embed/lm_head, KV
    # on the kv-head axis, and (under expert_parallel) the expert axis of
    # MoE weights. Contraction-dim (_ROW) weights stay REPLICATED so GSPMD
    # all-gathers activations (pure concatenation, bitwise safe) instead
    # of psum-reducing partial matmuls (reduction-order drift). Trades
    # per-chip FLOPs on the down-projections for stream bit-identity.
    exact: bool = False


def make_policy(cfg, mesh: Mesh, *, fsdp: Optional[bool] = None) -> ShardingPolicy:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axes.get("model", 1)
    data_n = axes.get("data", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    if fsdp is None:
        wb = 2 if cfg.dtype == "bfloat16" else 4
        per_dev = cfg.param_count() * wb / max(model_n, 1)
        fsdp = per_dev > 0.5 * TPU_V5E.hbm_bytes
    return ShardingPolicy(
        batch_axes=batch_axes,
        fsdp=fsdp,
        expert_parallel=cfg.moe_expert_parallel,
        model_size=model_n,
        data_size=data_n,
    )


def serving_policy(cfg, mesh: Mesh) -> ShardingPolicy:
    """Policy for a sharded ``ServingEngine`` replica: the bit-exact
    profile (see ``ShardingPolicy.exact``) with paged KV pools partitioned
    over the kv-head axis. Page tables and allocator bookkeeping stay
    host-side and layout-identical, so the paging/prefix/preemption stack
    is topology-blind."""
    import dataclasses as _dc

    return _dc.replace(make_policy(cfg, mesh, fsdp=False),
                       exact=True, kv_shard="kv_head")


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _key_path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(f"[{p.idx}]")
        else:
            names.append(str(p))
    return tuple(names)


# weight-name classes
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_gate_branch",
        "w_lin_branch", "w_a", "w_x", "lm_head"}  # (in, OUT) -> model on -1
_ROW = {"wo", "w_down", "out_proj", "w_out"}  # (IN, out) -> model on -2
_VEC_MODEL = {"Lambda", "b_a", "b_x", "norm_scale"}  # sharded feature vecs


def _param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
                pol: ShardingPolicy, stacked: bool):
    name = names[-1]
    nd = len(shape)
    lead = ("layer",) if stacked else ()  # placeholder, replaced by None
    m, d = pol.model_size, pol.data_size

    def out(*spec):
        spec = (None,) * len(lead) + spec
        spec = spec + (None,) * (nd - len(spec))
        assert len(spec) == nd, (names, shape, spec)
        return P(*spec)

    core = shape[len(lead):]

    if pol.exact:
        # bit-exact profile: no contraction-dim sharding anywhere. _COL
        # outputs and the embed/lm_head vocab axis shard (per-shard dots
        # keep the full contraction, identical reduction order); MoE
        # expert weights shard the expert axis under expert_parallel (the
        # combine psum only ever adds a token's <=k nonzero expert terms
        # plus exact zeros). Everything else replicates.
        if name == "embed":
            return out("model" if _div(core[0], m) else None, None)
        if name in _COL or name in _ROW:
            if len(core) == 3:  # MoE expert weights (E, d, ff)/(E, ff, d)
                if pol.expert_parallel and _div(core[0], m):
                    return out("model", None, None)
                if name in _COL and _div(core[2], m):
                    return out(None, None, "model")  # ff is an output dim
                return out(None, None, None)  # w_down: ff is contracted
            if len(core) == 2 and name in _COL and _div(core[1], m):
                return out(None, "model")
        return out(*([None] * len(core)))

    if name == "embed":
        v, dm = core
        sv = "model" if _div(v, m) else None
        sd = "data" if (pol.fsdp and _div(dm, d)) else None
        return out(sv, sd)
    if name == "router":
        return out(None, None)
    if name in ("conv_w",):
        c = core[-1]
        return out(None, "model" if _div(c, m) else None)
    if name in _VEC_MODEL and len(core) == 1:
        return out("model" if _div(core[0], m) else None)
    if name in ("A_log", "D", "dt_bias", "scale", "bias"):
        return out(*([None] * len(core)))
    if name in _COL or name in _ROW:
        if len(core) == 3:  # MoE expert weights (E, d, ff) / (E, ff, d)
            e = core[0]
            if pol.expert_parallel and _div(e, m):
                se = "model"
                sd = ("data" if (pol.fsdp and _div(core[1], d)) else None)
                return out(se, sd, None)
            # ff-sharded experts (+ FSDP second axis on d)
            ff_ax = 2 if name in _COL else 1
            d_ax = 1 if name in _COL else 2
            spec3 = [None, None, None]
            if _div(core[ff_ax], m):
                spec3[ff_ax] = "model"
            if pol.fsdp and _div(core[d_ax], d):
                spec3[d_ax] = "data"
            return out(*spec3)
        if len(core) == 2:
            o_ax = 1 if name in _COL else 0
            i_ax = 1 - o_ax
            spec2 = [None, None]
            if _div(core[o_ax], m):
                spec2[o_ax] = "model"
            if pol.fsdp and _div(core[i_ax], d):
                spec2[i_ax] = "data"
            return out(*spec2)
    return out(*([None] * len(core)))


def param_pspecs(cfg, param_tree, pol: ShardingPolicy):
    """PartitionSpec tree matching ``param_tree`` (arrays or SDS)."""

    def spec_for(path, leaf):
        names = _key_path_names(path)
        stacked = "body" in names  # scanned stacks carry a leading layer dim
        return _param_spec(names, tuple(leaf.shape), pol, stacked)

    flat, tdef = jax.tree_util.tree_flatten_with_path(param_tree)
    return tdef.unflatten([spec_for(p, l) for p, l in flat])


def opt_pspecs(cfg, opt_tree, pol: ShardingPolicy):
    """Optimizer state: ZeRO-style — force 2-D (fsdp) sharding so fp32
    master/m/v never exceed per-device HBM."""
    import dataclasses as _dc

    pol2 = _dc.replace(pol, fsdp=True)

    def spec_for(path, leaf):
        names = _key_path_names(path)
        if len(leaf.shape) == 0:  # step counter
            return P()
        stacked = "body" in names
        return _param_spec(names, tuple(leaf.shape), pol2, stacked)

    flat, tdef = jax.tree_util.tree_flatten_with_path(opt_tree)
    return tdef.unflatten([spec_for(p, l) for p, l in flat])


def _batch_dim_spec(b: int, pol: ShardingPolicy, mesh_axes: dict):
    n = 1
    for a in pol.batch_axes:
        n *= mesh_axes.get(a, 1)
    if _div(b, n):
        return pol.batch_axes if len(pol.batch_axes) > 1 else pol.batch_axes[0]
    if _div(b, mesh_axes.get("data", 1)):
        return "data"
    return None


def batch_pspecs(cfg, batch_tree, pol: ShardingPolicy, mesh: Mesh):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec_for(path, leaf):
        names = _key_path_names(path)
        name = names[-1]
        shape = tuple(leaf.shape)
        if name == "positions":  # (3, B, S)
            bs = _batch_dim_spec(shape[1], pol, axes)
            return P(None, bs, *([None] * (len(shape) - 2)))
        if name == "pos":
            return P(*([None] * len(shape)))
        bs = _batch_dim_spec(shape[0], pol, axes)
        return P(bs, *([None] * (len(shape) - 1)))

    flat, tdef = jax.tree_util.tree_flatten_with_path(batch_tree)
    return tdef.unflatten([spec_for(p, l) for p, l in flat])


def cache_pspecs(cfg, cache_tree, pol: ShardingPolicy, mesh: Mesh):
    """KV/state caches: batch dim -> batch axes; head_dim / feature dim ->
    model axis (always divisible: hd in {64,80,128,256})."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = pol.model_size

    def spec_for(path, leaf):
        names = _key_path_names(path)
        name = names[-1]
        shape = tuple(leaf.shape)
        stacked = "body" in names
        off = 1 if stacked else 0
        if name == "pos":
            return P(*([None] * len(shape)))
        core = shape[off:]
        bs = _batch_dim_spec(core[0], pol, axes)
        spec = [None] * off + [bs] + [None] * (len(core) - 1)
        if name in ("k", "v"):
            # (B, W, kv, hd): shard hd on model — the kv-head dim under
            # the serving bit-exact profile (kv is a batch dim of the
            # grouped-GQA einsums) — or the sequence dim under the
            # flash-decoding layout (perf lever "kv_seq")
            if pol.kv_shard == "kv_head" and _div(core[2], m):
                spec[off + 2] = "model"
            elif pol.kv_shard == "seq" and _div(core[1], m):
                spec[off + 1] = "model"
            elif pol.kv_shard == "hd" and _div(core[3], m):
                spec[off + 3] = "model"
        elif name in ("k_scale", "v_scale"):
            # (B, W, kv, 1): per-vector scales of the int8 cache follow
            # the value leaves' W/kv layout (the trailing singleton is
            # never sharded)
            if pol.kv_shard == "seq" and _div(core[1], m):
                spec[off + 1] = "model"
            elif pol.kv_shard == "kv_head" and _div(core[2], m):
                spec[off + 2] = "model"
        elif pol.exact:
            pass  # recurrent state/conv: replicated (scan psums reorder)
        elif name == "conv":
            if _div(core[-1], m):
                spec[off + len(core) - 1] = "model"
        elif name == "state":
            if len(core) == 4 and _div(core[1], m):  # ssd (B, H, P, N)
                spec[off + 1] = "model"
            elif len(core) == 2 and _div(core[1], m):  # rglru (B, L)
                spec[off + 1] = "model"
        return P(*spec)

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return tdef.unflatten([spec_for(p, l) for p, l in flat])


def paged_cache_pspecs(cfg, cache_tree, pol: ShardingPolicy, mesh: Mesh):
    """Paged-KV cache layout: shared pools (P, page_size, kv, hd) shard
    the kv-head dim over ``model`` (falling back to hd, then replication,
    on divisibility); the page table and per-slot positions REPLICATE so
    the host-side ``PageAllocator``/``PrefixIndex`` see a layout identical
    to the single-device engine. Pool pages are never batch-sharded —
    page ids are global, and any slot's table row must reach any page."""
    m = pol.model_size

    def spec_for(path, leaf):
        names = _key_path_names(path)
        name = names[-1]
        shape = tuple(leaf.shape)
        off = 1 if "body" in names else 0  # stacked pools: leading layer dim
        core = shape[off:]
        spec = [None] * len(shape)
        if name in ("k", "v") and len(core) == 4:
            if pol.kv_shard != "hd" and _div(core[2], m):
                spec[off + 2] = "model"
            elif _div(core[3], m):
                spec[off + 3] = "model"
        elif name in ("k_scale", "v_scale") and len(core) == 4:
            # (P, ps, kv, 1): int8 pools' per-vector scale pages shard
            # the kv-head dim with the value pools; under an hd-sharded
            # value layout the (hd-less) scales replicate
            if pol.kv_shard != "hd" and _div(core[2], m):
                spec[off + 2] = "model"
        return P(*spec)

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return tdef.unflatten([spec_for(p, l) for p, l in flat])


def to_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
