from repro.core.simd.embedding import (
    batch_specs,
    dlrm_forward,
    init_dlrm,
    lookup_traffic_bytes,
    shard_specs,
)
from repro.core.simd.offload import OffloadPlan, effective_bandwidth, plan_offload, zipf_hit_rate
from repro.core.simd.sharding import (
    ShardingPolicy,
    batch_pspecs,
    cache_pspecs,
    make_policy,
    opt_pspecs,
    param_pspecs,
    to_shardings,
)
