"""Heterogeneous-memory inference (survey §4.3.2, [25][47][49]).

TPU analogue of the DRAM/SSD embedding tier: HBM <-> host-DRAM offload.
Hot embedding rows are cached in HBM; cold rows stream from host memory
over PCIe-class links. The policy question ([47] FlashEmbedding, [49]
RecSSD) is placement + caching; with Zipf-distributed accesses a small HBM
cache yields near-DRAM average latency — reproduced by
``effective_bandwidth``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

HBM_BW = 819e9
HOST_BW = 32e9  # PCIe-class host link
SSD_BW = 3e9


@dataclass
class TierSpec:
    name: str
    bandwidth: float
    capacity_bytes: float


def zipf_hit_rate(cache_rows: int, total_rows: int, alpha: float = 0.8) -> float:
    """P(access hits the `cache_rows` hottest rows) under Zipf(alpha)."""
    if cache_rows >= total_rows:
        return 1.0
    # harmonic approximations
    def h(n):
        if alpha == 1.0:
            return math.log(n) + 0.5772
        return (n ** (1 - alpha) - 1) / (1 - alpha) + 1
    return h(cache_rows) / h(total_rows)


def effective_bandwidth(hbm_frac: float, total_rows: int,
                        alpha: float = 0.8, cold_bw: float = HOST_BW) -> float:
    """Average row-fetch bandwidth with the hottest `hbm_frac` rows in HBM."""
    hit = zipf_hit_rate(int(hbm_frac * total_rows), total_rows, alpha)
    # harmonic mean of tier bandwidths weighted by miss ratio
    return 1.0 / (hit / HBM_BW + (1 - hit) / cold_bw)


@dataclass
class OffloadPlan:
    hbm_rows: int
    host_rows: int
    hit_rate: float
    effective_bw: float
    slowdown_vs_hbm: float


def plan_offload(table_rows: int, row_bytes: int, hbm_budget_bytes: float,
                 alpha: float = 0.8, cold_bw: float = HOST_BW) -> OffloadPlan:
    hbm_rows = min(table_rows, int(hbm_budget_bytes // row_bytes))
    hit = zipf_hit_rate(hbm_rows, table_rows, alpha)
    eff = 1.0 / (hit / HBM_BW + (1 - hit) / cold_bw)
    return OffloadPlan(
        hbm_rows=hbm_rows,
        host_rows=table_rows - hbm_rows,
        hit_rate=hit,
        effective_bw=eff,
        slowdown_vs_hbm=HBM_BW / eff,
    )
