"""Distributed DLRM inference (survey §4.3.1 Fig. 7, [26] Lui et al.).

The survey's flagship SIMD workload: embedding tables dominate weights
(80–95%) with almost no FLOPs. The paper's torch-RPC fan-out becomes a
sharded table + collectives inside one pjit program here: tables live
row-sharded on the `model` axis; lookups become a GSPMD gather whose data
motion is exactly the RPC pattern of Fig. 7 (request ids out, embedding
rows back).

`dlrm_forward` is the full model (bottom MLP -> sparse lookups ->
pairwise-interaction -> top MLP); `shard_specs` gives the deployment
layout. The fig7 benchmark compares single-host (replicated) vs scale-out
(sharded) rooflines with the cost model.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def init_dlrm(cfg, key):
    assert cfg.bottom_mlp[-1] == cfg.embed_dim, (
        "bottom MLP must project dense features to embed_dim")
    ks = jax.random.split(key, 4)
    emb = jax.random.normal(
        ks[0], (cfg.num_tables, cfg.rows_per_table, cfg.embed_dim), F32
    ) * 0.01

    def mlp(key, dims):
        keys = jax.random.split(key, len(dims) - 1)
        return [
            {
                "w": jax.random.normal(k, (a, b), F32) * (a ** -0.5),
                "b": jnp.zeros((b,), F32),
            }
            for k, a, b in zip(keys, dims[:-1], dims[1:])
        ]

    bot_dims = (cfg.num_dense_features,) + cfg.bottom_mlp
    num_int = (cfg.num_tables + 1) * cfg.num_tables // 2
    top_dims = (num_int + cfg.embed_dim,) + cfg.top_mlp
    return {
        "tables": emb,
        "bottom": mlp(ks[1], bot_dims),
        "top": mlp(ks[2], top_dims),
    }


def _mlp_apply(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def dlrm_forward(cfg, params, batch):
    """batch: dense (B, 13) float; sparse (B, T, multi_hot) int32 row ids.
    Returns CTR logit (B,)."""
    dense, sparse = batch["dense"], batch["sparse"]
    b = dense.shape[0]
    bot = _mlp_apply(params["bottom"], dense, final_act=True)  # (B, E)

    # sparse lookups: gather rows from each (sharded) table, sum multi-hot
    # tables: (T, R, E); sparse: (B, T, M)
    def lookup(table, ids):  # (R, E), (B, M)
        return jnp.take(table, ids, axis=0).sum(axis=1)  # (B, E)

    emb = jax.vmap(lookup, in_axes=(0, 1), out_axes=1)(
        params["tables"], sparse)  # (B, T, E)

    # pairwise dot interaction over [bottom] + T embeddings
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, T+1, E)
    inter = jnp.einsum("bte,bse->bts", z, z)  # (B, T+1, T+1)
    iu, ju = np.triu_indices(z.shape[1], k=1)
    inter_flat = inter[:, iu, ju]  # (B, T(T+1)/2)

    top_in = jnp.concatenate([bot, inter_flat], axis=-1)
    out = _mlp_apply(params["top"], top_in)
    return out[:, 0]


def shard_specs(cfg) -> Dict:
    """Deployment layout: tables row-sharded over `model` (the scale-out
    dimension of [26]); MLPs replicated (they are tiny)."""
    return {
        "tables": P(None, "model", None),
        "bottom": [{"w": P(None, None), "b": P(None)} for _ in
                   range(len(cfg.bottom_mlp))],
        "top": [{"w": P(None, None), "b": P(None)} for _ in
                range(len(cfg.top_mlp))],
    }


def batch_specs(cfg) -> Dict:
    return {"dense": P("data", None), "sparse": P("data", None, None)}


def lookup_traffic_bytes(cfg, batch: int) -> float:
    """Collective traffic per query batch for the sharded layout — the
    'RPC fan-out' volume of Fig. 7: each lookup returns one embed_dim row."""
    rows = batch * cfg.num_tables * cfg.multi_hot
    return rows * cfg.embed_dim * 4.0
