"""SISD baseline: one model, one device — the survey's 'traditional'
quadrant, kept as the comparison baseline for every MISD/SIMD benchmark."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core.costmodel import WorkEstimate, estimate_decode, estimate_prefill
from repro.core.hardware import Chip, TPU_V5E
from repro.core.misd.scheduler import Device, FIFOScheduler, Job, MISDSimulator, SimResult


def sisd_device(name: str = "chip0") -> Device:
    """Single-tenant device: max_tenants=1 (no co-location)."""
    return Device(name, max_tenants=1)


def run_single_tenant(jobs: List[Job]) -> SimResult:
    """Serialize jobs on one device — the SISD baseline for Fig. 3."""
    sim = MISDSimulator([sisd_device()], FIFOScheduler())
    return sim.run(jobs)


def run_multi_tenant(jobs: List[Job], max_tenants: int = 2,
                     scheduler=None) -> SimResult:
    sim = MISDSimulator(
        [Device("chip0", max_tenants=max_tenants)],
        scheduler or FIFOScheduler())
    return sim.run(jobs)
