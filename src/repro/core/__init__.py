"""The survey's contribution — the SISD/MISD/SIMD/MIMD taxonomy — as a
composable system: cost model + hardware constants at the root, one
subpackage per quadrant (misd/, simd/, mimd/) and the SISD baseline."""
from repro.core.costmodel import (
    WorkEstimate,
    estimate,
    estimate_decode,
    estimate_prefill,
    estimate_train,
    model_flops,
)
from repro.core.hardware import CHIPS, TPU_V5E
from repro.core.paradigm import Deployment, Paradigm, classify, executor_for
