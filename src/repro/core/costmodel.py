"""Analytic latency/resource model for inference and training work.

Every scheduling layer of the taxonomy consumes this model:
  * MISD: per-job demand vectors (compute vs memory) -> interference
  * MIMD: per-(model, shape) latency estimates -> routing
  * SIMD: collective traffic per sharding layout -> scale-out efficiency
  * benchmarks: Fig. 3 / Fig. 4 reproductions

The model is the standard three-term roofline over the chip constants in
``repro.core.hardware``; the container has no TPU, so the simulator's
"wall clock" is this model's output (trends are the reproduction target —
DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core.hardware import Chip, DISPATCH_OVERHEAD_S, TPU_V5E


@dataclass(frozen=True)
class WorkEstimate:
    """Roofline terms for one step of work on a device (group)."""

    flops: float
    hbm_bytes: float
    collective_bytes: float = 0.0
    chip: Chip = TPU_V5E
    n_chips: int = 1

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chip.peak_flops * self.n_chips)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chip.hbm_bw * self.n_chips)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chip.link_bw * self.n_chips)

    @property
    def latency_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s) + DISPATCH_OVERHEAD_S

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def demand(self) -> tuple:
        """(compute, memory) demand fractions in [0,1] — how much of the
        device each resource class is busy during this job's latency.
        Input to the MISD interference model."""
        lat = self.latency_s
        return (min(1.0, self.compute_s / lat), min(1.0, self.memory_s / lat))

    def demand_at(self, occupancy: float) -> tuple:
        """Demand scaled by single-stream occupancy: a lone small query
        cannot saturate a large accelerator (the survey's §3 premise —
        ResNet's 4 GFLOPs vs 130 TFLOPS). Dependency stalls and dispatch
        gaps leave the device idle `1-occupancy` of the time; co-tenants
        fill those gaps."""
        c, m = self.demand
        return (c * occupancy, m * occupancy)


def stream_occupancy(batch: int, *, half_sat: float = 16.0,
                     floor: float = 0.30, cap: float = 0.95) -> float:
    """Occupancy of a single inference stream as a function of batch size:
    rises toward `cap` as batching amortizes dispatch/dependency stalls."""
    return min(cap, floor + (1.0 - floor) * batch / (batch + half_sat))


def _dtype_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def _n_attn_layers(cfg) -> int:
    """Layers that keep a KV cache (hybrids only count their attention
    blocks)."""
    if cfg.arch_type != "hybrid":
        return cfg.num_layers
    pat = cfg.block_pattern or ("rglru", "rglru", "local_attn")
    return cfg.num_layers * sum(b == "local_attn" for b in pat) // len(pat)


def kv_bytes_per_token(cfg, kv_cache_dtype: str = "") -> float:
    """HBM bytes one cached token costs across every attention layer (the
    unit of the paged-KV capacity plan: a rolling cache pays this for a
    full window per slot; a paged cache only for resident tokens).

    ``kv_cache_dtype`` is the POOL storage dtype ("" = model dtype;
    "int8" = quantized serving pools: 1 byte per element plus one fp32
    scale per (token, kv-head) vector). The loud assert is deliberate —
    a silently-wrong per-token estimate over-admits the whole pool."""
    if not cfg.has_attention:
        return 0.0
    hd = cfg.resolved_head_dim
    if kv_cache_dtype == "":
        per_vec = hd * _dtype_bytes(cfg)
    elif kv_cache_dtype == "int8":
        per_vec = hd * 1 + 4.0  # int8 values + one fp32 scale per vector
    else:
        raise AssertionError(
            f"kv_bytes_per_token: unknown kv_cache_dtype "
            f"{kv_cache_dtype!r} — capacity planning would over-admit")
    return 2.0 * _n_attn_layers(cfg) * cfg.num_kv_heads * per_vec


def _attn_flops(cfg, batch: int, s_q: int, s_kv: int) -> float:
    if not cfg.has_attention:
        return 0.0
    hd = cfg.resolved_head_dim
    # score + value matmuls, causal halves the pair count for s_q == s_kv
    pairs = s_q * s_kv * (0.5 if (cfg.causal and s_q == s_kv) else 1.0)
    if cfg.arch_type == "hybrid":
        pairs = min(pairs, s_q * cfg.local_window)
    return 4.0 * batch * _n_attn_layers(cfg) * cfg.num_heads * pairs * hd


def _axes_chips(mesh_axes) -> int:
    n = 1
    for _, size in mesh_axes:
        n *= int(size)
    return n


def collective_bytes_per_axis(cfg, tokens: int, *, mesh_axes) -> dict:
    """Per-mesh-axis collective traffic (bytes, per participating chip)
    for one forward pass over ``tokens`` tokens, keyed off the mesh shape
    ``((axis, size), ...)`` — the sharded-serving term of the roofline.

    ``model`` axis (tensor parallel): two activation collectives per layer
    (attention-output and MLP-hidden all-gather/reduce of the (tokens, d)
    residual), ring cost ``(n-1)/n`` of the buffer each. MoE archs add
    the expert-parallel all-to-all: each routed token's activation crosses
    the axis twice (dispatch + combine) for each of its top-k experts.
    ``data``/``pod`` axes: batch-sharded activations need no per-step
    collective (weights are replicated across them at inference)."""
    wb = _dtype_bytes(cfg)
    out = {}
    for name, n in mesh_axes:
        n = int(n)
        traffic = 0.0
        if name == "model" and n > 1:
            ring = (n - 1) / n
            traffic = 4.0 * cfg.num_layers * tokens * cfg.d_model * wb * ring
            if cfg.arch_type == "moe" and cfg.num_experts:
                moe_layers = cfg.num_layers // max(1, cfg.moe_layer_period)
                k = max(1, cfg.experts_per_token)
                traffic += (2.0 * moe_layers * tokens * k * cfg.d_model
                            * wb * ring)
        out[name] = traffic
    return out


def collective_s_per_axis(cfg, tokens: int, *, mesh_axes,
                          chip: Chip = TPU_V5E) -> dict:
    """Per-axis collective seconds for one forward pass (per-chip link
    bandwidth; axes move bytes concurrently only if XLA overlaps them —
    the conservative sum is what ``WorkEstimate.collective_s`` sees)."""
    per_axis = collective_bytes_per_axis(cfg, tokens, mesh_axes=mesh_axes)
    return {a: b / chip.link_bw for a, b in per_axis.items()}


def estimate_prefill(cfg, batch: int, seq: int, *, chip: Chip = TPU_V5E,
                     n_chips: int = 1, collective_bytes: float = 0.0,
                     prefix_hit: int = 0, mesh_axes=None) -> WorkEstimate:
    """``prefix_hit`` > 0 models suffix-offset prefill over a shared-prefix
    KV cache hit: only ``seq - prefix_hit`` tokens flow through the model
    (their attention still spans all ``seq`` keys), and the cached prefix
    KV is READ from HBM instead of computed. This is the discount the
    cluster's prefix-affinity routing scores with — a replica already
    holding a request's template predicts a cheaper prefill."""
    n_active = cfg.active_param_count()
    new = max(1, seq - prefix_hit) if prefix_hit > 0 else seq
    flops = 2.0 * n_active * batch * new + _attn_flops(cfg, batch, new, seq)
    wb = _dtype_bytes(cfg)
    act_bytes = 12.0 * batch * new * cfg.d_model * wb  # residual traffic
    hbm = cfg.param_count() * wb + act_bytes
    if prefix_hit > 0:
        hbm += kv_bytes_per_token(cfg) * min(prefix_hit, seq) * batch
    if mesh_axes is not None:
        n_chips = _axes_chips(mesh_axes)
        if collective_bytes == 0.0:
            collective_bytes = sum(collective_bytes_per_axis(
                cfg, batch * new, mesh_axes=mesh_axes).values())
    return WorkEstimate(flops, hbm, collective_bytes, chip, n_chips)


def estimate_decode(cfg, batch: int, context: int, *, chip: Chip = TPU_V5E,
                    n_chips: int = 1, window: int = 0,
                    collective_bytes: float = 0.0,
                    mesh_axes=None) -> WorkEstimate:
    n_active = cfg.active_param_count()
    wb = _dtype_bytes(cfg)
    kv_len = min(context, window) if window else context
    flops = 2.0 * n_active * batch + _attn_flops(cfg, batch, 1, kv_len)
    kv_bytes = 0.0
    if cfg.has_attention:
        if cfg.arch_type == "hybrid":
            kv_len = min(kv_len, cfg.local_window)
        kv_bytes = (2.0 * batch * _n_attn_layers(cfg) * kv_len
                    * cfg.num_kv_heads * cfg.resolved_head_dim * wb)
    if cfg.arch_type in ("ssm", "hybrid"):
        # recurrent state read+write
        state = batch * cfg.num_layers * cfg.d_model * 4 * 4.0
        kv_bytes += state
    hbm = cfg.param_count() * wb + kv_bytes
    if mesh_axes is not None:
        n_chips = _axes_chips(mesh_axes)
        if collective_bytes == 0.0:
            collective_bytes = sum(collective_bytes_per_axis(
                cfg, batch, mesh_axes=mesh_axes).values())
    return WorkEstimate(flops, hbm, collective_bytes, chip, n_chips)


def estimate_train(cfg, batch: int, seq: int, *, chip: Chip = TPU_V5E,
                   n_chips: int = 1, collective_bytes: float = 0.0) -> WorkEstimate:
    n_active = cfg.active_param_count()
    flops = 6.0 * n_active * batch * seq + 3.0 * _attn_flops(cfg, batch, seq, seq)
    wb = _dtype_bytes(cfg)
    hbm = 3.0 * cfg.param_count() * (wb + 12) + 24.0 * batch * seq * cfg.d_model * wb
    if collective_bytes == 0.0 and n_chips > 1:
        collective_bytes = 2.0 * cfg.param_count() * 4  # grad all-reduce
    return WorkEstimate(flops, hbm, collective_bytes, chip, n_chips)


def estimate_backlog_s(cfg, *, queued_prefill_tokens: int,
                       decode_tokens_remaining: int, slots: int,
                       context: int, chip: Chip = TPU_V5E,
                       n_chips: int = 1, mesh_axes=None) -> float:
    """Seconds to drain an engine's outstanding work — the scalar the
    cluster frontend routes on (``ServingEngine.load_report``).

    Two terms: every queued/unfinished prefill token must flow through the
    prefill path once, and every remaining decode token costs a share of a
    batched decode tick (an engine with B slots emits up to B tokens per
    tick, so drain time is ``tokens / B`` ticks). Both terms are monotone
    in load, which is all routing needs; the cluster's closed loop
    (``InterferencePredictor.observe_latency``) absorbs the constant
    factor this model gets wrong on real hardware."""
    s = 0.0
    if queued_prefill_tokens > 0:
        s += estimate_prefill(cfg, 1, queued_prefill_tokens, chip=chip,
                              n_chips=n_chips, mesh_axes=mesh_axes).latency_s
    if decode_tokens_remaining > 0:
        b = max(1, slots)
        per_tick = estimate_decode(cfg, b, context, chip=chip,
                                   n_chips=n_chips,
                                   mesh_axes=mesh_axes).latency_s
        s += per_tick * decode_tokens_remaining / b
    return s


def suggest_health_timeout_s(cfg, *, slots: int, context: int,
                             chip: Chip = TPU_V5E, n_chips: int = 1,
                             ticks: int = 8) -> float:
    """Health-watchdog staleness budget for a replica of this shape: the
    cost-model time for ``ticks`` full-batch decode ticks. A healthy
    replica holding work advances its progress signature at least once
    per decode tick, so ``ticks`` missed ticks in a row is decisive
    evidence of a wedge, while transient stalls (a slow host at 2-4x)
    stay under the bar. Used by ``ClusterFrontend(health_timeout_s=...)``
    and ``launch/serve.py``."""
    per_tick = estimate_decode(cfg, max(1, slots), context, chip=chip,
                               n_chips=n_chips).latency_s
    return max(1, ticks) * per_tick


def estimate(cfg, shape, *, chip: Chip = TPU_V5E, n_chips: int = 1) -> WorkEstimate:
    """Estimate for an assigned ShapeConfig."""
    if shape.kind == "train":
        return estimate_train(cfg, shape.global_batch, shape.seq_len,
                              chip=chip, n_chips=n_chips)
    if shape.kind == "prefill":
        return estimate_prefill(cfg, shape.global_batch, shape.seq_len,
                                chip=chip, n_chips=n_chips)
    window = cfg.sliding_window_decode if shape.seq_len > 100_000 else 0
    return estimate_decode(cfg, shape.global_batch, shape.seq_len,
                           chip=chip, n_chips=n_chips, window=window)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS for the roofline report: 6·N·D train, 2·N·D inference
    (N = active params, D = tokens processed)."""
    mult = 6.0 if shape.kind == "train" else 2.0
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return mult * cfg.active_param_count() * tokens
