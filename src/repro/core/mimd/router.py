"""MIMD service routing (survey §2, DLIS [42]): route inference queries
across a cluster of model instances deployed on meshlets/pods.

The router "understands different models' requirements and places one or
multiple queries intelligently onto hardware": each model has an instance
pool (replicas on meshlets); routing policies are

  round-robin   — rotate through the pool, blind to load;
  least-loaded  — minimize the instance's instantaneous ``load()`` signal;
  p2c           — power-of-two-choices: sample two (seeded), keep the one
                  with the lower predicted completion;
  predicted     — minimize predicted completion over the whole pool.

``Instance`` is the simulation-facing replica (its load signal is the
``queue_s`` scalar the router itself maintains); live engines plug in via
``repro.serving.cluster.EngineInstance``, which overrides ``load()`` /
``predicted_completion()`` with real telemetry from
``ServingEngine.load_report()`` — the SAME router policies then run
unchanged over live engines. Tie-breaks are deterministic under the
constructor seed: ties on the routing key fall back to registration order,
never to dict/hash order. Autoscaling hooks grow/shrink pools from queue
pressure — the data-center management layer the survey notes is
underexplored for inference.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.misd.scheduler import Device, Job

POLICIES = ("least-loaded", "p2c", "round-robin", "predicted")


@dataclass
class Instance:
    """One deployed replica of a model on a meshlet."""

    name: str
    model: str
    device: Device
    queue_s: float = 0.0  # predicted backlog seconds
    draining: bool = False  # deregistered: finish in-flight, take no routes
    failed: bool = False  # declared dead (crash/hang): work fails over
    order: int = -1  # registration sequence (deterministic tie-break key)

    def load(self) -> float:
        """Instantaneous load signal for least-loaded routing (cheaper and
        noisier than ``predicted_completion`` — no per-job service term)."""
        return self.queue_s

    def prefix_hit_s(self, job: Job) -> float:
        """Prefix-affinity term: service seconds this replica would SKIP
        because it already holds the job's prompt prefix in its KV cache.
        The simulated instance has no cache, so the default is 0; live
        engines (``repro.serving.cluster.EngineInstance``) override it
        with a real ``PrefixIndex`` probe. Subtracted from the routing
        score, so template traffic gravitates to the replica that already
        paid for the prefix."""
        return 0.0

    def predicted_completion(self, job: Job) -> float:
        concurrency = len(self.device.running) + 1
        service = max(0.0, job.service_s - self.prefix_hit_s(job))
        return self.queue_s + service * concurrency / self.device.speed


class ServiceRouter:
    """Cluster-level query router over per-model instance pools."""

    def __init__(self, policy: str = "least-loaded", seed: int = 0):
        assert policy in POLICIES, f"unknown policy {policy!r} (want {POLICIES})"
        self.policy = policy
        self.pools: Dict[str, List[Instance]] = {}
        self._rr: Dict[str, int] = {}
        self._rng = random.Random(seed)
        self._next_order = 0

    def register(self, inst: Instance) -> Instance:
        inst.order = self._next_order
        self._next_order += 1
        inst.draining = False
        self.pools.setdefault(inst.model, []).append(inst)
        return inst

    def deregister(self, inst_or_name, model: Optional[str] = None) -> Optional[Instance]:
        """Retire an instance: mark it draining and remove it from its pool
        so it stops receiving routes (in-flight work finishes elsewhere —
        the caller keeps stepping it until empty). Accepts the instance or
        its name; returns the removed instance, or None if absent."""
        pools = ([self.pools.get(model, [])] if model is not None
                 else list(self.pools.values()))
        for pool in pools:
            for i, inst in enumerate(pool):
                if inst is inst_or_name or inst.name == inst_or_name:
                    pool.pop(i)
                    inst.draining = True
                    return inst
        return None

    def route(self, job: Job,
              eligible: Optional[set] = None) -> Optional[Instance]:
        """Pick a replica for ``job`` under the configured policy.
        ``eligible`` (instance names) restricts the candidate pool — the
        cluster frontend's circuit breaker passes it so a half-open
        recovering replica only sees bounded probe traffic. None = whole
        pool; an empty intersection returns None (caller holds the job)."""
        pool = self.pools.get(job.model)
        if pool and eligible is not None:
            pool = [i for i in pool if i.name in eligible]
        if not pool:
            return None
        if self.policy == "round-robin":
            i = self._rr.get(job.model, 0) % len(pool)
            self._rr[job.model] = i + 1
            chosen = pool[i]
        elif self.policy == "p2c":
            # the seeded sample order doubles as the tie-break (first of
            # the pair wins an exact tie): deterministic under the
            # constructor seed, yet persistent ties still spread; a pool
            # shrunk to one replica degrades to that replica
            pair = (self._rng.sample(pool, k=2) if len(pool) >= 2
                    else [pool[0]])
            chosen = min(pair, key=lambda x: x.predicted_completion(job))
        elif self.policy == "predicted":
            chosen = min(pool, key=lambda x: (x.predicted_completion(job),
                                              x.order))
        else:  # least-loaded: the seeded shuffle IS the tie-break, so
            # exact-tie loads spread out (deterministically under the seed)
            order = list(pool)
            self._rng.shuffle(order)
            chosen = min(order, key=lambda x: x.load())
        chosen.queue_s += job.service_s / chosen.device.speed
        return chosen

    def drain(self, inst: Instance, seconds: float):
        inst.queue_s = max(0.0, inst.queue_s - seconds)

    # -- autoscaling ---------------------------------------------------
    def pressure(self, model: str) -> float:
        """Mean predicted backlog seconds PER CHIP. The denominator is
        ``pool_chips``, not the replica count: a tp=8 replica is 8 chips
        of capacity, so the same queue spread over it is 8x less
        pressure than over a 1-chip replica — scale decisions must weigh
        hardware, not processes (each replica's ``device.speed`` mirrors
        its chip count; 1.0 for single-device engines, so homogeneous
        1-chip pools are numerically unchanged)."""
        pool = self.pools.get(model, [])
        if not pool:
            return float("inf")
        return sum(i.queue_s for i in pool) / max(1.0, self.pool_chips(model))

    def pool_chips(self, model: str) -> float:
        """Devices the pool occupies (each replica's ``device.speed``
        mirrors its chip count — 1 for single-device engines, the mesh
        size for sharded replicas via ``LoadReport.n_chips``). The
        data-center sizing denominator: a scale-out of one tp=8 replica
        costs 8 chips, not 1."""
        return sum(i.device.speed for i in self.pools.get(model, []))

    def want_scale(self, model: str, *, high_s: float = 1.0,
                   low_s: float = 0.05) -> int:
        """+1 = scale out, -1 = scale in, 0 = hold. Thresholds compare
        against chip-weighted ``pressure`` (backlog seconds per chip),
        so a pool of tp=8 replicas doesn't scale out 8x too eagerly —
        the ROADMAP-flagged replicas-vs-chips bug in scale decisions."""
        p = self.pressure(model)
        if p > high_s:
            return 1
        if p < low_s and len(self.pools.get(model, [])) > 1:
            return -1
        return 0
