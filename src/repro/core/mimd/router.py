"""MIMD service routing (survey §2, DLIS [42]): route inference queries
across a cluster of model instances deployed on meshlets/pods.

The router "understands different models' requirements and places one or
multiple queries intelligently onto hardware": each model has an instance
pool (replicas on meshlets); routing is least-loaded / power-of-two-choices
over predicted completion time from the cost model. Autoscaling hooks
grow/shrink pools from queue pressure — the data-center management layer
the survey notes is underexplored for inference.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import WorkEstimate
from repro.core.misd.scheduler import Device, Job


@dataclass
class Instance:
    """One deployed replica of a model on a meshlet."""

    name: str
    model: str
    device: Device
    queue_s: float = 0.0  # predicted backlog seconds

    def predicted_completion(self, job: Job) -> float:
        concurrency = len(self.device.running) + 1
        return self.queue_s + job.service_s * concurrency / self.device.speed


class ServiceRouter:
    """Cluster-level query router over per-model instance pools."""

    def __init__(self, policy: str = "least-loaded", seed: int = 0):
        assert policy in ("least-loaded", "p2c", "round-robin")
        self.policy = policy
        self.pools: Dict[str, List[Instance]] = {}
        self._rr: Dict[str, int] = {}
        self._rng = random.Random(seed)

    def register(self, inst: Instance):
        self.pools.setdefault(inst.model, []).append(inst)

    def route(self, job: Job) -> Optional[Instance]:
        pool = self.pools.get(job.model)
        if not pool:
            return None
        if self.policy == "round-robin":
            i = self._rr.get(job.model, 0) % len(pool)
            self._rr[job.model] = i + 1
            chosen = pool[i]
        elif self.policy == "p2c":
            a, b = self._rng.sample(pool, k=min(2, len(pool)))
            chosen = min((a, b), key=lambda x: x.predicted_completion(job))
        else:  # least-loaded (random tie-break so equal loads spread out)
            order = list(pool)
            self._rng.shuffle(order)
            chosen = min(order, key=lambda x: x.predicted_completion(job))
        chosen.queue_s += job.service_s / chosen.device.speed
        return chosen

    def drain(self, inst: Instance, seconds: float):
        inst.queue_s = max(0.0, inst.queue_s - seconds)

    # -- autoscaling ---------------------------------------------------
    def pressure(self, model: str) -> float:
        pool = self.pools.get(model, [])
        if not pool:
            return float("inf")
        return sum(i.queue_s for i in pool) / len(pool)

    def want_scale(self, model: str, *, high_s: float = 1.0,
                   low_s: float = 0.05) -> int:
        """+1 = scale out, -1 = scale in, 0 = hold."""
        p = self.pressure(model)
        if p > high_s:
            return 1
        if p < low_s and len(self.pools.get(model, [])) > 1:
            return -1
        return 0
