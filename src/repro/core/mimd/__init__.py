from repro.core.mimd.router import Instance, ServiceRouter
