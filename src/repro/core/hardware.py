"""Hardware constants for the target platform (TPU v5e) plus the survey's
comparison devices (Fig. 4). All roofline math reads from here."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float  # FLOP/s (bf16 for accelerators, fp32 for CPU)
    hbm_bw: float  # bytes/s
    hbm_bytes: float
    link_bw: float  # bytes/s per ICI/NVLink-class link
    tdp_watts: float


TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 2 ** 30,
    link_bw=50e9,
    tdp_watts=200.0,
)

# Survey Fig. 4 comparison points (nominal public specs)
XEON_4116 = Chip("xeon-4116", 0.8e12, 115e9, 192 * 2 ** 30, 10e9, 85.0)
RTX_2080TI = Chip("rtx-2080ti", 26.9e12, 616e9, 11 * 2 ** 30, 16e9, 250.0)
V100 = Chip("v100", 130e12, 900e9, 32 * 2 ** 30, 25e9, 300.0)
A100 = Chip("a100", 312e12, 1555e9, 40 * 2 ** 30, 37.5e9, 400.0)

CHIPS = {c.name: c for c in (TPU_V5E, XEON_4116, RTX_2080TI, V100, A100)}

# Fixed per-dispatch overhead (host->device launch, runtime) seconds.
DISPATCH_OVERHEAD_S = 45e-6
# Meshlet/partition reconfiguration cost (survey §3.3.2: "several seconds"
# for MIG-class repartitioning; TPU analogue = recompile + weight reshard).
RECONFIG_COST_S = 5.0
