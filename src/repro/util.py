"""Runtime flags + scan wrapper.

XLA's ``cost_analysis`` counts a while-loop body ONCE, so any FLOPs/bytes/
collectives inside ``lax.scan`` are undercounted by the trip count. The
dry-run's count-mode therefore lowers the model with every scan fully
unrolled (``unrolled_scans()``), which makes the compiled HLO's cost and
collective statistics exact; the rolled variant remains the
compile/memory-fit proof (EXPERIMENTS.md §Dry-run methodology).
"""
from __future__ import annotations

import contextlib
import contextvars
import time

import jax


class TimedSamples(float):
    """The mean seconds-per-call, plus the per-iteration samples behind it.

    Subclassing ``float`` keeps every existing ``timeit(...) * 1e6`` call
    site working while benches that care about distribution (noise floors,
    medians, histogram feeding) read ``.samples`` / ``.median``."""

    __slots__ = ("samples",)
    samples: tuple

    def __new__(cls, mean_s: float, samples):
        self = super().__new__(cls, mean_s)
        self.samples = tuple(samples)
        return self

    @property
    def median(self) -> float:
        s = sorted(self.samples)
        n = len(s)
        if not n:
            return float(self)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def timeit(fn, *args, iters: int = 10, warmup: int = 2) -> TimedSamples:
    """Wall-clock seconds per call of a (jitted) function, with
    ``block_until_ready`` fencing both the warmup and each timed iteration
    so async dispatch cannot skew the measurement (the timer would
    otherwise stop while work is still queued on the device).

    Returns a ``TimedSamples`` — a float (the mean) that also carries the
    per-iteration wall times, each individually fenced."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    return TimedSamples(sum(samples) / max(1, len(samples)), samples)

_UNROLL = contextvars.ContextVar("unroll_scans", default=False)
_ATTN_CHUNK = contextvars.ContextVar("attn_chunk", default=1024)


@contextlib.contextmanager
def unrolled_scans(attn_chunk: int = 4096):
    """Fully unroll every framework scan (dry-run count-mode). Larger
    attention chunks keep the unrolled block-pair count manageable; the
    enumerated FLOPs are chunk-invariant up to diagonal-block masking."""
    t1 = _UNROLL.set(True)
    t2 = _ATTN_CHUNK.set(attn_chunk)
    try:
        yield
    finally:
        _UNROLL.reset(t1)
        _ATTN_CHUNK.reset(t2)


def scans_unrolled() -> bool:
    return _UNROLL.get()


def attn_chunk_default() -> int:
    return _ATTN_CHUNK.get()


def scan(f, init, xs, length=None):
    """lax.scan honoring the unroll flag."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if _UNROLL.get() else 1)


# ---------------------------------------------------------------------------
# sharding hints (perf-iteration levers; see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

_HINTS = contextvars.ContextVar("sharding_hints", default=None)


@contextlib.contextmanager
def sharding_hints(*, batch_axes=("data",), model_axis="model",
                    opts=frozenset(), **kwargs):
    """Make mesh-axis names + enabled optimizations visible to model code
    so it can place jax.lax.with_sharding_constraint on internal tensors.

    opts (beyond-paper hillclimb levers):
      "attn_carry"  — pin the block-attention scan carry/output sharding
                      (kills GSPMD's involuntary resharding collectives)
      "kv_seq"      — shard the decode KV cache along the sequence dim
                      (flash-decoding style length-parallel decode)
      "decode_pin"  — pin decode-attention intermediates (scores/probs)
    """
    tok = _HINTS.set({"batch_axes": tuple(batch_axes),
                      "model_axis": model_axis, "opts": frozenset(opts),
                      "batch_div": int(kwargs.get("batch_div", 1)),
                      "kv_scale_page": int(kwargs.get("kv_scale_page", 0))})
    try:
        yield
    finally:
        _HINTS.reset(tok)


def hints():
    return _HINTS.get()


def hint_opt(name: str) -> bool:
    h = _HINTS.get()
    return bool(h) and name in h["opts"]


def hint_val(name: str, default: int = 0) -> int:
    """Scalar hint lookup (e.g. "kv_scale_page": the page size the
    quantized KV cache groups prefill scales by; 0 = per-token)."""
    h = _HINTS.get()
    return h.get(name, default) if h else default


def wsc(x, *spec):
    """with_sharding_constraint using the hinted axis names; no-op when no
    hints are active (keeps unit tests mesh-free)."""
    h = _HINTS.get()
    if h is None:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec))
