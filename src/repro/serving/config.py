"""Engine configuration: ``DeviceTopology`` + the frozen ``EngineConfig``.

``ServingEngine`` grew one keyword at a time (PRs 1-6) until call sites
carried a dozen positional-ish knobs. ``EngineConfig`` collapses that
sprawl into one frozen, hashable value object — the thing a cluster
frontend can log, diff across replicas, and ship to a spawner. The
``topology`` field covers a replica that spans an N-chip mesh
(tensor/expert-parallel sharded serving) instead of one device; the
``precision`` field (``PrecisionConfig``) covers the quantized serving
path (int8 KV-cache pages + int8 weights). The all-default config is
bit-identical to the pre-config engine.

Construction goes through ``EngineConfig`` only: the one-PR
``from_legacy_kwargs`` shim (PR 7) is gone, and legacy keyword
construction (``ServingEngine(cfg, params, slots=4, ...)``) raises
``TypeError`` with the migration recipe.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

#: MoE capacity-overflow handling for the serving traces (moe archs only):
#:   strict       — size the per-expert capacity to the full token group in
#:                  every serving trace: token dropping is impossible (the
#:                  decode group is the slot count, so this is cheap at
#:                  serving batch sizes, unlike training).
#:   backpressure — keep the configured ``moe_capacity_factor`` but refuse
#:                  work that COULD drop: the slot count is clamped to the
#:                  drop-free decode group and prompts whose prefill group
#:                  exceeds it are rejected with a typed ``RequestRejected``
#:                  (admission backpressure instead of silent quality loss).
#:   drop         — GShard serving default: overflow tokens silently pass
#:                  through the residual (the pre-config engine behavior).
MOE_CAPACITY_POLICIES = ("strict", "backpressure", "drop")

#: KV-cache storage dtypes the quantized serving path accepts ("" = the
#: model compute dtype, the lossless default).
KV_CACHE_DTYPES = ("", "int8")

#: Weight storage dtypes ("" = model dtype). int8 is weight-only
#: quantization: per-output-channel fp32 scales, fp32 accumulation.
WEIGHT_DTYPES = ("", "int8")

#: Scale granularity for the quantized KV cache. Storage is identical
#: (one fp32 scale per (token, kv-head) vector); "page" additionally
#: COARSENS prefill writes to one scale per (page, kv-head) so a whole
#: page shares one dequant multiplier (the fused kernel's fast path),
#: while decode-time single-token appends always get their own scale.
#: "token" keeps per-token scales everywhere (tighter error bound).
KV_SCALE_GRANULARITIES = ("page", "token")

#: Block types whose attention/MLP matmul weights may quantize to int8.
#: MoE is excluded (expert-stacked weight layout + router sensitivity),
#: recurrent mixers (rglru/ssd) carry state-update matmuls whose error
#: compounds across steps.
WEIGHT_QUANT_BLOCKS = ("dense", "encoder", "local_attn")


@dataclass(frozen=True)
class PrecisionConfig:
    """Serving-path numeric precision, as one frozen hashable sub-config.

    ``kv_cache_dtype``: "" (model dtype) or "int8" — int8 stores KV-cache
    pages as int8 values + per-vector fp32 scales, halving (hd >> 4:
    nearly quartering vs f32) HBM per resident token; ``plan_admission``
    converts that into extra concurrent slots. Quantized KV requires the
    PAGED cache: rolling/recurrent caches are rejected by ``validate()``.
    ``weight_dtype``: "" or "int8" — weight-only int8 for the
    attention/MLP matmuls (per-output-channel fp32 scales, fp32
    accumulation via ``kernels/int8_matmul.py`` semantics). Only
    ``WEIGHT_QUANT_BLOCKS`` archs qualify; embed/lm_head stay f32.
    ``kv_scale_granularity``: see ``KV_SCALE_GRANULARITIES``.
    """

    kv_cache_dtype: str = ""
    weight_dtype: str = ""
    kv_scale_granularity: str = "page"

    def __post_init__(self):
        if self.kv_cache_dtype not in KV_CACHE_DTYPES:
            raise ValueError(
                f"unknown kv_cache_dtype {self.kv_cache_dtype!r} "
                f"(want one of {KV_CACHE_DTYPES})")
        if self.weight_dtype not in WEIGHT_DTYPES:
            raise ValueError(
                f"unknown weight_dtype {self.weight_dtype!r} "
                f"(want one of {WEIGHT_DTYPES})")
        if self.kv_scale_granularity not in KV_SCALE_GRANULARITIES:
            raise ValueError(
                f"unknown kv_scale_granularity "
                f"{self.kv_scale_granularity!r} (want one of "
                f"{KV_SCALE_GRANULARITIES})")

    @property
    def quantized_kv(self) -> bool:
        return self.kv_cache_dtype != ""

    @property
    def quantized_weights(self) -> bool:
        return self.weight_dtype != ""


@dataclass(frozen=True)
class DeviceTopology:
    """Mesh shape ONE engine replica spans: ``dp`` data-parallel ways on
    the ``data`` axis, ``tp`` tensor/expert-parallel ways on the ``model``
    axis. The default (1, 1) is the single-chip engine. Cluster replicas
    multiply OUTSIDE this: a 4-replica frontend over tp=8 replicas is 32
    chips."""

    dp: int = 1
    tp: int = 1

    def __post_init__(self):
        if self.dp < 1 or self.tp < 1:
            raise ValueError(
                f"DeviceTopology axes must be >= 1 (got dp={self.dp}, "
                f"tp={self.tp})")

    @property
    def n_chips(self) -> int:
        return self.dp * self.tp

    @property
    def sharded(self) -> bool:
        return self.n_chips > 1

    @property
    def mesh_axes(self) -> tuple:
        """((axis_name, size), ...) — the wire/cost-model mesh shape."""
        return (("data", self.dp), ("model", self.tp))


@dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a ``ServingEngine`` besides (cfg, params).

    Field semantics match the engine's former keywords one-for-one (see
    ``ServingEngine``'s docstring); new fields:

    ``topology``: device mesh this replica spans. >1 chip shards params,
    paged KV pools (kv-head axis), and the prefill/decode traces over a
    ``jax`` mesh; streams stay bit-identical to the 1-chip engine.
    ``modeled_chips``: cost-model-only chip count override for telemetry
    on heterogeneous simulated clusters (legacy ``n_chips=``); 0 means
    "use topology.n_chips".
    ``moe_capacity_policy``: see ``MOE_CAPACITY_POLICIES``; None resolves
    to "strict" on sharded MoE replicas (expert-parallel decode must not
    silently drop) and "drop" (legacy behavior) otherwise.
    """

    slots: Optional[int] = 4
    window: int = 512
    eos_id: int = -1
    sync_every: int = 8
    donate: bool = True
    bucket_prompts: bool = True
    chunk_prefill: int = 64
    sla_s: float = 0.05
    prefill_policy: Optional[object] = None  # ChunkedPrefillPolicy
    paged: Optional[bool] = None
    page_size: int = 16
    pool_pages: Optional[int] = None
    max_seq: Optional[int] = None
    kv_hbm_budget: Optional[float] = None
    expected_len: Optional[int] = None
    edf_backlog: bool = False
    prefix_cache: bool = False
    preemption: bool = False
    preempt_policy: str = "latest-deadline"
    shed_overdue: bool = False
    topology: DeviceTopology = DeviceTopology()
    modeled_chips: int = 0
    moe_capacity_policy: Optional[str] = None
    # serving-path precision (quantized KV pages / int8 weights); the
    # all-default PrecisionConfig is the lossless model-dtype path
    precision: PrecisionConfig = PrecisionConfig()
    # --- observability ---
    # span tracing: stamp a Trace on every request at phase boundaries
    # (host timestamps at existing sync points only; bit-identical
    # streams, bounded overhead — see serving/README.md "Observability")
    tracing: bool = False
    # trace every Nth request (by rid modulus) instead of all of them —
    # head-sampling for high-QPS fleets; 1 = trace everything. Span
    # rollups (span_totals) then cover the sampled subset only.
    trace_sample_n: int = 1
    # retain the last N finished request traces on the Tracer for
    # post-hoc inspection (0 = keep none; rollups are kept regardless)
    trace_ring: int = 0
    # jax.profiler trace directory for ServingEngine.start_profile();
    # None leaves the profiler hook disarmed
    profile_dir: Optional[str] = None

    def __post_init__(self):
        if (self.moe_capacity_policy is not None
                and self.moe_capacity_policy not in MOE_CAPACITY_POLICIES):
            raise ValueError(
                f"unknown moe_capacity_policy "
                f"{self.moe_capacity_policy!r} (want one of "
                f"{MOE_CAPACITY_POLICIES})")
        if self.modeled_chips < 0:
            raise ValueError(f"modeled_chips must be >= 0, got "
                             f"{self.modeled_chips}")
        if self.trace_sample_n < 1:
            raise ValueError(f"trace_sample_n must be >= 1, got "
                             f"{self.trace_sample_n}")
        if self.trace_ring < 0:
            raise ValueError(f"trace_ring must be >= 0, got "
                             f"{self.trace_ring}")

    @property
    def n_chips(self) -> int:
        """Chips the cost model bills this replica for."""
        return self.modeled_chips or self.topology.n_chips

    def validate(self, cfg=None) -> "EngineConfig":
        """Fail fast — BEFORE any trace — when the requested topology
        cannot be realized on this host, or the requested precision
        cannot serve ``cfg``'s architecture, with the fix in the message
        (an opaque XLA shape/device error at first trace otherwise).
        ``cfg`` (the model config) arms the precision checks; without it
        only host-level checks run."""
        need = self.topology.n_chips
        if need > 1:
            import jax

            have = jax.local_device_count()
            if need > have:
                raise ValueError(
                    f"EngineConfig.topology (dp={self.topology.dp} x "
                    f"tp={self.topology.tp}) needs {need} devices but this "
                    f"host exposes {have}; on CPU hosts set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{need} in the environment before jax initializes, "
                    f"or shrink the topology")
        pr = self.precision
        if cfg is not None and pr.quantized_kv:
            from repro.models import paged_ok

            if self.paged is False:
                raise ValueError(
                    f"precision.kv_cache_dtype={pr.kv_cache_dtype!r} "
                    f"quantizes KV-cache PAGES; the rolling cache "
                    f"(paged=False) has no paged pools — drop paged=False "
                    f"or clear kv_cache_dtype")
            if not paged_ok(cfg):
                raise ValueError(
                    f"precision.kv_cache_dtype={pr.kv_cache_dtype!r} "
                    f"needs every block pageable, but {cfg.name} has "
                    f"rolling/recurrent-cache blocks (local_attn/rglru/"
                    f"ssd) that cannot serve from quantized pages — clear "
                    f"kv_cache_dtype for this arch")
        if cfg is not None and pr.quantized_weights:
            from repro.models import block_program

            pattern, _, tail = block_program(cfg)
            bad = sorted({bt for bt in pattern + tail
                          if bt not in WEIGHT_QUANT_BLOCKS})
            if bad:
                raise ValueError(
                    f"precision.weight_dtype={pr.weight_dtype!r} supports "
                    f"blocks {WEIGHT_QUANT_BLOCKS} only, but {cfg.name} "
                    f"contains {bad} — clear weight_dtype for this arch")
            if self.topology.sharded:
                raise ValueError(
                    f"precision.weight_dtype={pr.weight_dtype!r} is not "
                    f"supported on sharded replicas yet (int8 weight "
                    f"leaves have no GSPMD profile) — serve quantized "
                    f"weights on 1-chip replicas or clear weight_dtype")
        return self

    def resolved_moe_policy(self, cfg) -> str:
        """Capacity policy after the None default resolves against the
        model arch and topology (see class docstring)."""
        if self.moe_capacity_policy is not None:
            return self.moe_capacity_policy
        if cfg.arch_type == "moe" and self.topology.sharded:
            return "strict"
        return "drop"

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)
