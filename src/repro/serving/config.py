"""Engine configuration: ``DeviceTopology`` + the frozen ``EngineConfig``.

``ServingEngine`` grew one keyword at a time (PRs 1-6) until call sites
carried a dozen positional-ish knobs. ``EngineConfig`` collapses that
sprawl into one frozen, hashable value object — the thing a cluster
frontend can log, diff across replicas, and ship to a spawner. The
``topology`` field is the new capability: a replica that spans an
N-chip mesh (tensor/expert-parallel sharded serving) instead of one
device. The 1-chip default is bit-identical to the pre-config engine.

Legacy keyword construction (``ServingEngine(cfg, params, slots=4, ...)``)
still works for one PR via ``EngineConfig.from_legacy_kwargs`` and emits a
``DeprecationWarning``; construct with ``config=EngineConfig(...)``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, fields
from typing import Optional

#: MoE capacity-overflow handling for the serving traces (moe archs only):
#:   strict       — size the per-expert capacity to the full token group in
#:                  every serving trace: token dropping is impossible (the
#:                  decode group is the slot count, so this is cheap at
#:                  serving batch sizes, unlike training).
#:   backpressure — keep the configured ``moe_capacity_factor`` but refuse
#:                  work that COULD drop: the slot count is clamped to the
#:                  drop-free decode group and prompts whose prefill group
#:                  exceeds it are rejected with a typed ``RequestRejected``
#:                  (admission backpressure instead of silent quality loss).
#:   drop         — GShard serving default: overflow tokens silently pass
#:                  through the residual (the pre-config engine behavior).
MOE_CAPACITY_POLICIES = ("strict", "backpressure", "drop")


@dataclass(frozen=True)
class DeviceTopology:
    """Mesh shape ONE engine replica spans: ``dp`` data-parallel ways on
    the ``data`` axis, ``tp`` tensor/expert-parallel ways on the ``model``
    axis. The default (1, 1) is the single-chip engine. Cluster replicas
    multiply OUTSIDE this: a 4-replica frontend over tp=8 replicas is 32
    chips."""

    dp: int = 1
    tp: int = 1

    def __post_init__(self):
        if self.dp < 1 or self.tp < 1:
            raise ValueError(
                f"DeviceTopology axes must be >= 1 (got dp={self.dp}, "
                f"tp={self.tp})")

    @property
    def n_chips(self) -> int:
        return self.dp * self.tp

    @property
    def sharded(self) -> bool:
        return self.n_chips > 1

    @property
    def mesh_axes(self) -> tuple:
        """((axis_name, size), ...) — the wire/cost-model mesh shape."""
        return (("data", self.dp), ("model", self.tp))


@dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a ``ServingEngine`` besides (cfg, params).

    Field semantics match the engine's former keywords one-for-one (see
    ``ServingEngine``'s docstring); new fields:

    ``topology``: device mesh this replica spans. >1 chip shards params,
    paged KV pools (kv-head axis), and the prefill/decode traces over a
    ``jax`` mesh; streams stay bit-identical to the 1-chip engine.
    ``modeled_chips``: cost-model-only chip count override for telemetry
    on heterogeneous simulated clusters (legacy ``n_chips=``); 0 means
    "use topology.n_chips".
    ``moe_capacity_policy``: see ``MOE_CAPACITY_POLICIES``; None resolves
    to "strict" on sharded MoE replicas (expert-parallel decode must not
    silently drop) and "drop" (legacy behavior) otherwise.
    """

    slots: Optional[int] = 4
    window: int = 512
    eos_id: int = -1
    sync_every: int = 8
    donate: bool = True
    bucket_prompts: bool = True
    chunk_prefill: int = 64
    sla_s: float = 0.05
    prefill_policy: Optional[object] = None  # ChunkedPrefillPolicy
    paged: Optional[bool] = None
    page_size: int = 16
    pool_pages: Optional[int] = None
    max_seq: Optional[int] = None
    kv_hbm_budget: Optional[float] = None
    expected_len: Optional[int] = None
    edf_backlog: bool = False
    prefix_cache: bool = False
    preemption: bool = False
    preempt_policy: str = "latest-deadline"
    shed_overdue: bool = False
    topology: DeviceTopology = DeviceTopology()
    modeled_chips: int = 0
    moe_capacity_policy: Optional[str] = None
    # --- observability ---
    # span tracing: stamp a Trace on every request at phase boundaries
    # (host timestamps at existing sync points only; bit-identical
    # streams, bounded overhead — see serving/README.md "Observability")
    tracing: bool = False
    # trace every Nth request (by rid modulus) instead of all of them —
    # head-sampling for high-QPS fleets; 1 = trace everything. Span
    # rollups (span_totals) then cover the sampled subset only.
    trace_sample_n: int = 1
    # retain the last N finished request traces on the Tracer for
    # post-hoc inspection (0 = keep none; rollups are kept regardless)
    trace_ring: int = 0
    # jax.profiler trace directory for ServingEngine.start_profile();
    # None leaves the profiler hook disarmed
    profile_dir: Optional[str] = None

    def __post_init__(self):
        if (self.moe_capacity_policy is not None
                and self.moe_capacity_policy not in MOE_CAPACITY_POLICIES):
            raise ValueError(
                f"unknown moe_capacity_policy "
                f"{self.moe_capacity_policy!r} (want one of "
                f"{MOE_CAPACITY_POLICIES})")
        if self.modeled_chips < 0:
            raise ValueError(f"modeled_chips must be >= 0, got "
                             f"{self.modeled_chips}")
        if self.trace_sample_n < 1:
            raise ValueError(f"trace_sample_n must be >= 1, got "
                             f"{self.trace_sample_n}")
        if self.trace_ring < 0:
            raise ValueError(f"trace_ring must be >= 0, got "
                             f"{self.trace_ring}")

    @property
    def n_chips(self) -> int:
        """Chips the cost model bills this replica for."""
        return self.modeled_chips or self.topology.n_chips

    def validate(self) -> "EngineConfig":
        """Fail fast — BEFORE any trace — when the requested topology
        cannot be realized on this host, with the fix in the message
        (an opaque XLA shape/device error at first trace otherwise)."""
        need = self.topology.n_chips
        if need > 1:
            import jax

            have = jax.local_device_count()
            if need > have:
                raise ValueError(
                    f"EngineConfig.topology (dp={self.topology.dp} x "
                    f"tp={self.topology.tp}) needs {need} devices but this "
                    f"host exposes {have}; on CPU hosts set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{need} in the environment before jax initializes, "
                    f"or shrink the topology")
        return self

    def resolved_moe_policy(self, cfg) -> str:
        """Capacity policy after the None default resolves against the
        model arch and topology (see class docstring)."""
        if self.moe_capacity_policy is not None:
            return self.moe_capacity_policy
        if cfg.arch_type == "moe" and self.topology.sharded:
            return "strict"
        return "drop"

    @classmethod
    def from_legacy_kwargs(cls, **kw) -> "EngineConfig":
        """Map the pre-config ``ServingEngine`` keywords onto a config.
        ``n_chips`` (a cost-model fiction for heterogeneous simulated
        replicas) becomes ``modeled_chips``."""
        if "n_chips" in kw:
            kw["modeled_chips"] = kw.pop("n_chips")
        known = {f.name for f in fields(cls)}
        unknown = set(kw) - known
        if unknown:
            raise TypeError(
                f"unknown ServingEngine/EngineConfig argument(s): "
                f"{sorted(unknown)}")
        return cls(**kw)

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)
