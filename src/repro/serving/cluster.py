"""Multi-engine cluster frontend: SLO-aware routing of live traffic across
``ServingEngine`` replicas (the survey's MIMD quadrant made real).

The survey (§2) calls for a datacenter tier that "understands different
models' requirements and places one or multiple queries intelligently onto
hardware". ``repro.core.mimd.router`` has that tier for *simulated*
instances; this module unifies it with reality:

  * ``EngineInstance`` adapts a live ``ServingEngine`` to the router's
    ``Instance`` protocol — ``load()`` / ``predicted_completion()`` read
    real telemetry from ``ServingEngine.load_report()`` (free slots, free
    pages, queued prefill tokens, cost-model backlog seconds), so every
    ``ServiceRouter`` policy (round-robin, least-loaded,
    power-of-two-choices, predicted-completion) runs unchanged over live
    engines;
  * predictions are closed-loop: each instance owns an
    ``InterferencePredictor`` that folds observed TTFT / completion
    latency back into a multiplicative residual on the cost model
    (``corrected_latency``), so a replica that is slower than the model
    thinks (noisy host, co-tenant, weaker chip) organically repels load;
  * ``ClusterFrontend`` owns the replicas plus one shared frontend queue
    with SLO-aware EDF ordering (earliest TTFT deadline dispatches first),
    and exposes autoscaling hooks (``autoscale``: grow a pool via a spawn
    callback under queue pressure, retire + drain the least-loaded replica
    when idle).

Dispatch is eager: a routed request enters its engine's own admission
machinery (accumulator -> backlog -> paged backpressure), so per-engine
invariants — all-or-nothing page reservation, single-trace probes,
bit-identical token streams — hold unchanged under the cluster. A retired
replica keeps being stepped until it drains empty; it just stops
receiving routes.

Per-request ``SamplingParams`` ride the ``Request`` across the frontend
untouched, and a stochastic stream is a pure function of (seed, token
position) — never of the replica, slot, or batch the router lands it in —
so seeded sampled streams are bit-identical under every routing policy,
autoscale event, and replica count (tested:
``test_cluster_sampled_streams_stable_under_routing``).

Fault tolerance (see serving/README.md "Failure semantics"): the frontend
keeps its own per-replica ledger of dispatched-but-unresolved requests.
A replica that raises ``EngineFailure`` (crash) or whose progress
signature freezes past ``health_timeout_s`` while holding work (hang) is
deregistered, and every request on its ledger is replayed on survivors —
``reset_for_retry`` + position-keyed seeded sampling make the replayed
streams bit-identical — under a per-request retry budget with
exponential backoff. Typed rejections (unknown model, oversize prompt)
resolve as FAILED outcomes instead of exceptions.

Overload control (see serving/README.md "Overload semantics"): with
``tenants`` registered, the frontend queue is a token-cost-weighted
deficit-round-robin ``WeightedFairQueue`` (EDF within a tenant, DRR
across tenants) and dispatch is *paced* — each replica's queue is fed
only to a bounded depth, so excess burst load waits at the frontend
where fair queueing (not engine-side EDF luck) decides who goes next.
Per-tenant ``TokenBucket`` admission and the ``OverloadDetector``'s
degradation ladder (shed lowest tier -> brownout budget trims -> typed
reject-with-retry-after) ride on top; a ``CircuitBreaker`` keeps the
failover retry wave from re-flooding a replica that just recovered.
Without tenants, the queue degenerates to the exact old flat-EDF order
and dispatch stays eager — the single-tenant path is unchanged.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Union

from repro.core.costmodel import estimate_decode, estimate_prefill
from repro.core.mimd.router import Instance, ServiceRouter
from repro.core.misd.interference import InterferencePredictor
from repro.core.misd.scheduler import Device, Job
from repro.serving.engine import ServingEngine
from repro.serving.faults import EngineFailure
from repro.serving.metrics import MetricsRegistry, latency_histogram
from repro.serving.overload import (BROWNOUT, NORMAL, REJECT, SHED,
                                    CircuitBreaker, OverloadDetector,
                                    TenantAdmission, TenantClass,
                                    WeightedFairQueue)
from repro.serving.request import (Request, RequestRejected, RequestState,
                                   ServeMetrics)
from repro.serving.tracing import Trace

DEFAULT_POOL = ""  # model tag for homogeneous (single-model) clusters


class EngineInstance(Instance):
    """A live ``ServingEngine`` behind the router's ``Instance`` protocol.

    ``sync()`` mirrors the engine's cost-model backlog into the simulation
    field ``queue_s``, so router machinery written for simulated instances
    (``pressure``, ``want_scale``) keeps working; the routing-policy hooks
    themselves (``load`` / ``predicted_completion``) take a fresh
    ``load_report()`` every call — telemetry, not the mirror."""

    def __init__(self, name: str, engine: ServingEngine,
                 model: str = DEFAULT_POOL):
        super().__init__(
            name=name, model=model,
            # device speed mirrors the replica's chip count so router
            # machinery written for simulated instances scales its
            # fallback predictions on heterogeneous pools
            device=Device(name=f"dev:{name}", max_tenants=engine.slots,
                          speed=float(engine.n_chips)))
        self.engine = engine
        self.corrector = InterferencePredictor()
        # frontend-side accounting (the bench's utilization columns)
        self.routed = 0
        self.ticks = 0
        self.busy_ticks = 0
        # health-watchdog state: last virtual time the engine's progress
        # signature changed while it had work (None until first observed)
        self.last_progress_t = 0.0
        self._progress_sig = None

    def sync(self):
        self.queue_s = self.engine.load_report().backlog_s

    def load(self) -> float:
        """Instantaneous occupancy signal for least-loaded routing: queued
        requests plus busy slots, normalized by slot count so replicas of
        different widths compare fairly. No cost model involved."""
        rep = self.engine.load_report()
        busy = rep.slots - rep.free_slots
        return (rep.queued_requests + busy) / max(1, rep.slots)

    @staticmethod
    def _slot_wait_ticks(rep) -> float:
        """Decode ticks until a slot opens for ONE MORE request, simulating
        the engine's drain: each busy slot frees after its remaining token
        budget, the queued requests (in drain order) claim slots as they
        free, and the new request takes the next opening. Exact under
        FCFS/EDF + one-token-per-tick; the closed loop absorbs the rest
        (fused scans, chunk interleave)."""
        frees = [0.0] * rep.free_slots + sorted(rep.active_remaining)
        frees = frees[:max(1, rep.slots)]
        heapq.heapify(frees)
        for budget in rep.queued_budgets:
            heapq.heappush(frees, heapq.heappop(frees) + budget)
        return heapq.heappop(frees)

    def queue_wait_s(self, rep=None) -> float:
        """Uncorrected cost-model seconds a new request would wait before
        its slot opens: slot-drain simulation plus queued prefill work.
        Pass a ``load_report()`` snapshot to amortize it across calls."""
        rep = rep if rep is not None else self.engine.load_report()
        return rep.tick_est_s * self._slot_wait_ticks(rep) + rep.queued_prefill_s

    def prefix_hit_s(self, job: Job) -> float:
        """Live prefix-affinity probe: cost-model prefill seconds this
        replica's ``PrefixIndex`` would skip for the job's prompt (0 when
        the cache is off, the prompt is unknown, or nothing matches)."""
        if job.tokens is None or job.prompt_tokens <= 0:
            return 0.0
        hit = self.engine.prefix_match_len(job.tokens)
        if hit <= 0:
            return 0.0
        eng = self.engine
        full = estimate_prefill(eng.cfg, 1, job.prompt_tokens,
                                n_chips=eng.n_chips,
                                mesh_axes=eng.mesh_axes).latency_s
        rest = estimate_prefill(eng.cfg, 1, job.prompt_tokens,
                                n_chips=eng.n_chips,
                                mesh_axes=eng.mesh_axes,
                                prefix_hit=hit).latency_s
        return max(0.0, full - rest)

    def service_s(self, job: Job) -> float:
        """The job's isolated service time ON THIS replica: re-estimated
        from its token shape with this engine's chip count (heterogeneous
        pools) and discounted by the prefix-affinity hit. Falls back to
        the pool-reference ``job.service_s`` when the token shape is
        unknown."""
        if job.prompt_tokens <= 0:
            return job.service_s
        eng = self.engine
        hit = (self.engine.prefix_match_len(job.tokens)
               if job.tokens is not None else 0)
        pre = estimate_prefill(eng.cfg, 1, job.prompt_tokens,
                               n_chips=eng.n_chips,
                               mesh_axes=eng.mesh_axes,
                               prefix_hit=max(0, hit)).latency_s
        dec = estimate_decode(eng.cfg, 1, eng.window,
                              n_chips=eng.n_chips,
                              mesh_axes=eng.mesh_axes).latency_s
        return pre + dec * max(0, job.new_tokens - 1)

    def predicted_completion(self, job: Job) -> float:
        """Cost-model completion estimate on THIS replica, residual-
        corrected by what the closed loop has observed here: seconds until
        a decode slot opens for the job (slot-drain simulation over the
        telemetry), plus the engine's queued prefill work, plus the job's
        own service time on this hardware (chip count + prefix affinity)."""
        return self.corrector.corrected_latency(
            self.queue_wait_s() + self.service_s(job))

    def predicted_wait(self, prefill_s: float, rep=None) -> float:
        """Corrected seconds until the job's FIRST token (TTFT component):
        slot wait plus queued prefill work plus the job's own prefill."""
        return self.corrector.corrected_latency(
            self.queue_wait_s(rep) + prefill_s)

    @property
    def utilization(self) -> float:
        return self.busy_ticks / self.ticks if self.ticks else 0.0


class ClusterFrontend:
    """Owns N live engine replicas (homogeneous, or pools keyed by model
    tag) behind one shared, SLO-aware frontend queue.

    ``engines``: a sequence of ``ServingEngine`` (single default pool) or a
    mapping ``model -> sequence of engines`` (multi-model pools; requests
    select a pool via ``Request.model``). ``policy``: any
    ``repro.core.mimd.router.POLICIES`` entry. ``edf``: order the frontend
    queue by TTFT deadline (earliest first; untracked requests last) —
    False preserves FIFO arrival order. ``edf`` also turns on each
    engine's EDF backlog drain so deadline order survives engine-side
    queueing.
    """

    def __init__(self,
                 engines: Union[Sequence[ServingEngine],
                                Mapping[str, Sequence[ServingEngine]]],
                 *, policy: str = "predicted", seed: int = 0,
                 edf: bool = True, health_timeout_s: float = 0.0,
                 max_retries: int = 3, retry_backoff_s: float = 0.0,
                 tracing: bool = False,
                 tenants: Optional[Mapping[str, TenantClass]] = None,
                 overload: Optional[OverloadDetector] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 fair_quantum: float = 256.0,
                 dispatch_depth: Optional[int] = None):
        self.router = ServiceRouter(policy=policy, seed=seed)
        self.edf = edf
        # --- multi-tenant overload control (see serving/overload.py) ---
        # tenants: name -> TenantClass turns on weighted-fair queueing +
        # paced dispatch; overload: the degradation-ladder detector;
        # breaker: circuit breaker over the failover/recovery path.
        self.tenants: Dict[str, TenantClass] = dict(tenants or {})
        self.fair = bool(self.tenants)
        self.overload = overload
        self.breaker = breaker
        self.dispatch_depth = dispatch_depth
        self._admission = (TenantAdmission(self.tenants)
                           if self.tenants else None)
        tiers = [tc.tier for tc in self.tenants.values()]
        self._top_tier = max(tiers) if tiers else 0
        self._low_tier = min(tiers) if tiers else 0
        # frontend-side span tracing: every submitted request gets a Trace
        # stamped with queue/dispatch/failover events here; engines stamp
        # their phases into the SAME trace (engine-side tracing need not
        # be on), so one trace tells the request's cross-replica story
        self.tracing = tracing
        # fault tolerance: a replica whose progress signature freezes for
        # longer than health_timeout_s while it holds work is declared
        # failed (0 disables the watchdog — crashes are still caught via
        # EngineFailure); its requests fail over to survivors with at most
        # max_retries re-submissions per request, exponentially backed off
        # from retry_backoff_s (0 = immediate requeue).
        self.health_timeout_s = health_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.instances: List[EngineInstance] = []
        self.draining: List[EngineInstance] = []
        self.retired: List[EngineInstance] = []  # drained + reaped
        self.failed: List[EngineInstance] = []  # declared dead
        # the frontend queue: weighted-fair across tenants (DRR), EDF
        # within each tenant. With a single (untagged) tenant its drain
        # order is exactly the old flat-EDF heap's.
        self._queue = WeightedFairQueue(
            edf=edf, quantum=fair_quantum,
            weight_of=lambda name: (self.tenants[name].weight
                                    if name in self.tenants else 1.0))
        self._seq = itertools.count()
        self._names = itertools.count()
        # per-replica ledger of dispatched-but-unresolved requests: the
        # frontend's own copy of what each replica owes it, harvested on
        # failure (a dead machine's memory cannot be read back)
        self._outstanding: Dict[str, Dict[int, Request]] = {}
        self._held_retries: List = []  # heap of (release_t, seq, Request)
        self._resolved: List[Request] = []  # frontend-resolved (no engine)
        self.metrics = ServeMetrics()  # frontend-level counters
        if isinstance(engines, Mapping):
            for model, pool in engines.items():
                for eng in pool:
                    self.add_engine(eng, model=model)
        else:
            for eng in engines:
                self.add_engine(eng)

    # -- pool management ---------------------------------------------------
    def add_engine(self, engine: ServingEngine,
                   model: str = DEFAULT_POOL,
                   name: Optional[str] = None) -> EngineInstance:
        """Register a live replica into ``model``'s pool (autoscale grow
        path). The engine starts receiving routes immediately."""
        if self.edf:
            engine.edf_backlog = True
        name = name or f"{model or 'pool'}/e{next(self._names)}"
        inst = EngineInstance(name, engine, model=model)
        self.router.register(inst)
        self.instances.append(inst)
        return inst

    def retire(self, inst_or_name) -> Optional[EngineInstance]:
        """Deregister a replica (autoscale shrink path): it stops receiving
        routes NOW, its queued-but-unstarted backlog migrates back through
        the frontend queue to be re-routed across survivors, and it keeps
        being stepped until its in-flight (slot-resident) work drains,
        then drops out of the cluster. Returns the retiring instance."""
        inst = self.router.deregister(inst_or_name)
        if inst is None:
            return None
        self.instances.remove(inst)
        self.draining.append(inst)
        # migrate unstarted work: the same requeue primitive failover
        # uses, minus the retry accounting (nothing was lost — these
        # requests never touched a slot on the retiree)
        ledger = self._outstanding.get(inst.name, {})
        for req in inst.engine.takeover_queue():
            ledger.pop(req.rid, None)
            req.routed_to = ""
            self._enqueue(req)
        return inst

    def pool(self, model: str = DEFAULT_POOL) -> List[EngineInstance]:
        return list(self.router.pools.get(model, []))

    @property
    def engines(self) -> List[ServingEngine]:
        return [i.engine for i in self.instances]

    # -- request path ------------------------------------------------------
    def submit(self, req: Request, now: float) -> bool:
        """Enqueue a request at the frontend queue. Routing happens at the
        next ``step``: every request submitted inside one tick is dispatched
        in EDF order (earliest TTFT deadline routes first — and therefore
        lands earliest in its engine's own queue), not arrival order.

        An unroutable request (unknown model tag) is a typed REJECTION,
        not an exception: it resolves as FAILED with a reason, counts in
        the frontend's ``metrics.rejected``, and surfaces from the next
        ``step`` — one bad request must never kill the frontend loop.
        Returns True iff the request was accepted into the queue."""
        if self.tracing and req.trace is None:
            req.trace = Trace(req.rid)
            req.trace.begin("queued", now)
        if req.model not in self.router.pools or not self.router.pools[req.model]:
            self._resolve(req, now, RequestState.FAILED,
                          f"rejected: no engine pool for model "
                          f"{req.model!r} (pools: {list(self.router.pools)})")
            self._count_rejected(req)
            return False
        tc = self.tenants.get(req.tenant)
        if tc is not None:
            req.tier = tc.tier  # the registered class is authoritative
        # degradation ladder, top rung: under sustained saturation every
        # sub-protected submission is refused OUTRIGHT with a finite
        # cost-model retry horizon — the serverless-inference contract
        if (self.overload is not None and self.overload.level >= REJECT
                and req.tier < self._top_tier):
            req.retry_after_s = self.overload.retry_after_s()
            self._resolve(req, now, RequestState.FAILED,
                          f"rejected: cluster overloaded (ladder="
                          f"{self.overload.level_name}); retry after "
                          f"{req.retry_after_s:.3f}s")
            self._count_rejected(req)
            return False
        # per-tenant token-bucket rate limit (typed, finite retry-after)
        if self._admission is not None:
            try:
                self._admission.admit(req, now)
            except RequestRejected as e:
                req.retry_after_s = e.retry_after_s
                self._resolve(req, now, RequestState.FAILED, str(e))
                self._count_rejected(req)
                return False
        self._enqueue(req)
        return True

    def _enqueue(self, req: Request):
        self._queue.push(req)

    def _count_rejected(self, req: Request):
        self.metrics.rejected += 1
        if req.tenant:
            self.metrics.tenant(req.tenant).rejected += 1

    def _resolve(self, req: Request, now: float, state: RequestState,
                 reason: str):
        """Terminally resolve a request at the frontend (it never reaches —
        or never returns from — an engine); surfaced by the next step."""
        req.state = state
        req.fail_reason = reason
        req.finish_time = now
        if req.trace is not None:
            req.trace.close_all(now)
            req.trace.event("abort", now, state=state.value,
                            reason=reason[:120])
        self._resolved.append(req)

    def _dispatch_credit(self, now: float, reports=None) -> Optional[int]:
        """Paced-dispatch budget for this tick (fair mode only): feed
        each live replica's queue to a bounded depth (``dispatch_depth``,
        default its slot count) and hold the rest at the frontend, where
        DRR — not engine-side EDF — decides who goes next. None =
        unlimited (the pre-fair eager dispatch)."""
        if not self.fair:
            return None
        credit = 0
        for inst in self.instances:
            rep = (reports or {}).get(inst.name)
            if rep is None:
                rep = inst.engine.load_report()
            depth = (self.dispatch_depth if self.dispatch_depth is not None
                     else rep.slots)
            credit += max(0, depth + rep.free_slots - rep.queued_requests)
        return credit

    def _shed(self, req: Request, now: float):
        """Degradation-ladder shed: a lowest-tier request dropped under
        overload, with the same retry-after contract as a rejection."""
        req.retry_after_s = (self.overload.retry_after_s()
                             if self.overload is not None else 0.0)
        if req.trace is not None:
            req.trace.event("shed", now, tier=req.tier,
                            level=self.overload.level_name)
        self.metrics.shed += 1
        if req.tenant:
            self.metrics.tenant(req.tenant).shed += 1
        self._resolve(req, now, RequestState.TIMED_OUT,
                      f"shed: overload ladder ({self.overload.level_name}) "
                      f"dropped tier {req.tier}; retry after "
                      f"{req.retry_after_s:.3f}s")

    def _brownout(self, req: Request, now: float):
        """Degradation-ladder brownout: trim a sub-protected request's
        decode budget (recorded on the request + in metrics; the served
        stream stays a bit-identical prefix of the unclamped one)."""
        tc = self.tenants.get(req.tenant)
        frac = tc.brownout_frac if tc is not None else 0.5
        cap = max(1, int(req.max_new_tokens * frac))
        if cap >= req.max_new_tokens:
            return
        trimmed = req.max_new_tokens - cap
        req.max_new_tokens = cap
        req.browned_out_tokens = trimmed
        if req.trace is not None:
            req.trace.event("brownout", now, tier=req.tier,
                            trimmed=trimmed, budget=cap)

    def _dispatch(self, now: float, reports=None):
        """Drain the frontend queue in weighted-fair order (single-tenant:
        plain EDF), routing each request to the policy-chosen replica.
        Without tenants routing is eager — engine-side backlogs (and
        their paged backpressure) do the holding — so every policy pays
        the same queueing machinery and differs ONLY in choice. In fair
        mode dispatch is paced by ``_dispatch_credit`` and the overload
        ladder sheds/brownouts sub-protected work at the pop point."""
        level = self.overload.level if self.overload is not None else NORMAL
        credit = self._dispatch_credit(now, reports)
        held = []
        while self._queue and (credit is None or credit > 0):
            req = self._queue.pop()
            doomed = req.overdue(now)
            if doomed is not None:
                # cancelled / JCT-expired while still queued at the
                # frontend: resolve here, never spend a route on it
                if doomed is RequestState.CANCELLED:
                    self.metrics.cancelled += 1
                    self._resolve(req, now, doomed, "cancelled at frontend")
                else:
                    self.metrics.timed_out += 1
                    self._resolve(req, now, doomed,
                                  "deadline passed while queued at frontend")
                continue
            # degradation ladder at the pop point: shed the lowest tier
            # outright, trim lower tiers' budgets under brownout. The
            # protected (top) tier passes untouched at every level.
            if (level >= SHED and req.tier <= self._low_tier
                    and self._low_tier < self._top_tier):
                self._shed(req, now)
                continue
            if (level >= BROWNOUT and req.tier < self._top_tier
                    and not req.browned_out_tokens):
                self._brownout(req, now)
            if not self.router.pools.get(req.model):
                # pool emptied (every replica retired or failed) after
                # this request was accepted: hold it at the frontend — it
                # dispatches the moment add_engine repopulates the pool —
                # rather than crashing the step and losing the request
                held.append(req)
                continue
            eligible = None
            if self.breaker is not None:
                pool = self.router.pools.get(req.model, [])
                eligible = {i.name for i in pool
                            if self.breaker.allow(i.name, now)}
                if not eligible:
                    # every replica open/half-open at probe capacity:
                    # hold — the breaker cooldown bounds the wait
                    held.append(req)
                    continue
                if len(eligible) == len(pool):
                    eligible = None  # all healthy: no filtering cost
            job = self._job_for(req, now)
            inst = self.router.route(job, eligible=eligible)
            if inst is None:
                held.append(req)
                continue
            # stash the closed-loop anchors on the request: the RAW
            # (uncorrected) predictions, so the residual is learned
            # against the cost model itself — observing the corrected
            # value would converge to sqrt of the true slowdown. One
            # telemetry snapshot serves both (route() already took
            # per-instance snapshots for its own scoring).
            rep = inst.engine.load_report()
            base = inst.queue_wait_s(rep)
            # one radix probe + one estimate pair for both anchors (the
            # per-candidate probes during route() scoring are inherent
            # to the policy; the chosen replica's is not re-run)
            eng = inst.engine
            hit = eng.prefix_match_len(req.prompt)
            pre_s = estimate_prefill(eng.cfg, 1, max(1, req.prompt_len),
                                     n_chips=eng.n_chips,
                                     mesh_axes=eng.mesh_axes,
                                     prefix_hit=hit).latency_s
            dec_s = estimate_decode(eng.cfg, 1, eng.window,
                                    n_chips=eng.n_chips,
                                    mesh_axes=eng.mesh_axes).latency_s
            req._pred_wait_s = base + pre_s
            req._pred_complete_s = (base + pre_s
                                    + dec_s * max(0, req.max_new_tokens - 1))
            req._dispatch_t = now
            req.routed_to = inst.name
            inst.routed += 1
            if req.trace is not None:
                req.trace.event("dispatch", now, replica=inst.name,
                                pred_wait_s=req._pred_wait_s)
            try:
                accepted = inst.engine.submit(req, now)
            except EngineFailure:
                # the chosen replica died between routing decisions: fail
                # it over and re-run this request through the (now
                # smaller) pool — survivors pick it up this same tick
                self._fail_instance(inst, now)
                self._retry(req, now)
                continue
            if accepted is not False:
                # ledger entry until the engine resolves it (engine-side
                # typed rejections return False and self-report through
                # the engine's own finished stream)
                self._outstanding.setdefault(inst.name, {})[req.rid] = req
                if self.breaker is not None:
                    self.breaker.note_dispatch(inst.name, now)
                if credit is not None:
                    credit -= 1
        for req in held:
            self._enqueue(req)

    def _job_for(self, req: Request, now: float) -> Job:
        pool = self.router.pools[req.model]
        cfg = pool[0].engine.cfg
        n_chips = pool[0].engine.n_chips
        mesh_axes = pool[0].engine.mesh_axes
        ctx = pool[0].engine.window
        dec = estimate_decode(cfg, 1, ctx, n_chips=n_chips,
                              mesh_axes=mesh_axes)
        pre_s = estimate_prefill(cfg, 1, max(1, req.prompt_len),
                                 n_chips=n_chips,
                                 mesh_axes=mesh_axes).latency_s
        service = pre_s + dec.latency_s * max(0, req.max_new_tokens - 1)
        return Job(jid=req.rid, model=req.model, demand=dec.demand,
                   service_s=service, arrival=now, priority=req.priority,
                   sla_s=req.ttft_slo_s,
                   # token shape: lets each EngineInstance re-estimate
                   # service for its own chips and probe prefix affinity
                   prompt_tokens=req.prompt_len,
                   new_tokens=req.max_new_tokens, tokens=req.prompt)

    def step(self, now: float) -> List[Request]:
        """One cluster tick: release due retries, dispatch anything queued,
        step every replica (live and draining) catching replica death,
        watchdog wedged replicas, observe finished requests into each
        replica's closed-loop corrector, and reap fully-drained retirees.
        The returned list carries every request resolved this tick —
        finished, rejected, aborted, or failed over to exhaustion."""
        while self._held_retries and self._held_retries[0][0] <= now:
            _, _, req = heapq.heappop(self._held_retries)
            self._enqueue(req)
        reports = None
        if self.fair or self.overload is not None:
            reports = {i.name: i.engine.load_report()
                       for i in self.instances}
            if self.overload is not None:
                # frontend-queue drain estimate: queued token cost over
                # the pool's aggregate per-tick token rate — under paced
                # dispatch the burst waits HERE, invisible to engine-side
                # backlog_s
                ticks = [r.tick_est_s for r in reports.values()
                         if r.tick_est_s > 0]
                slots = sum(r.slots for r in reports.values())
                fb = (self._queue.queued_cost
                      * (sum(ticks) / len(ticks)) / max(1, slots)
                      if ticks else 0.0)
                self.overload.observe(now, reports.values(),
                                      frontend_backlog_s=fb)
        self._dispatch(now, reports)
        finished: List[Request] = []
        for inst in list(self.instances) + list(self.draining):
            eng = inst.engine
            inst.ticks += 1
            busy = bool(eng.n_decoding or eng.n_prefilling or eng.backlog
                        or eng.admission.pending)
            if busy:
                inst.busy_ticks += 1
            try:
                out = eng.step(now)
            except EngineFailure:
                self._fail_instance(inst, now)
                continue
            ledger = self._outstanding.get(inst.name, {})
            for req in out:
                ledger.pop(req.rid, None)
                self._observe(inst, req)
                if (self.breaker is not None
                        and req.state is RequestState.FINISHED):
                    self.breaker.note_success(inst.name, now)
                finished.append(req)
            if self._wedged(inst, now, busy):
                self._fail_instance(inst, now)
                continue
            inst.sync()
        reaped = [i for i in self.draining if i.engine.idle]
        if reaped:
            # keep reaped retirees for the metrics rollup — the traffic
            # they served must not vanish from completed/goodput
            self.retired.extend(reaped)
            self.draining = [i for i in self.draining
                             if not i.engine.idle]
        if self._resolved:
            finished.extend(self._resolved)
            self._resolved = []
        return finished

    # -- failure detection + failover --------------------------------------
    def _wedged(self, inst: EngineInstance, now: float, busy: bool) -> bool:
        """Staleness watchdog over the replica's progress signature: a
        replica that HOLDS work but whose observable counters have not
        moved for health_timeout_s is wedged (hung host, livelocked
        runtime) — indistinguishable from slow until the timeout, exactly
        as in production. Idle replicas are healthy by definition."""
        if self.health_timeout_s <= 0:
            return False
        eng, m = inst.engine, inst.engine.metrics
        sig = (m.decode_ticks, m.prefill_chunks, m.completed, m.rejected,
               m.cancelled, m.timed_out, m.shed, m.failed, m.preempted,
               eng.n_decoding, eng.n_prefilling, len(eng.backlog),
               len(eng.admission.pending))
        if sig != inst._progress_sig or not busy:
            inst._progress_sig = sig
            inst.last_progress_t = now
            return False
        return now - inst.last_progress_t > self.health_timeout_s

    def _fail_instance(self, inst: EngineInstance, now: float):
        """Declare a replica dead: deregister it from routing, and fail
        over every request the ledger says it still owes — its in-flight
        AND queued work — to the survivors. The dead engine is never
        touched again (a crashed machine's memory is unreadable); requests
        are replayed from the frontend's own copies."""
        self.router.deregister(inst)
        if inst in self.instances:
            self.instances.remove(inst)
        if inst in self.draining:
            self.draining.remove(inst)
        inst.failed = True
        self.failed.append(inst)
        if self.breaker is not None:
            self.breaker.trip(inst.name, now)
        for req in list(self._outstanding.pop(inst.name, {}).values()):
            self.metrics.failed_over += 1
            self._retry(req, now)

    def revive(self, inst: EngineInstance, now: float = 0.0
               ) -> EngineInstance:
        """Re-register a previously failed replica whose host recovered
        (chaos 'recover' + operator revive). The engine restarts EMPTY —
        ``reset()`` drops whatever the dead process held; its ledgered
        work was already replayed on survivors at failure time — and
        keeps its jit caches warm. With a circuit breaker armed, the
        replica re-enters HALF_OPEN after the cooldown: dispatch ramps
        through bounded probes instead of re-flooding it (the breaker
        keys on the instance NAME, which revive preserves)."""
        if inst in self.failed:
            self.failed.remove(inst)
        inst.failed = False
        inst._progress_sig = None
        inst.last_progress_t = now
        inst.engine.reset()
        if self.edf:
            inst.engine.edf_backlog = True
        self.router.register(inst)
        self.instances.append(inst)
        inst.sync()
        return inst

    def _retry(self, req: Request, now: float):
        """Re-submit a harvested request to the survivors, within its
        retry budget. ``reset_for_retry`` rewinds the request to its
        original submission state (un-folding any preemption fold), so
        the survivor replays it from scratch — and seeded sampling keyed
        on (seed, absolute position) makes the replayed stream
        bit-identical to the one the dead replica was producing."""
        if req.retries >= self.max_retries:
            self.metrics.failed += 1
            self._resolve(req, now, RequestState.FAILED,
                          f"retry budget exhausted ({self.max_retries})")
            return
        req.retries += 1
        self.metrics.retried += 1
        req.reset_for_retry()  # leaves req.trace alone: history survives
        if req.trace is not None:
            req.trace.close_all(now)
            req.trace.event("failover_retry", now, retries=req.retries)
            req.trace.begin("queued", now)
        if self.retry_backoff_s > 0:
            delay = min(self.retry_backoff_s * (2 ** (req.retries - 1)),
                        8 * self.retry_backoff_s)
            heapq.heappush(self._held_retries,
                           (now + delay, next(self._seq), req))
        else:
            self._enqueue(req)

    def _observe(self, inst: EngineInstance, req: Request):
        """Close the loop: predicted vs observed wait (TTFT) and completion
        latency, measured from dispatch, feed the instance's residual."""
        if req.state is not RequestState.FINISHED:
            return  # aborted/rejected requests carry no latency signal
        t0 = getattr(req, "_dispatch_t", None)
        if t0 is None:
            return
        if req.prefill_done >= 0 and getattr(req, "_pred_wait_s", 0) > 0:
            inst.corrector.observe_latency(req._pred_wait_s,
                                           req.prefill_done - t0)
        if req.finish_time >= 0 and getattr(req, "_pred_complete_s", 0) > 0:
            inst.corrector.observe_latency(req._pred_complete_s,
                                           req.finish_time - t0)

    def drain(self, now: float) -> List[Request]:
        """Flush every replica's deferred tokens (end-of-run bookkeeping),
        plus any frontend-resolved requests not yet surfaced."""
        out: List[Request] = list(self._resolved)
        self._resolved = []
        for inst in self.instances + self.draining:
            ledger = self._outstanding.get(inst.name, {})
            for req in inst.engine.drain(now):
                ledger.pop(req.rid, None)
                out.append(req)
        return out

    # -- autoscaling -------------------------------------------------------
    def autoscale(self, now: float, *, spawn=None, model: str = DEFAULT_POOL,
                  high_s: float = 1.0, low_s: float = 0.05):
        """One autoscaling decision from queue pressure: pressure above
        ``high_s`` spawns a replica (via the ``spawn`` callback — building
        a ServingEngine is the caller's business), pressure below ``low_s``
        retires the least-loaded replica (it drains, then drops). Returns
        the instance added or retired, else None. ``sync`` during ``step``
        keeps ``router.pressure`` fed with live backlog telemetry."""
        sig = self.router.want_scale(model, high_s=high_s, low_s=low_s)
        if sig > 0 and spawn is not None:
            return self.add_engine(spawn(), model=model)
        if sig < 0:
            pool = self.router.pools.get(model, [])
            if len(pool) > 1:
                victim = min(pool, key=lambda i: (i.queue_s, i.order))
                return self.retire(victim)
        return None

    # -- rollups -----------------------------------------------------------
    def merged_metrics(self) -> ServeMetrics:
        """Cluster-wide ServeMetrics: every replica's counters summed —
        including replicas retired (reaped) or failed along the way —
        plus the frontend's own lifecycle counters (rejections, retries,
        failovers, frontend-queue aborts)."""
        m = ServeMetrics()
        m.merge(self.metrics)
        for inst in (self.instances + self.draining + self.retired
                     + self.failed):
            m.merge(inst.engine.metrics)
        return m

    def metrics_registry(self) -> MetricsRegistry:
        """Cluster-wide exposition: the merged ServeMetrics plus engine-
        level rollups — compile events and span totals summed across every
        replica (dead ones included), step-wall histograms exactly merged,
        and each live replica's closed-loop residual state."""
        reg = self.merged_metrics().registry(prefix="cluster_")
        compile_events: Dict[str, int] = {}
        span_totals: Dict[str, list] = {}
        tick_wall = latency_histogram()
        for inst in (self.instances + self.draining + self.retired
                     + self.failed):
            eng = inst.engine
            for k, n in eng.compile_events.items():
                compile_events[k] = compile_events.get(k, 0) + n
            for k, (c, s) in eng.tracer.span_totals.items():
                cur = span_totals.setdefault(k, [0, 0.0])
                cur[0] += c
                cur[1] += s
            tick_wall.merge(eng._tick_wall)
        for k, n in sorted(compile_events.items()):
            reg.set_counter(f"cluster_compile_events_total{{key=\"{k}\"}}", n)
        for k, (c, s) in sorted(span_totals.items()):
            reg.set_counter(f"cluster_span_count_total{{kind=\"{k}\"}}", c)
            reg.set_gauge(f"cluster_span_seconds{{kind=\"{k}\"}}", s)
        if tick_wall.count:
            reg.register("cluster_step_wall_seconds", tick_wall)
        for inst in self.instances:
            reg.set_gauge(
                f"cluster_residual_correction{{replica=\"{inst.name}\"}}",
                inst.corrector.correction)
            reg.register(
                f"cluster_residuals{{replica=\"{inst.name}\"}}",
                inst.corrector.residuals)
        return reg

    def utilization(self) -> Dict[str, float]:
        return {i.name: i.utilization
                for i in (self.instances + self.draining + self.retired
                          + self.failed)}

    @property
    def idle(self) -> bool:
        return (not self._queue and not self._held_retries
                and all(i.engine.idle
                        for i in self.instances + self.draining))
