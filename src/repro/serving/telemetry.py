"""Typed, versioned engine telemetry.

``LoadReport`` is the contract between one ``ServingEngine`` replica and
everything that watches it: the cluster router's predicted-completion
simulation, the autoscaler, the health watchdog, the chaos harness, and
the benches' JSON artifacts. It is versioned (``schema_version``) with a
``to_dict``/``from_dict`` wire shape so reports can cross process
boundaries (future cross-engine KV migration) without pickling.

Schema history:
  v1 — PR 3-6 implicit shape (slots/pages/backlog/lifecycle counters).
  v2 — PR 7: explicit ``schema_version``; per-mesh-axis fields
       (``mesh_axes``, ``axis_collective_s``, ``axis_util``) so the
       router understands an n-chip sharded replica; MoE capacity-policy
       fields.
  v3 — PR 8 (observability): ``histograms`` — sparse latency
       histograms (TTFT/TPOT/JCT) in repro.serving.metrics wire form, so
       the router's closed-loop correction and cluster-wide percentiles
       come from exactly-mergeable bounded state; ``span_totals`` —
       per-span-kind (count, seconds) rollups from request traces;
       ``compile_events`` — jit traces per trace-cache key.
  v4 — PR 9 (overload control): ``browned_out`` — requests served
       with a ladder-trimmed token budget; ``tenant_stats`` — per-tenant
       rollups ((tenant, (admitted, completed, total_tokens, rejected,
       shed, browned_out, brownout_trimmed_tokens, slo_tracked,
       slo_met), ttft-histogram-wire-or-()), ...) in
       ``TenantMetrics.to_wire`` form, exactly mergeable across replicas
       — the overload detector's and per-tenant-goodput dashboards'
       input.
  v5 — this PR (quantized serving): ``kv_bytes_per_token`` — the
       replica's actual per-resident-token KV cost (pool dtype included)
       so routers/cost models price heterogeneous pools correctly;
       ``kv_cache_dtype``/``weight_dtype`` — the replica's
       ``PrecisionConfig`` storage dtypes ("" = model dtype).

Readers upgrade old wire dicts through ``_UPGRADES``: one table-driven
step per historical version (v_n -> v_{n+1}), walked in order — adding a
schema version means appending ONE entry, not threading a new ad-hoc
branch through ``from_dict``.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, fields

SCHEMA_VERSION = 5

#: tuple-of-tuples fields that serialize as lists (JSON has no tuples)
_TUPLE_FIELDS = ("active_remaining", "queued_budgets", "mesh_axes",
                 "axis_collective_s", "axis_util")

#: arbitrarily nested tuple fields (v3+) — converted recursively
_DEEP_FIELDS = ("histograms", "span_totals", "compile_events",
                "tenant_stats")


def _listify(x):
    if isinstance(x, tuple):
        return [_listify(v) for v in x]
    return x


def _tuplify(x):
    if isinstance(x, (list, tuple)):
        return tuple(_tuplify(v) for v in x)
    return x


@dataclass(frozen=True)
class LoadReport:
    """One engine's telemetry snapshot — the routing signal the cluster
    frontend (repro.serving.cluster) consumes. Everything is host-side
    bookkeeping: taking a report never syncs the device."""

    slots: int
    free_slots: int  # slots with no active or prefilling request
    queued_requests: int  # backlog + admission-accumulator pending
    queued_prefill_tokens: int  # prompt tokens not yet through prefill
    decode_tokens_remaining: int  # unfinished token budgets, queued incl.
    free_pages: int  # page pool headroom (-1: rolling cache, unpaged)
    total_pages: int  # usable pool capacity (0 when unpaged)
    backlog_s: float  # cost-model seconds to drain the outstanding work
    tick_est_s: float  # cost-model latency of one batched decode tick
    queued_prefill_s: float  # cost-model seconds for the queued prefills
    # per-slot remaining token budgets of in-flight requests (prefilling
    # slots count their budget plus pending chunk ticks), and the queued
    # requests' budgets in the order the backlog will drain them — the
    # inputs to the cluster's slot-availability simulation
    active_remaining: tuple = ()
    queued_budgets: tuple = ()
    # --- prefix cache (0s when the index is off) ---
    prefix_cached_pages: int = 0  # pages currently held by the index
    prefix_cached_tokens: int = 0
    prefix_hits: int = 0  # cumulative admissions served from the cache
    prefix_hit_tokens: int = 0  # cumulative prompt tokens skipped
    # --- lifecycle / fault tolerance (cumulative ServeMetrics mirrors;
    # the cluster watchdog also reads report freshness as the replica's
    # health signal) ---
    rejected: int = 0
    cancelled: int = 0
    timed_out: int = 0
    shed: int = 0
    failed: int = 0
    preempted: int = 0
    # --- v2: sharded-replica shape (1-chip default) ---
    schema_version: int = SCHEMA_VERSION
    # ((axis, size), ...): the device mesh this replica spans
    mesh_axes: tuple = (("data", 1), ("model", 1))
    # ((axis, seconds), ...): modeled per-axis collective time inside one
    # full-batch decode tick (all-reduce/all-gather on "model", expert
    # all-to-all folded into "model" for TPxEP meshes)
    axis_collective_s: tuple = ()
    # ((axis, fraction), ...): axis_collective_s / tick_est_s — how much of
    # a tick the replica spends moving bytes over each mesh axis; the
    # router's sharding-overhead signal
    axis_util: tuple = ()
    # --- v2: MoE capacity policy (empty/0 for dense archs) ---
    moe_capacity_policy: str = ""
    moe_drop_free_group: int = 0  # largest never-dropping token group
    # --- v3: observability ---
    # ((name, histogram-wire), ...): non-empty ServeMetrics latency
    # histograms (latency_s/jct_s/ttft_s/tpot_s) in the sparse
    # repro.serving.metrics.Histogram.to_wire form — exactly mergeable
    # across replicas, so cluster percentiles need no sample shipping
    histograms: tuple = ()
    # ((span kind, count, seconds), ...): per-kind rollups folded from
    # terminal request traces (empty with tracing off)
    span_totals: tuple = ()
    # ((trace-cache key, count), ...): jit traces per shape-derived key —
    # the flat-compile-count invariant as queryable telemetry
    compile_events: tuple = ()
    # --- v4: multi-tenant overload control ---
    # cumulative requests this replica served with a brownout-trimmed
    # token budget (mirrors ServeMetrics.browned_out)
    browned_out: int = 0
    # per-tenant counters + TTFT histograms in TenantMetrics.to_wire
    # form: ((tenant, (counters...), ttft-wire-or-()), ...) — exactly
    # mergeable across replicas like everything else on this wire
    tenant_stats: tuple = ()
    # --- v5: serving-path precision (quantized replicas) ---
    # HBM bytes one resident cached token costs on THIS replica (pool
    # dtype included) — the router/cost model's capacity unit for
    # heterogeneous pools; 0.0 from pre-v5 reports means "unknown, assume
    # model dtype"
    kv_bytes_per_token: float = 0.0
    # the replica's PrecisionConfig storage dtypes ("" = model dtype)
    kv_cache_dtype: str = ""
    weight_dtype: str = ""

    @property
    def saturated(self) -> bool:
        """No slot free for an immediate admission."""
        return self.free_slots <= 0

    @property
    def n_chips(self) -> int:
        n = 1
        for _, size in self.mesh_axes:
            n *= int(size)
        return n

    # -- wire shape --------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-safe dict (tuples -> lists), carrying ``schema_version``."""
        d = asdict(self)
        for k in _TUPLE_FIELDS:
            d[k] = [list(x) if isinstance(x, tuple) else x for x in d[k]]
        for k in _DEEP_FIELDS:
            d[k] = _listify(d[k])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LoadReport":
        """Inverse of ``to_dict``. Historical versions (v1: no version
        field; v2-v4: missing newer fields) upgrade through the
        ``_UPGRADES`` table one step at a time; FUTURE schemas are
        rejected instead of silently mis-read."""
        version = int(d.get("schema_version", 1))
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"LoadReport schema v{version} is newer than this "
                f"reader (v{SCHEMA_VERSION}); upgrade the consumer")
        d = dict(d)
        for v in range(version, SCHEMA_VERSION):
            d = _UPGRADES[v](d)
        known = {f.name for f in fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        for k in _TUPLE_FIELDS:
            if k in kw:
                kw[k] = tuple(tuple(x) if isinstance(x, list) else x
                              for x in kw[k])
        for k in _DEEP_FIELDS:
            if k in kw:
                kw[k] = _tuplify(kw[k])
        kw["schema_version"] = SCHEMA_VERSION
        return cls(**kw)


# -- table-driven wire upgrades (v_n dict -> v_{n+1} dict) ------------------
# Every historical bump so far only ADDED fields whose dataclass defaults
# are the correct backfill, so each step is the identity on the payload;
# a future bump that renames/reshapes a field writes its migration here
# (and ONLY here) instead of branching inside from_dict.


def _add_fields_step(d: dict) -> dict:
    return d


_UPGRADES = {
    1: _add_fields_step,  # v1 -> v2: + mesh/axis + MoE capacity fields
    2: _add_fields_step,  # v2 -> v3: + histograms/span_totals/compiles
    3: _add_fields_step,  # v3 -> v4: + browned_out/tenant_stats
    4: _add_fields_step,  # v4 -> v5: + kv_bytes_per_token/precision dtypes
}
assert sorted(_UPGRADES) == list(range(1, SCHEMA_VERSION)), (
    "every historical schema version needs exactly one upgrade step")
