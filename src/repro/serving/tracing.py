"""Request span tracing for the serving engine + cluster frontend.

Every traced ``Request`` carries a ``Trace``: an append-only list of
typed ``Span``s stamped at phase boundaries.  The span taxonomy (see
serving/README.md "Observability"):

==================  ========================================================
kind                stamped at
==================  ========================================================
``queued``          frontend/engine submit -> admission (re-opened after
                    preemption and failover re-queue)
``prefill``         slot admission -> first token (meta: path=full/prefix/
                    chunked, prefix_hit tokens)
``prefill_chunk``   instant event per chunked-prefill tick
``decode``          first token -> terminal state
``decode_window``   one span per fused decode window whose host sync
                    delivered tokens to this request (meta: tokens)
``sample``          instant event when stochastic sampling is armed
``preempt``         instant event when a slot is preempted
``restore``         instant event when a preempted request re-activates
``dispatch``        instant event when the frontend routes to a replica
``failover_retry``  instant event when the frontend re-queues after a
                    replica failure
``shed``            instant event when the overload ladder drops a
                    low-tier request at dispatch (meta: level,
                    retry_after_s)
``brownout``        instant event when the ladder trims a request's
                    token budget (meta: level, max_new_tokens before/
                    after)
``rejected``/``abort``  instant terminal events for non-completion paths
``compile``         engine-level event per jit trace (meta: trace-cache key)
==================  ========================================================

Stamping discipline — the part that keeps tracing off the hot path:
timestamps are *host* clocks the engine already has in hand (the ``now``
argument threaded through every engine entry point), recorded only at
existing host-sync points.  Tracing never adds a device sync, and when
tracing is off a request's ``trace`` stays ``None`` so the per-token
cost is one attribute check.

``end`` is lenient (no-op if no span of that kind is open) because
requests can enter the engine through several doors (frontend submit,
direct ``try_admit`` in tests, failover re-queue) and the engine must
not need to know which spans a previous owner opened.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["Span", "Trace", "Tracer"]


@dataclass
class Span:
    kind: str
    t0: float
    t1: Optional[float] = None  # None while open
    meta: dict = field(default_factory=dict)

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def dur(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0


class Trace:
    """Append-only span list for one request (or one engine)."""

    __slots__ = ("rid", "spans")

    def __init__(self, rid: int = -1):
        self.rid = rid
        self.spans: List[Span] = []

    def begin(self, kind: str, t: float, **meta) -> Span:
        sp = Span(kind, float(t), None, meta)
        self.spans.append(sp)
        return sp

    def end(self, kind: str, t: float, **meta) -> Optional[Span]:
        """Close the most recent open span of ``kind``; no-op if none."""
        for sp in reversed(self.spans):
            if sp.kind == kind and sp.t1 is None:
                sp.t1 = float(t)
                if meta:
                    sp.meta.update(meta)
                return sp
        return None

    def event(self, kind: str, t: float, **meta) -> Span:
        """Zero-duration (instant) span."""
        t = float(t)
        sp = Span(kind, t, t, meta)
        self.spans.append(sp)
        return sp

    def add(self, kind: str, t0: float, t1: float, **meta) -> Span:
        sp = Span(kind, float(t0), float(t1), meta)
        self.spans.append(sp)
        return sp

    def is_open(self, kind: str) -> bool:
        return any(sp.kind == kind and sp.t1 is None for sp in self.spans)

    def close_all(self, t: float) -> int:
        """Close every open span at ``t`` (terminal paths: abort/failover)."""
        n = 0
        for sp in self.spans:
            if sp.t1 is None:
                sp.t1 = float(t)
                n += 1
        return n

    def validate(self) -> List[str]:
        """Well-formedness problems for a *terminal* trace (empty = ok):
        no open spans, every span non-negative, start times monotonically
        non-decreasing in record order."""
        problems = []
        prev_t0 = None
        for i, sp in enumerate(self.spans):
            if sp.t1 is None:
                problems.append(f"span[{i}] {sp.kind} still open (t0={sp.t0})")
            elif sp.t1 < sp.t0:
                problems.append(
                    f"span[{i}] {sp.kind} negative ({sp.t0}->{sp.t1})")
            if prev_t0 is not None and sp.t0 < prev_t0:
                problems.append(
                    f"span[{i}] {sp.kind} starts at {sp.t0} before "
                    f"span[{i-1}] at {prev_t0}")
            prev_t0 = sp.t0
        return problems

    def totals(self) -> Dict[str, Tuple[int, float]]:
        """Per-kind (count, total seconds) over closed spans."""
        out: Dict[str, Tuple[int, float]] = {}
        for sp in self.spans:
            if sp.t1 is None:
                continue
            c, s = out.get(sp.kind, (0, 0.0))
            out[sp.kind] = (c + 1, s + sp.dur)
        return out

    def kinds(self) -> List[str]:
        return sorted({sp.kind for sp in self.spans})

    def __len__(self) -> int:
        return len(self.spans)

    def __repr__(self) -> str:
        return f"Trace(rid={self.rid}, spans={len(self.spans)})"


class Tracer:
    """Engine-level trace sink: an engine-scoped trace (compile/profile
    events) plus per-kind rollups folded in from terminal request traces.

    ``span_totals`` is what ``LoadReport`` v3 ships — bounded per-kind
    aggregates, not the spans themselves.  ``ring`` > 0 additionally
    retains the last N finished request traces (a bounded deque) for
    post-hoc inspection without unbounded memory growth.
    """

    __slots__ = ("enabled", "engine", "span_totals", "collected", "ring")

    def __init__(self, enabled: bool = False, ring: int = 0):
        self.enabled = enabled
        self.engine = Trace(rid=-1)  # engine-scoped events (compile, profile)
        self.span_totals: Dict[str, Tuple[int, float]] = {}
        self.collected = 0
        self.ring = deque(maxlen=ring) if ring > 0 else None

    def event(self, kind: str, t: float, **meta) -> None:
        self.engine.event(kind, t, **meta)

    def collect(self, trace: Optional[Trace]) -> None:
        """Fold a terminal request trace into the per-kind rollup."""
        if trace is None:
            return
        self.collected += 1
        for kind, (c, s) in trace.totals().items():
            c0, s0 = self.span_totals.get(kind, (0, 0.0))
            self.span_totals[kind] = (c0 + c, s0 + s)
        if self.ring is not None:
            self.ring.append(trace)

    def totals_wire(self) -> tuple:
        """Hashable, JSON-safe ((kind, count, seconds), ...) for LoadReport."""
        return tuple((k, c, s)
                     for k, (c, s) in sorted(self.span_totals.items()))
