"""Host-side page allocator for the paged KV cache.

The device holds one shared page pool per attention layer
(``[n_pages, page_size, kv_heads, head_dim]``) plus an integer page table
per slot; this module owns the *indices*. Pages are fixed-size, so
allocation is a free-list pop and free is a push — O(1), no compaction,
no fragmentation beyond per-page internal padding (< ``page_size`` tokens
per request).

Invariants (tests/test_paging.py):
  * page 0 is reserved as the trash page: freed/inactive slots point their
    page-table rows at it, so a stale slot's decode writes can never land
    in a page owned by a live request;
  * a page is owned by at most one slot at a time; ``free_slot`` returns
    every page to the free list (LIFO, so reuse is cache-friendly);
  * ``alloc`` is all-or-nothing: it returns None (admission backpressure)
    rather than a partial grant.
"""
from __future__ import annotations

from typing import Dict, List, Optional


class OutOfPagesError(RuntimeError):
    """Raised when decode growth needs a page and the pool is exhausted.

    Admission-time shortage is signalled by ``alloc`` returning None (the
    engine queues the request); mid-decode shortage means the pool was
    sized without decode headroom — size ``pool_pages`` at
    ``slots * ceil(max_seq / page_size) + 1`` (the +1 covers the reserved
    trash page) to make this unreachable.
    """


class PageAllocator:
    """Free-list allocator over a fixed pool of KV pages.

    ``n_pages`` counts the whole pool including the reserved trash page
    (page 0), so ``capacity`` = n_pages - reserved usable pages.
    """

    TRASH_PAGE = 0

    def __init__(self, n_pages: int, page_size: int, *, reserved: int = 1):
        if n_pages <= reserved:
            raise ValueError(f"pool of {n_pages} pages leaves none usable "
                             f"({reserved} reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.reserved = reserved
        # LIFO free list: lowest page numbers on top so early allocations
        # are dense (nicer locality, easier to eyeball in tests).
        self._free: List[int] = list(range(n_pages - 1, reserved - 1, -1))
        self._owned: Dict[int, List[int]] = {}

    # -- sizing ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.n_pages - self.reserved

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc / free ------------------------------------------------------
    def alloc(self, slot: int, n: int) -> Optional[List[int]]:
        """Grant ``n`` pages to ``slot`` (appending to what it owns), or
        None if the pool cannot cover the whole request."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(slot, []).extend(pages)
        return pages

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def owned_tokens(self, slot: int) -> int:
        """Token capacity currently backed by the slot's pages."""
        return len(self._owned.get(slot, ())) * self.page_size

    def free_slot(self, slot: int) -> List[int]:
        """Return every page owned by ``slot`` to the free list."""
        pages = self._owned.pop(slot, [])
        self._free.extend(reversed(pages))  # LIFO: newest pages reused first
        return pages

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PageAllocator(pages={self.n_pages}, size={self.page_size}, "
                f"in_use={self.pages_in_use}, free={self.free_pages})")
