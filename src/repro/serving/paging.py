"""Host-side page allocator + shared-prefix index for the paged KV cache.

The device holds one shared page pool per attention layer
(``[n_pages, page_size, kv_heads, head_dim]``) plus an integer page table
per slot; this module owns the *indices*. Pages are fixed-size, so
allocation is a free-list pop and free is a push — O(1), no compaction,
no fragmentation beyond per-page internal padding (< ``page_size`` tokens
per request).

Pages are REFCOUNTED so requests with a common prompt prefix can share
the prefix's pages instead of recomputing (and re-storing) them:

  * ``alloc`` grants fresh pages at refcount 1 (exclusive);
  * ``share`` aliases already-live pages into another slot (refcount+1);
  * ``free_slot`` decrefs everything a slot holds and only returns a page
    to the free list when its refcount reaches 0;
  * ``retain``/``release`` let a non-slot owner — the ``PrefixIndex`` —
    keep prefix chains alive after the request that computed them is gone.

``PrefixIndex`` is a host-side radix tree over *full pages* of prompt
tokens: each node is one page whose ``page_size`` tokens are the edge
label. ``lookup`` walks the longest cached chain for a new prompt (full
pages aliased read-only; a partially-matching tail page is surfaced for
copy-on-write), ``register`` inserts a finished prompt's full pages, and
``evict`` drops least-recently-touched chains whose pages no live slot
references (refcount held only by the index) under pool pressure.

Invariants (tests/test_paging.py, tests/test_prefix_cache.py):
  * page 0 is reserved as the trash page: freed/inactive slots point their
    page-table rows at it, so a stale slot's decode writes can never land
    in a page owned by a live request; the trash page is never granted,
    shared, or indexed;
  * ``alloc`` is all-or-nothing: it returns None (admission backpressure)
    rather than a partial grant;
  * a page returns to the free list (LIFO, cache-friendly reuse) exactly
    when its last reference drops — eviction can never free a page a live
    slot still reads.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class OutOfPagesError(RuntimeError):
    """Raised when decode growth needs a page and the pool is exhausted.

    Admission-time shortage is signalled by ``alloc`` returning None (the
    engine queues the request); mid-decode shortage means the pool was
    sized without decode headroom — size ``pool_pages`` at
    ``slots * ceil(max_seq / page_size) + 1`` (the +1 covers the reserved
    trash page) to make this unreachable.
    """


class PageAllocator:
    """Refcounted free-list allocator over a fixed pool of KV pages.

    ``n_pages`` counts the whole pool including the reserved trash page
    (page 0), so ``capacity`` = n_pages - reserved usable pages.
    """

    TRASH_PAGE = 0

    def __init__(self, n_pages: int, page_size: int, *, reserved: int = 1):
        if n_pages <= reserved:
            raise ValueError(f"pool of {n_pages} pages leaves none usable "
                             f"({reserved} reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.reserved = reserved
        # LIFO free list: lowest page numbers on top so early allocations
        # are dense (nicer locality, easier to eyeball in tests).
        self._free: List[int] = list(range(n_pages - 1, reserved - 1, -1))
        self._owned: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}  # live page -> reference count

    # -- sizing ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.n_pages - self.reserved

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def refcount(self, page: int) -> int:
        """References currently held on ``page`` (0 = free or trash)."""
        return self._ref.get(page, 0)

    @property
    def total_refs(self) -> int:
        """Sum of all live refcounts (0 = fully drained pool — the
        zero-leak probe benches assert after clear_prefix_cache)."""
        return sum(self._ref.values())

    # -- alloc / share / free ----------------------------------------------
    def alloc(self, slot: int, n: int) -> Optional[List[int]]:
        """Grant ``n`` fresh pages to ``slot`` (appending to what it owns,
        each at refcount 1), or None if the pool cannot cover the whole
        request."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._owned.setdefault(slot, []).extend(pages)
        return pages

    def share(self, slot: int, pages: List[int]) -> List[int]:
        """Alias already-live ``pages`` into ``slot`` (refcount+1 each).
        Never allocates, so it cannot fail for lack of pool space; sharing
        a free (or trash) page is a lifecycle bug and raises."""
        for p in pages:
            if self._ref.get(p, 0) <= 0:
                raise ValueError(f"page {p} is not live; cannot share")
        for p in pages:
            self._ref[p] += 1
        self._owned.setdefault(slot, []).extend(pages)
        return list(pages)

    def retain(self, page: int):
        """Take a non-slot reference on a live page (the PrefixIndex's
        hold, keeping cached prefixes alive after their slot frees)."""
        if self._ref.get(page, 0) <= 0:
            raise ValueError(f"page {page} is not live; cannot retain")
        self._ref[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; returns True when the page was reclaimed
        (refcount reached 0 and it went back to the free list)."""
        r = self._ref.get(page, 0)
        if r <= 0:
            raise ValueError(f"page {page} is not live; cannot release")
        if r > 1:
            self._ref[page] = r - 1
            return False
        del self._ref[page]
        self._free.append(page)
        return True

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def owned_tokens(self, slot: int) -> int:
        """Token capacity currently backed by the slot's pages."""
        return len(self._owned.get(slot, ())) * self.page_size

    def free_slot(self, slot: int) -> List[int]:
        """Drop the slot's reference on every page it holds; returns the
        pages actually reclaimed (refcount hit 0). Shared pages survive
        with the other holders (LIFO: newest reclaimed pages reused
        first)."""
        pages = self._owned.pop(slot, [])
        freed = [p for p in reversed(pages) if self.release(p)]
        return freed

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PageAllocator(pages={self.n_pages}, size={self.page_size}, "
                f"in_use={self.pages_in_use}, free={self.free_pages})")


# ---------------------------------------------------------------------------
# shared-prefix radix index
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrefixHit:
    """Longest cached prefix for a prompt: ``full_pages`` alias read-only
    (their whole ``page_size`` span matches), ``tail_page`` (if >= 0)
    matches only its first ``tail_tokens`` tokens and must be COPIED into
    a private page before the admitting slot writes anything into that
    span (copy-on-write). ``tokens`` is the total usable hit, capped at
    prompt_len - 1 so the last prompt token is always recomputed (its
    logits seed the first output token; only KV is cached)."""

    tokens: int
    full_pages: Tuple[int, ...] = ()
    tail_page: int = -1
    tail_tokens: int = 0


@dataclass
class _PrefixNode:
    key: Tuple[int, ...]  # the page's page_size prompt tokens (edge label)
    page: int
    children: Dict[Tuple[int, ...], "_PrefixNode"] = field(default_factory=dict)
    stamp: int = 0  # insertion/touch order (LRU eviction key)


class PrefixIndex:
    """Radix tree mapping prompt-token prefixes to cached page chains.

    Granularity is one FULL page per node: a node exists only when every
    one of its ``page_size`` tokens came from a registered prompt, so an
    indexed page is immutable by construction (its owner's decode appends
    land strictly after the prompt span). The index holds one allocator
    reference per node (``retain``); eviction releases it, and the page
    returns to the pool the moment no live slot aliases it.
    """

    def __init__(self, allocator: PageAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self._root: Dict[Tuple[int, ...], _PrefixNode] = {}
        self._clock = 0
        self._nodes = 0
        self.evicted_pages = 0  # cumulative (engine telemetry)

    # -- stats -------------------------------------------------------------
    @property
    def cached_pages(self) -> int:
        return self._nodes

    @property
    def cached_tokens(self) -> int:
        return self._nodes * self.page_size

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- lookup ------------------------------------------------------------
    @staticmethod
    def _common(key: Tuple[int, ...], toks) -> int:
        n = 0
        for a, b in zip(key, toks):
            if a != int(b):
                break
            n += 1
        return n

    def lookup(self, prompt) -> Optional[PrefixHit]:
        """Longest cached prefix of ``prompt`` (token ids, 1-D). The hit is
        capped at ``len(prompt) - 1``: a full-to-the-end match converts its
        last page into a COW tail so the final token's logits are always
        recomputed. Returns None when no full page matches (a sub-page hit
        is not worth the gather). Touches matched nodes' LRU stamps."""
        ps = self.page_size
        plen = int(len(prompt))
        cap = plen - 1
        full: List[_PrefixNode] = []
        level = self._root
        off = 0
        tail: Optional[_PrefixNode] = None
        tail_t = 0
        while off < cap:
            rem = cap - off
            node = None
            if rem >= ps:
                node = level.get(tuple(int(x) for x in prompt[off:off + ps]))
            if node is not None:
                full.append(node)
                off += ps
                level = node.children
                continue
            # partial tail: the child sharing the longest leading run
            upto = min(ps, plen - off)
            toks = prompt[off:off + upto]
            for child in level.values():
                t = self._common(child.key, toks)
                if t > tail_t:
                    tail, tail_t = child, t
            tail_t = min(tail_t, rem)
            break
        if not full:
            return None
        now = self._tick()
        for n in full:
            n.stamp = now
        if tail is not None and tail_t > 0:
            tail.stamp = now
            return PrefixHit(off + tail_t, tuple(n.page for n in full),
                             tail.page, tail_t)
        return PrefixHit(off, tuple(n.page for n in full))

    def match_len(self, prompt) -> int:
        """Usable hit length WITHOUT touching LRU stamps or hit counters —
        the routing probe (cluster prefix affinity)."""
        ps = self.page_size
        plen = int(len(prompt))
        cap = plen - 1
        level = self._root
        off = 0
        while off + ps <= cap:
            node = level.get(tuple(int(x) for x in prompt[off:off + ps]))
            if node is None:
                break
            off += ps
            level = node.children
        if not off:
            return 0  # sub-page matches are not taken (see lookup)
        best = 0
        upto = min(ps, plen - off)
        toks = prompt[off:off + upto]
        for child in level.values():
            best = max(best, self._common(child.key, toks))
        return min(off + best, cap)

    # -- registration ------------------------------------------------------
    def register(self, prompt, pages: List[int]) -> int:
        """Insert a prefilled prompt's FULL pages (``pages[i]`` backs tokens
        ``[i*ps, (i+1)*ps)``). Existing nodes are kept — a concurrent
        duplicate prompt does not replace the cached chain — and each new
        node takes one allocator reference. Returns new nodes added."""
        ps = self.page_size
        level = self._root
        added = 0
        for i in range(int(len(prompt)) // ps):
            key = tuple(int(x) for x in prompt[i * ps:(i + 1) * ps])
            node = level.get(key)
            if node is None:
                page = pages[i]
                if page == PageAllocator.TRASH_PAGE:
                    raise ValueError("cannot index the trash page")
                self.allocator.retain(page)
                node = _PrefixNode(key, page, {}, self._tick())
                level[key] = node
                self._nodes += 1
                added += 1
            else:
                node.stamp = self._tick()
            level = node.children
        return added

    # -- eviction ----------------------------------------------------------
    def _leaves(self, level, out):
        for key, node in level.items():
            if node.children:
                self._leaves(node.children, out)
            else:
                out.append((node.stamp, key, node, level))

    def evict(self, n_pages: int) -> int:
        """Free >= ``n_pages`` pool pages by dropping cached chains, oldest
        stamp first, leaves inward. Only nodes whose page no live slot
        references (allocator refcount == 1, the index's own hold) are
        candidates — eviction can NEVER reclaim a page out from under a
        running request. Returns pages actually freed (may fall short)."""
        freed = 0
        while freed < n_pages:
            leaves: List = []
            self._leaves(self._root, leaves)
            cands = sorted((x for x in leaves
                            if self.allocator.refcount(x[2].page) == 1),
                           key=lambda x: x[0])
            if not cands:
                break
            for _, key, node, level in cands:
                if freed >= n_pages:
                    break
                del level[key]
                self._nodes -= 1
                if self.allocator.release(node.page):
                    freed += 1
                    self.evicted_pages += 1
        return freed

    def clear(self) -> int:
        """Drop every cached chain (engine reset): releases the index's
        reference on every node; pages with no live slot return to the
        pool. Returns pages freed."""
        freed = 0
        stack = [self._root]
        while stack:
            level = stack.pop()
            for node in level.values():
                stack.append(node.children)
                if self.allocator.release(node.page):
                    freed += 1
        self._root = {}
        self._nodes = 0
        return freed

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PrefixIndex(pages={self._nodes}, "
                f"tokens={self.cached_tokens}, evicted={self.evicted_pages})")
