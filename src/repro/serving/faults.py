"""Fault injection for the serving cluster (chaos testing).

Production inference lives with machine failures as the norm (the
Facebook datacenter study: co-location interference, capacity pressure,
host loss), so the cluster frontend's failover path must be exercisable
deterministically. This module provides the instruments:

  * ``EngineFailure`` — what a dead replica's RPC layer would surface:
    raised by a killed engine's ``step``/``submit``; the frontend catches
    it, deregisters the replica, and fails over its outstanding work;
  * ``FaultyEngine`` — a transparent proxy over a live ``ServingEngine``
    that a ``FaultInjector`` arms. Modes:
      - ``kill``: every ``step``/``submit`` raises ``EngineFailure``
        (crashed host — detection is immediate at the next step);
      - ``hang``: ``step`` returns nothing and makes NO progress while
        the engine keeps accepting work (wedged host — only the
        frontend's staleness watchdog can catch it);
      - ``slow``: only every ``slow_every``-th ``step`` actually runs
        (co-tenant interference / failing disk; mild slowness survives
        via the closed-loop residual, pathological slowness trips the
        watchdog like a hang);
      - ``recover``: back to healthy forwarding.
  * ``FaultInjector`` — a deterministic virtual-time schedule of fault
    events over named proxies (the chaos bench's driver).

The proxy forwards every attribute read AND write to the wrapped engine
(``ClusterFrontend.add_engine`` sets ``engine.edf_backlog``), so it can
stand anywhere a ``ServingEngine`` does.

A frontend built with a ``CircuitBreaker`` (serving/overload.py) layers
recovery discipline over these faults: an ``EngineFailure`` trips the
replica's breaker open (routing excludes it), ``revive()`` resets it,
and a half-open replica takes only bounded probe traffic until it
proves itself — so chaos-injected flapping can't turn the failover
retry path into a retry storm.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Tuple


class EngineFailure(RuntimeError):
    """A replica stopped serving (crashed / unreachable). Raised by a
    killed ``FaultyEngine``; the ``ClusterFrontend`` catches it, marks
    the instance failed, and re-submits its outstanding requests to
    survivors."""


_KINDS = ("kill", "hang", "slow", "recover")


class FaultyEngine:
    """Transparent ``ServingEngine`` proxy with an injectable fault mode."""

    _LOCAL = frozenset({"_eng", "mode", "slow_every", "_skips"})

    def __init__(self, engine):
        object.__setattr__(self, "_eng", engine)
        object.__setattr__(self, "mode", None)
        object.__setattr__(self, "slow_every", 1)
        object.__setattr__(self, "_skips", 0)

    # -- proxy plumbing ----------------------------------------------------
    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_eng"), name)

    def __setattr__(self, name, value):
        if name in type(self)._LOCAL:
            object.__setattr__(self, name, value)
        else:
            setattr(object.__getattribute__(self, "_eng"), name, value)

    @property
    def engine(self):
        """The wrapped live engine (post-mortem inspection in tests)."""
        return object.__getattribute__(self, "_eng")

    # -- fault arming ------------------------------------------------------
    def inject(self, kind: str, *, slow_every: int = 4):
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (want {_KINDS})")
        self.mode = None if kind == "recover" else kind
        if kind == "slow":
            self.slow_every = max(2, slow_every)

    # -- intercepted engine surface ---------------------------------------
    def step(self, now: float):
        if self.mode == "kill":
            raise EngineFailure("replica killed (fault injection)")
        if self.mode == "hang":
            return []  # no error, no progress: watchdog territory
        if self.mode == "slow":
            self._skips += 1
            if self._skips % self.slow_every:
                return []
        return self.engine.step(now)

    def submit(self, req, now: float):
        if self.mode == "kill":
            raise EngineFailure("replica killed (fault injection)")
        # a hung replica still ACCEPTS work (the insidious case: requests
        # sink into its queue until the watchdog declares it dead)
        return self.engine.submit(req, now)

    def drain(self, now: float):
        if self.mode in ("kill", "hang"):
            return []
        return self.engine.drain(now)


class FaultInjector:
    """Deterministic fault schedule over named ``FaultyEngine`` proxies.

    ``schedule(t, name, kind)`` registers an event; ``tick(now)`` (called
    once per virtual-time step, before the cluster steps) fires every
    event due at or before ``now`` and returns the fired
    ``(t, name, kind)`` triples. No wall clock, no randomness — a chaos
    run is exactly reproducible from its schedule.
    """

    def __init__(self, proxies: Dict[str, FaultyEngine]):
        self.proxies = dict(proxies)
        self._events: List[Tuple[float, int, str, str, int]] = []
        self._seq = itertools.count()
        self.fired: List[Tuple[float, str, str]] = []

    def schedule(self, t: float, name: str, kind: str, *,
                 slow_every: int = 4):
        if name not in self.proxies:
            raise KeyError(f"no proxy named {name!r} "
                           f"(have {sorted(self.proxies)})")
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (want {_KINDS})")
        heapq.heappush(self._events,
                       (t, next(self._seq), name, kind, slow_every))

    def tick(self, now: float) -> List[Tuple[float, str, str]]:
        out = []
        while self._events and self._events[0][0] <= now:
            t, _, name, kind, slow_every = heapq.heappop(self._events)
            self.proxies[name].inject(kind, slow_every=slow_every)
            out.append((t, name, kind))
        self.fired.extend(out)
        return out

    @property
    def pending(self) -> int:
        return len(self._events)
