"""Chrome-trace / Perfetto JSON export for request span traces.

Produces the Trace Event Format (the ``{"traceEvents": [...]}`` JSON
Chrome's ``about:tracing`` and https://ui.perfetto.dev load directly):
one complete event (``ph: "X"``) per closed span, instant events
(``ph: "i"``) for zero-duration markers, and metadata events naming
each process/thread.  Mapping:

- **pid** = one serving process lane per replica (requests grouped by
  ``Request.routed_to``; engine-level traces get their own lane),
- **tid** = request id, so one request's lifecycle reads as one row,
- **ts/dur** = serving-clock seconds scaled to microseconds (the trace
  format's unit) — virtual benchmark clocks export fine because the
  viewer only needs relative time.

``write_chrome_trace`` is the one-call path used by
``launch/serve.py --trace-out`` and the chaos harness's ``make trace-demo``.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple

from repro.serving.tracing import Trace

__all__ = [
    "chrome_events",
    "chrome_trace",
    "request_traces",
    "validate_chrome_trace",
    "write_chrome_trace",
]

_US = 1e6  # trace-event timestamps are microseconds


def _san(meta: dict) -> dict:
    """JSON-safe copy of span meta (numpy scalars -> python)."""
    out = {}
    for k, v in meta.items():
        if hasattr(v, "item"):
            v = v.item()
        out[k] = v
    return out


def chrome_events(trace: Trace, *, pid: int, tid: Optional[int] = None,
                  scale: float = _US) -> List[dict]:
    """Trace-event dicts for one Trace (no metadata events)."""
    tid = trace.rid if tid is None else tid
    events = []
    for sp in trace.spans:
        t1 = sp.t1 if sp.t1 is not None else sp.t0  # open spans: render 0-len
        base = {"name": sp.kind, "cat": "serving", "pid": pid, "tid": tid,
                "ts": sp.t0 * scale}
        if t1 > sp.t0:
            base["ph"] = "X"
            base["dur"] = (t1 - sp.t0) * scale
        else:
            base["ph"] = "i"
            base["s"] = "t"  # thread-scoped instant
        if sp.meta:
            base["args"] = _san(sp.meta)
        events.append(base)
    return events


def chrome_trace(traces: Iterable[Tuple[str, Trace]], *,
                 scale: float = _US) -> dict:
    """Assemble a full Chrome-trace document from (lane-name, Trace)
    pairs.  Lane names map to pids; rids map to tids; metadata events
    label both so the viewer shows real names."""
    pids: Dict[str, int] = {}
    events: List[dict] = []
    for lane, trace in traces:
        if lane not in pids:
            pids[lane] = len(pids) + 1
            events.append({"name": "process_name", "ph": "M",
                           "pid": pids[lane], "tid": 0,
                           "args": {"name": lane}})
        pid = pids[lane]
        tid = trace.rid if trace.rid >= 0 else 0
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid,
                       "args": {"name": f"rid {trace.rid}"
                                if trace.rid >= 0 else "engine"}})
        events.extend(chrome_events(trace, pid=pid, tid=tid, scale=scale))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def request_traces(reqs, prefix: str = "") -> List[Tuple[str, Trace]]:
    """(lane, Trace) pairs for every traced request, grouped by the
    replica that served it (``routed_to``; un-routed requests land in a
    'frontend' lane)."""
    out = []
    for r in reqs:
        if getattr(r, "trace", None) is None:
            continue
        lane = prefix + (r.routed_to or "frontend")
        out.append((lane, r.trace))
    return out


def write_chrome_trace(path: str, traces: Iterable[Tuple[str, Trace]], *,
                       scale: float = _US) -> dict:
    doc = chrome_trace(traces, scale=scale)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc: dict) -> List[str]:
    """Structural problems with an exported trace document (empty = ok)."""
    problems = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for i, ev in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                problems.append(f"event[{i}] missing {key!r}")
                break
        else:
            if ev["ph"] == "X" and ("ts" not in ev or "dur" not in ev):
                problems.append(f"event[{i}] complete event without ts/dur")
            elif ev["ph"] == "X" and ev["dur"] < 0:
                problems.append(f"event[{i}] negative duration")
    return problems
