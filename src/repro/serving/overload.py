"""Overload control for multi-tenant serving: SLO tiers, weighted-fair
admission, and a graceful-degradation ladder under saturation.

The survey's central large-scale-serving challenge is traffic that
routinely exceeds provisioned capacity: production systems live or die
by their behavior *past* the knee, not by peak throughput. This module
gives the cluster frontend the four instruments it needs there:

  * ``TenantClass`` — an SLO tier: weight (fair-share ratio), tier rank
    (degradation order), and a token-per-second admission rate;
  * ``TokenBucket`` — per-tenant admission rate limiting whose refusal
    is a *contract*, not an error: a typed ``RequestRejected`` carrying
    the bucket-refill-derived ``retry_after_s``;
  * ``WeightedFairQueue`` — deficit-round-robin (DRR) across tenants
    with EDF-by-TTFT-deadline *within* each tenant. Token-cost-weighted
    quanta make isolation structural: a tenant flooding 3x capacity can
    saturate only its own sub-queue, and every backlogged tenant is
    served within a provable number of rounds
    (``ceil(max_cost / (quantum * weight))`` — see ``max_wait_rounds``);
  * ``OverloadDetector`` — pooled-histogram tail watcher (windowed p99
    TTFT/JCT vs SLO out of ``LoadReport`` v4 wire histograms, plus the
    cost model's backlog estimate as the leading signal) driving the
    deterministic degradation ladder

        NORMAL -> SHED (drop lowest tier)
               -> BROWNOUT (+ trim lower tiers' token budgets)
               -> REJECT (+ typed reject-with-retry-after at submit)

    with consecutive-breach hysteresis so one slow tick never flaps the
    ladder;
  * ``CircuitBreaker`` — failover-path protection: a replica that died
    and came back is HALF_OPEN (bounded probe dispatches) until it
    proves itself, so the retry wave cannot instantly re-flood it.

Everything here is pure host-side, virtual-time arithmetic: no wall
clock, no randomness — an overload episode replays exactly from its
request schedule (the chaos-harness discipline, applied to saturation).
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Mapping, Optional, Tuple

from repro.serving.metrics import Histogram
from repro.serving.request import Request, RequestRejected

__all__ = [
    "TenantClass",
    "TokenBucket",
    "TenantAdmission",
    "WeightedFairQueue",
    "OverloadDetector",
    "CircuitBreaker",
    "NORMAL",
    "SHED",
    "BROWNOUT",
    "REJECT",
    "LADDER_LEVELS",
    "request_cost",
]

# -- degradation ladder levels (strictly ordered) ---------------------------
NORMAL = 0  # serve everything
SHED = 1  # drop the lowest tier's queued work
BROWNOUT = 2  # + trim lower tiers' max_new_tokens budgets
REJECT = 3  # + typed reject-with-retry-after at submit (below top tier)

LADDER_LEVELS = {NORMAL: "normal", SHED: "shed", BROWNOUT: "brownout",
                 REJECT: "reject"}


def request_cost(req: Request) -> float:
    """Token cost of a request for fair-share arithmetic: prompt tokens
    plus the decode budget it asks for. Brownout trims lower this, so a
    trimmed request also charges its tenant less — the ladder and the
    fair queue agree on what 'load' means."""
    return float(req.prompt_len + req.max_new_tokens)


@dataclass(frozen=True)
class TenantClass:
    """One tenant's SLO class.

    ``tier``: degradation rank — the ladder sheds/brownouts/rejects
    strictly from the lowest tier upward; the highest registered tier is
    "protected" (served at every ladder level, never trimmed).
    ``weight``: DRR fair-share ratio (2.0 gets twice the token
    throughput of 1.0 under contention).
    ``rate_tokens_s``/``burst_tokens``: token-bucket admission limit
    (prompt + decode budget tokens per second); rate <= 0 = unlimited.
    ``brownout_frac``: fraction of ``max_new_tokens`` kept when the
    ladder reaches BROWNOUT and this tenant is below the top tier.
    """

    name: str
    tier: int = 0
    weight: float = 1.0
    rate_tokens_s: float = 0.0
    burst_tokens: float = 0.0
    brownout_frac: float = 0.5

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if not (0.0 < self.brownout_frac <= 1.0):
            raise ValueError(
                f"tenant {self.name!r}: brownout_frac must be in (0, 1]")


class TokenBucket:
    """Deterministic token bucket over the serving clock (virtual time).

    ``take(cost, now)`` refills by ``rate * dt``, then either consumes
    ``cost`` (admitted) or returns the finite seconds until the bucket
    will hold ``cost`` — the ``retry_after_s`` the typed rejection
    carries. A request larger than the burst capacity still gets a
    finite answer (time to fill to capacity plus the overhang at rate),
    so *every* rate-limit rejection is retryable.
    """

    __slots__ = ("rate", "capacity", "level", "_t")

    def __init__(self, rate: float, capacity: float):
        self.rate = float(rate)
        self.capacity = float(max(capacity, rate))  # >= 1s of burst
        self.level = self.capacity
        self._t: Optional[float] = None

    def _refill(self, now: float):
        if self._t is None:
            self._t = now
        elif now > self._t:
            self.level = min(self.capacity,
                             self.level + (now - self._t) * self.rate)
            self._t = now

    def take(self, cost: float, now: float) -> float:
        """0.0 = admitted (cost consumed); > 0 = seconds until retry."""
        self._refill(now)
        if cost <= self.level:
            self.level -= cost
            return 0.0
        deficit = min(cost, self.capacity) - self.level
        wait = deficit / self.rate
        if cost > self.capacity:  # oversized: charge the overhang too
            wait += (cost - self.capacity) / self.rate
        return max(wait, 1e-9)


class TenantAdmission:
    """Per-tenant token-bucket admission front door.

    ``admit(req, now)`` raises a typed ``RequestRejected`` with a finite
    ``retry_after_s`` when the tenant's bucket cannot cover the
    request's token cost; tenants without a rate limit (or unknown
    tenants) always pass."""

    def __init__(self, classes: Mapping[str, TenantClass]):
        self.classes = dict(classes)
        self.buckets: Dict[str, TokenBucket] = {
            name: TokenBucket(tc.rate_tokens_s,
                              tc.burst_tokens or tc.rate_tokens_s)
            for name, tc in self.classes.items() if tc.rate_tokens_s > 0}

    def admit(self, req: Request, now: float) -> None:
        bucket = self.buckets.get(req.tenant)
        if bucket is None:
            return
        wait = bucket.take(request_cost(req), now)
        if wait > 0.0:
            raise RequestRejected(
                f"rejected: tenant {req.tenant!r} rate limit "
                f"({bucket.rate:g} tok/s) exceeded; retry after "
                f"{wait:.3f}s", retry_after_s=wait)


class WeightedFairQueue:
    """Deficit-round-robin fair queue across tenants, EDF within.

    Each tenant owns a heap keyed ``(ttft_deadline, seq)`` (or pure
    arrival ``seq`` with ``edf=False``) — the exact ordering the old
    flat frontend queue used, so a single-tenant queue drains
    bit-identically to the pre-DRR frontend. Across tenants, ``pop``
    runs textbook DRR: the round-robin cursor grants each backlogged
    tenant ``quantum * weight`` token-cost credit once per visit and
    serves its EDF head(s) while the deficit covers their cost; a
    drained tenant forfeits its remaining deficit (no credit hoarding).

    Starvation bound: a backlogged tenant's head (cost C, weight w) is
    served within ``ceil(C / (quantum * w))`` of its own grants, each
    round bounded by the other tenants' quantum spend — ``wait_rounds``
    / ``max_wait_rounds`` record the observed grant counts so benches
    can gate "zero starved tenants" on a hard number.
    """

    def __init__(self, *, edf: bool = True, quantum: float = 256.0,
                 weight_of: Optional[Callable[[str], float]] = None):
        self.edf = edf
        self.quantum = float(quantum)
        self._weight_of = weight_of or (lambda name: 1.0)
        self._seq = itertools.count()
        self._heaps: Dict[str, List[tuple]] = {}
        self._order: Deque[str] = deque()  # backlogged tenants, RR order
        self._deficit: Dict[str, float] = {}
        self._granted: Optional[str] = None  # cursor's tenant, post-grant
        self._total = 0
        self.queued_cost = 0.0  # token cost waiting here (overload signal)
        # starvation telemetry: grants a tenant waited for its last pop,
        # and the worst such wait ever observed (rounds, effectively)
        self._grants_waited: Dict[str, int] = {}
        self.wait_rounds: Dict[str, int] = {}
        self.max_wait_rounds = 0

    def __len__(self) -> int:
        return self._total

    def __bool__(self) -> bool:
        return self._total > 0

    def tenants(self) -> List[str]:
        return [t for t in self._order if self._heaps.get(t)]

    def push(self, req: Request) -> None:
        key = req.ttft_deadline if self.edf else 0.0
        heap = self._heaps.get(req.tenant)
        if heap is None:
            heap = self._heaps[req.tenant] = []
        if not heap:
            self._order.append(req.tenant)
            self._deficit.setdefault(req.tenant, 0.0)
            self._grants_waited.setdefault(req.tenant, 0)
        heapq.heappush(heap, (key, next(self._seq), req))
        self._total += 1
        self.queued_cost += request_cost(req)

    def _drop_head_tenant(self):
        name = self._order.popleft()
        self._heaps.pop(name, None)
        self._deficit.pop(name, None)  # forfeit credit: no hoarding
        self._grants_waited.pop(name, None)
        if self._granted == name:
            self._granted = None

    def pop(self) -> Optional[Request]:
        """Next request in DRR order (None when empty). Terminates: every
        full rotation grants each backlogged tenant a quantum, so the
        cheapest head's deficit eventually covers its cost."""
        if not self._total:
            return None
        while True:
            name = self._order[0]
            heap = self._heaps.get(name)
            if not heap:
                self._drop_head_tenant()
                continue
            if self._granted != name:
                self._deficit[name] = (self._deficit.get(name, 0.0)
                                       + self.quantum * self._weight_of(name))
                self._granted = name
                self._grants_waited[name] = self._grants_waited.get(name, 0) + 1
            _, _, head = heap[0]
            if request_cost(head) <= self._deficit[name]:
                heapq.heappop(heap)
                self._deficit[name] -= request_cost(head)
                self._total -= 1
                self.queued_cost = max(0.0,
                                       self.queued_cost - request_cost(head))
                waited = self._grants_waited.get(name, 1)
                self.wait_rounds[name] = waited
                if waited > self.max_wait_rounds:
                    self.max_wait_rounds = waited
                self._grants_waited[name] = 0
                if not heap:
                    self._drop_head_tenant()
                return head
            self._order.rotate(-1)
            self._granted = None

    def drain(self) -> List[Request]:
        """Pop everything (fair order) — requeue/teardown helper."""
        out = []
        while self._total:
            out.append(self.pop())
        return out

    def starvation_bound(self, max_cost: float) -> int:
        """Provable worst-case grants-to-service for a head of
        ``max_cost`` at the smallest registered weight (+1 slack for the
        grant that lands mid-round)."""
        w = min([self._weight_of(t) for t in self._heaps] or [1.0])
        return int(math.ceil(max_cost / (self.quantum * w))) + 1


class OverloadDetector:
    """Pooled-telemetry overload detector driving the degradation ladder.

    ``observe(now, reports)`` is called by the frontend each tick with
    every live replica's ``LoadReport``. Every ``period_s`` of serving
    time it evaluates two signals:

      * **tail signal**: windowed (delta-since-last-evaluation) pooled
        TTFT p99 vs ``ttft_slo_s`` (and JCT p99 vs ``jct_slo_s`` when
        set) out of the reports' exactly-mergeable wire histograms;
      * **backlog signal**: mean cost-model ``backlog_s`` per replica vs
        ``backlog_high_s`` — the *leading* indicator (under deep
        saturation few requests finish, so the tail histograms starve
        exactly when the ladder is needed most).

    ``patience`` consecutive breached evaluations escalate one ladder
    level; ``relax_patience`` consecutive clear evaluations (tail below
    ``relax * slo`` AND backlog below ``relax * backlog_high_s``)
    de-escalate one level. Deterministic in virtual time.
    """

    def __init__(self, *, ttft_slo_s: float, jct_slo_s: float = 0.0,
                 backlog_high_s: Optional[float] = None,
                 period_s: float = 0.25, patience: int = 2,
                 relax_patience: int = 4, relax: float = 0.7,
                 min_window: int = 4, max_level: int = REJECT):
        if ttft_slo_s <= 0:
            raise ValueError("ttft_slo_s must be > 0 (the ladder needs an "
                             "SLO to defend)")
        self.ttft_slo_s = ttft_slo_s
        self.jct_slo_s = jct_slo_s
        self.backlog_high_s = (backlog_high_s if backlog_high_s is not None
                               else 4.0 * ttft_slo_s)
        self.period_s = period_s
        self.patience = max(1, patience)
        self.relax_patience = max(1, relax_patience)
        self.relax = relax
        self.min_window = min_window
        self.max_level = max_level
        self.level = NORMAL
        self.transitions: List[Tuple[float, int]] = []  # (t, new level)
        self._last_eval: Optional[float] = None
        self._breaches = 0
        self._clears = 0
        self._prev: Dict[str, Histogram] = {}  # cumulative snapshots
        self._retry_after = 2.0 * ttft_slo_s
        # last evaluated signals (telemetry / tests)
        self.last_p99_ttft = 0.0
        self.last_p99_jct = 0.0
        self.last_backlog_s = 0.0

    @property
    def level_name(self) -> str:
        return LADDER_LEVELS[self.level]

    def _pooled(self, reports, name: str) -> Optional[Histogram]:
        merged: Optional[Histogram] = None
        for rep in reports:
            for hname, wire in rep.histograms:
                if hname != name:
                    continue
                h = Histogram.from_wire(wire)
                merged = h if merged is None else merged.merge(h)
        return merged

    def observe(self, now: float, reports,
                frontend_backlog_s: float = 0.0) -> int:
        """Fold one tick of pooled telemetry; returns the (possibly
        updated) ladder level. ``frontend_backlog_s`` is the caller's own
        queued work in cost-model seconds — under paced dispatch the
        burst waits at the FRONTEND, so engine-side ``backlog_s`` alone
        would under-read saturation exactly when it matters."""
        if self._last_eval is None:
            self._last_eval = now
            return self.level
        if now - self._last_eval < self.period_s:
            return self.level
        self._last_eval = now
        reports = list(reports)
        n = max(1, len(reports))
        self.last_backlog_s = (sum(r.backlog_s for r in reports) / n
                               + frontend_backlog_s)
        breach = self.last_backlog_s > self.backlog_high_s
        clear = self.last_backlog_s < self.relax * self.backlog_high_s
        for hname, slo, attr in (("ttft_s", self.ttft_slo_s, "last_p99_ttft"),
                                 ("jct_s", self.jct_slo_s, "last_p99_jct")):
            if slo <= 0:
                continue
            cum = self._pooled(reports, hname)
            if cum is None:
                continue
            prev = self._prev.get(hname)
            window = cum.delta(prev) if prev is not None else cum
            if window.count < self.min_window:
                continue  # too few new samples: let the window GROW
                # (the baseline snapshot only advances on evaluation, so
                # a starved tail accumulates instead of resetting)
            self._prev[hname] = cum
            p99 = window.percentile(99)
            setattr(self, attr, p99)
            breach = breach or p99 > slo
            clear = clear and p99 < self.relax * slo
        # retry-after contract: cost-model seconds to drain the pooled
        # backlog across the live replicas, floored at one SLO
        self._retry_after = max(self.ttft_slo_s,
                                min(self.last_backlog_s, 64.0 * self.ttft_slo_s))
        if breach:
            self._breaches += 1
            self._clears = 0
            if self._breaches >= self.patience and self.level < self.max_level:
                self.level += 1
                self._breaches = 0
                self.transitions.append((now, self.level))
        elif clear:
            self._clears += 1
            self._breaches = 0
            if self._clears >= self.relax_patience and self.level > NORMAL:
                self.level -= 1
                self._clears = 0
                self.transitions.append((now, self.level))
        else:
            self._breaches = 0
            self._clears = 0
        return self.level

    def retry_after_s(self) -> float:
        """Finite retry horizon for ladder rejections (cost-model
        backlog drain estimate, clamped)."""
        return self._retry_after


# -- circuit breaker --------------------------------------------------------
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class _BreakerState:
    __slots__ = ("state", "since", "probes", "successes")

    def __init__(self):
        self.state = CLOSED
        self.since = 0.0
        self.probes = 0  # outstanding half-open dispatches
        self.successes = 0


class CircuitBreaker:
    """Per-replica circuit breaker on the failover/recovery path.

    A replica declared dead trips OPEN (``trip``): no dispatches for
    ``cooldown_s``. After the cooldown it is HALF_OPEN: at most
    ``probe_limit`` outstanding requests (``allow`` + ``note_dispatch``)
    until ``close_after`` completions close it — so the backlog and the
    retry wave ramp onto a recovering replica instead of re-flooding it
    into a second death. A failure while HALF_OPEN re-trips.
    Unknown replicas are CLOSED (healthy by default)."""

    def __init__(self, *, cooldown_s: float = 1.0, probe_limit: int = 2,
                 close_after: int = 3):
        self.cooldown_s = cooldown_s
        self.probe_limit = max(1, probe_limit)
        self.close_after = max(1, close_after)
        self._states: Dict[str, _BreakerState] = {}

    def _st(self, key: str) -> _BreakerState:
        st = self._states.get(key)
        if st is None:
            st = self._states[key] = _BreakerState()
        return st

    def state(self, key: str, now: float) -> str:
        st = self._states.get(key)
        if st is None:
            return CLOSED
        if st.state == OPEN and now - st.since >= self.cooldown_s:
            st.state = HALF_OPEN
            st.since = now
            st.probes = 0
            st.successes = 0
        return st.state

    def trip(self, key: str, now: float) -> None:
        st = self._st(key)
        st.state = OPEN
        st.since = now
        st.probes = 0
        st.successes = 0

    def allow(self, key: str, now: float) -> bool:
        s = self.state(key, now)
        if s == CLOSED:
            return True
        if s == OPEN:
            return False
        return self._st(key).probes < self.probe_limit

    def note_dispatch(self, key: str, now: float) -> None:
        if self.state(key, now) == HALF_OPEN:
            self._st(key).probes += 1

    def note_success(self, key: str, now: float) -> None:
        if self.state(key, now) != HALF_OPEN:
            return
        st = self._st(key)
        st.probes = max(0, st.probes - 1)
        st.successes += 1
        if st.successes >= self.close_after:
            st.state = CLOSED
            st.since = now

    def note_failure(self, key: str, now: float) -> None:
        self.trip(key, now)
