"""Serving engine: jit'd prefill / decode steps + a continuous-batching
executor (the survey's "adaptive batching" [8][4] in its modern form).

The engine maintains B decode slots backed by one batched cache pytree.
Each slot runs an independent request (per-slot positions / rolling KV).
The steady-state decode loop is zero-copy and zero-recompile:

  * buffer donation — the batched KV cache is donated to the jit'd decode
    tick and to the jit'd slot-scatter (``cache_insert``), so XLA updates
    it in place instead of copying every leaf every tick;
  * device-resident tokens — the sampled-token carry and (m)rope positions
    never leave the device in steady state; token values are synced to the
    host once every ``sync_every`` ticks in a single transfer;
  * bucketed prefill — prompts are padded to power-of-two buckets so jit's
    shape-keyed compile cache retraces once per bucket, not once per
    prompt length (``prefill_traces`` is the compile-count probe);
  * chunked prefill — long prompts are split into fixed-size chunks that
    interleave with decode ticks (``ChunkedPrefillPolicy`` decides how
    many chunks fit per tick from the cost model), so admitting a long
    request no longer stalls in-flight decode slots;
  * cost-model admission — slot count and queue flush deadlines come from
    ``repro.core.misd.batching.plan_admission`` instead of constants.

All steps are pure jit functions; the executor is the only stateful part.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from functools import partial
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.misd.batching import BatchAccumulator, plan_admission
from repro.core.misd.scheduler import ChunkedPrefillPolicy
from repro.models import decode_step, forward, init_cache
from repro.models.blocks import KV_CACHE_BLOCKS
from repro.models.model import block_program
from repro.serving.request import Request, ServeMetrics


# ---------------------------------------------------------------------------
# jit'd steps (also the units the dry-run lowers)
# ---------------------------------------------------------------------------


def prefill_step(cfg, params, batch, *, window: int):
    """Full-prompt forward filling a fresh cache. Returns (last_token_logits,
    cache)."""
    b = (batch["frames"] if cfg.modality == "audio" else batch["tokens"]).shape[0]
    cache = init_cache(cfg, b, window)
    logits, _, cache = forward(cfg, params, batch, mode="prefill", cache=cache)
    return logits[:, -1], cache


def bucketed_prefill_step(cfg, params, batch, true_len, *, window: int):
    """Prefill a prompt padded (at the end) to a bucket length. ``true_len``
    is a traced int32 scalar, so every prompt length inside one bucket
    shares a single trace. Causality keeps the pad garbage out of the real
    tokens' keys; the returned cache's ``pos`` is clamped to ``true_len``
    so decode's validity mask hides the garbage slots until the rolling
    write index overwrites them. Returns (first_token (B,), last_true_token
    logits (B, V), cache)."""
    b = batch["tokens"].shape[0]
    cache = init_cache(cfg, b, window)
    logits, _, cache = forward(cfg, params, batch, mode="prefill", cache=cache)
    true_len = jnp.asarray(true_len, jnp.int32)
    last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                        keepdims=False)
    cache["pos"] = jnp.full((b,), true_len, jnp.int32)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return tok, last, cache


def prefill_chunk_step(cfg, params, cache, tokens, true_len):
    """One chunk of incremental prefill into a (B=1) cache via the
    multi-token decode path. ``tokens`` (B, C) may carry end padding on the
    final chunk; ``true_len`` (traced int32) clamps the advanced position
    so the pad keys stay masked. Returns (token (B,) argmax at the last
    true position, last-true-position logits (B, V), new_cache)."""
    b, c = tokens.shape
    start = cache["pos"]
    batch = {"tokens": tokens}
    if cfg.rope_variant == "mrope":
        p = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        batch["positions"] = jnp.broadcast_to(p[None], (3, b, c))
    logits, new_cache = decode_step(cfg, params, cache, batch)
    true_len = jnp.asarray(true_len, jnp.int32)
    new_cache["pos"] = jnp.minimum(new_cache["pos"], true_len)
    idx = jnp.clip(true_len - 1 - start[0], 0, c - 1)
    last = jax.lax.dynamic_index_in_dim(logits, idx, axis=1, keepdims=False)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return tok, last, new_cache


def serve_step(cfg, params, cache, batch):
    """One decode step for every active slot: ONE new token against the KV
    cache. Returns (next_tokens (B,), logits (B,V), new_cache)."""
    logits, new_cache = decode_step(cfg, params, cache, batch)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt, logits[:, -1], new_cache


def decode_tick(cfg, params, cache, tokens):
    """The engine's steady-state step: ``tokens`` (B,) is the device-resident
    last-token carry; (m)rope positions are built on device from the cache's
    ``pos`` leaf — no host round-trip. Returns (next_tokens (B,), new_cache).
    Jitted with the cache donated: the KV pytree updates in place."""
    batch = {"tokens": tokens[:, None]}
    if cfg.rope_variant == "mrope":
        b = tokens.shape[0]
        batch["positions"] = jnp.broadcast_to(
            cache["pos"][None, :, None], (3, b, 1))
    logits, new_cache = decode_step(cfg, params, cache, batch)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt, new_cache


def decode_scan_step(cfg, params, cache, tokens, *, n: int):
    """``n`` fused decode ticks as one jitted ``lax.scan``: one dispatch and
    one host sync per ``n`` tokens instead of per token. The engine uses
    this whenever nothing interrupts the window (no pending admissions, no
    prefill chunks, every active request has >= n tokens to go), falling
    back to single ticks at scheduling boundaries. Returns
    (final_tokens (B,), token_history (n, B), new_cache)."""

    def body(carry, _):
        toks, c = carry
        nxt, c = decode_tick(cfg, params, c, toks)
        return (nxt, c), nxt

    (toks, cache), hist = jax.lax.scan(body, (tokens, cache), None, length=n)
    return toks, hist, cache


def _cache_batch_axis(big_shape, small_shape, batch: int):
    """Find the slot (batch) axis of a batched cache leaf: the axis where
    the batched leaf has ``batch`` entries and the B=1 leaf has one. Both
    conditions are required — stacked body leaves carry an ``n_repeat``
    leading axis that can collide with ``batch`` by value."""
    for ax, (n_big, n_small) in enumerate(zip(big_shape, small_shape)):
        if n_big == batch and n_small == 1:
            return ax
    raise ValueError(f"no batch axis {batch} in {big_shape} vs {small_shape}")


def cache_insert(batched_cache, single_cache, slot, batch: int):
    """Scatter a B=1 cache into slot ``slot`` of a batched cache. ``slot``
    may be a traced int32 scalar — one trace covers every slot index (the
    engine jits this with the batched cache donated, making admission a
    true in-place scatter instead of a full-cache copy)."""

    def ins(big, small):
        ax = _cache_batch_axis(big.shape, small.shape, batch)
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, ax)

    return jax.tree.map(ins, batched_cache, single_cache)


def _token_set(tokens, tok, slot):
    """Write a (1,) token into the (B,) device carry at ``slot`` (traced)."""
    return jax.lax.dynamic_update_slice_in_dim(tokens, tok.astype(tokens.dtype),
                                               slot, 0)


# ---------------------------------------------------------------------------
# engine helpers
# ---------------------------------------------------------------------------


def _attn_only(cfg) -> bool:
    """True when every block's decode cache is a KV buffer (no recurrent
    state) — the precondition for end-padded bucketing and chunked prefill."""
    pattern, _, tail = block_program(cfg)
    return all(bt in KV_CACHE_BLOCKS for bt in pattern + tail)


def _min_cache_window(cfg, window: int) -> int:
    """Smallest KV ring among the model's attention blocks: bucketed /
    chunked prefill must fit entirely inside it (a multi-query chunk that
    wraps the ring would expose chunk-future keys to earlier queries)."""
    pattern, _, tail = block_program(cfg)
    w = window
    for bt in pattern + tail:
        if bt == "local_attn":
            w = min(w, cfg.local_window)
    return w


def prompt_bucket(n: int, *, min_bucket: int = 16) -> int:
    """Power-of-two bucket for a prompt of ``n`` tokens."""
    return max(min_bucket, 1 << max(n - 1, 1).bit_length())


@dataclass
class _PrefillJob:
    """A request mid-way through chunked prefill (slot reserved, B=1 cache
    accumulating chunks)."""

    req: Request
    slot: int
    cache: dict
    tokens: jnp.ndarray  # (1, padded_len) device-resident prompt
    true_len: np.int32
    next_off: int = 0


# ---------------------------------------------------------------------------
# continuous-batching executor
# ---------------------------------------------------------------------------


class ServingEngine:
    """Single-instance engine (SISD quadrant) with continuous batching.

    ``slots``: max concurrent decode streams (0/None -> derived from the
    cost model via ``plan_admission``). ``window``: KV window.
    ``sync_every``: decode ticks between device->host token syncs (forced
    to 1 when ``eos_id`` >= 0, since stopping needs token values).
    ``chunk_prefill``: chunk size for interleaved prefill (0 disables).
    ``bucket_prompts``: pad prefill to power-of-two buckets.
    ``donate``: donate the KV cache to the jit'd steps (in-place update).
    """

    def __init__(self, cfg, params, *, slots: Optional[int] = 4,
                 window: int = 512, eos_id: int = -1, sync_every: int = 8,
                 donate: bool = True, bucket_prompts: bool = True,
                 chunk_prefill: int = 64, sla_s: float = 0.05,
                 n_chips: int = 1,
                 prefill_policy: Optional[ChunkedPrefillPolicy] = None):
        self.cfg = cfg
        self.params = params
        self.plan = plan_admission(cfg, context=window, sla_s=sla_s,
                                   n_chips=n_chips)
        if not slots:
            slots = self.plan.slots
        self.slots = slots
        self.window = window
        self.eos_id = eos_id
        self.sync_every = 1 if eos_id >= 0 else max(1, sync_every)
        self.metrics = ServeMetrics()

        self._attn_only = _attn_only(cfg)
        self._min_window = _min_cache_window(cfg, window)
        self.bucket_prompts = bucket_prompts and self._attn_only
        if prefill_policy is not None:  # the policy's chunk size wins
            chunk_prefill = prefill_policy.chunk
        self.chunk = chunk_prefill if (chunk_prefill and self._attn_only) else 0
        self.prefill_policy = prefill_policy or ChunkedPrefillPolicy(
            chunk=self.chunk or 64)

        # --- device state (exclusively owned: donation-safe) ---
        self.cache = init_cache(cfg, slots, window)
        self._tokens = jnp.zeros((slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.decoding: List[bool] = [False] * slots
        self._unsynced: List[jnp.ndarray] = []  # per-tick (B,) token arrays
        self._finished: List[Request] = []
        self._jobs: Deque[_PrefillJob] = deque()

        # --- admission queue (deadline from the cost model) ---
        self.backlog: Deque[Request] = deque()
        self.admission = BatchAccumulator(
            target_batch=slots, deadline_s=self.plan.flush_deadline_s)

        # --- jit'd steps with compile-count probes ---
        self.prefill_traces = 0
        self.decode_traces = 0
        donate_cache = (1,) if donate else ()

        def _probed_decode(params, cache, tokens):
            self.decode_traces += 1
            return decode_tick(cfg, params, cache, tokens)

        def _probed_scan(params, cache, tokens):
            self.decode_traces += 1
            return decode_scan_step(cfg, params, cache, tokens,
                                    n=self.sync_every)

        def _probed_bucketed(params, batch, true_len):
            self.prefill_traces += 1
            return bucketed_prefill_step(cfg, params, batch, true_len,
                                         window=window)

        def _probed_exact(params, batch):
            self.prefill_traces += 1
            return prefill_step(cfg, params, batch, window=window)

        self._decode = jax.jit(_probed_decode, donate_argnums=donate_cache)
        self._decode_scan = jax.jit(_probed_scan, donate_argnums=donate_cache)
        self._prefill_bucketed = jax.jit(_probed_bucketed)
        self._prefill_exact = jax.jit(_probed_exact)
        self._prefill_chunk = jax.jit(
            partial(prefill_chunk_step, cfg),
            donate_argnums=(1,) if donate else ())
        self._insert = jax.jit(
            partial(cache_insert, batch=slots),
            donate_argnums=(0,) if donate else ())
        self._set_token = jax.jit(_token_set)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request, now: float):
        """Admit immediately while free capacity exists (holding a request
        back from an idle slot buys nothing); once saturated, queue and
        batch admissions up to the cost-model deadline (``plan_admission``)
        so freed slots refill in groups."""
        if (not self.backlog and not self.admission.pending
                and self.try_admit(req, now)):
            return
        flushed = self.admission.add(req, now)
        if flushed:
            self.backlog.extend(flushed)
            self._drain_backlog(now)

    def _pump_admissions(self, now: float):
        flushed = self.admission.poll(now)
        if flushed:
            self.backlog.extend(flushed)
        self._drain_backlog(now)

    def _drain_backlog(self, now: float):
        while self.backlog:
            if not self.try_admit(self.backlog[0], now):
                break
            self.backlog.popleft()

    def try_admit(self, req: Request, now: float) -> bool:
        """Claim a free slot for ``req``. Long prompts (when chunking is on
        and the prompt fits the KV ring) enter chunked prefill: the slot is
        reserved and the prompt is processed ``chunk`` tokens per tick,
        interleaved with decode. Short prompts prefill immediately
        (bucketed when possible)."""
        for i, slot in enumerate(self.active):
            if slot is None and not any(j.slot == i for j in self._jobs):
                if self._chunkable(req):
                    self._start_chunked(req, i)
                else:
                    self._admit_now(req, i, now)
                return True
        return False

    def _chunkable(self, req: Request) -> bool:
        return (self.chunk > 0
                and req.prompt_len > self.chunk
                and _padded_len(req.prompt_len, self.chunk) <= self._min_window)

    def _bucket_for(self, plen: int) -> Optional[int]:
        if not self.bucket_prompts:
            return None
        b = prompt_bucket(plen)
        return b if b <= self._min_window else None

    def _admit_now(self, req: Request, slot: int, now: float):
        plen = req.prompt_len
        bucket = self._bucket_for(plen)
        if bucket is not None:
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :plen] = req.prompt
            batch = {"tokens": jnp.asarray(padded)}
            if self.cfg.rope_variant == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(bucket, dtype=jnp.int32), (3, 1, bucket))
            tok, _, cache1 = self._prefill_bucketed(
                self.params, batch, np.int32(plen))
        else:
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            if self.cfg.rope_variant == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(plen, dtype=jnp.int32), (3, 1, plen))
            logits, cache1 = self._prefill_exact(self.params, batch)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._activate(req, slot, tok, cache1, now)

    def _start_chunked(self, req: Request, slot: int):
        padded_len = _padded_len(req.prompt_len, self.chunk)
        padded = np.zeros((1, padded_len), np.int32)
        padded[0, :req.prompt_len] = req.prompt
        self._jobs.append(_PrefillJob(
            req=req, slot=slot,
            cache=init_cache(self.cfg, 1, self.window),
            tokens=jnp.asarray(padded),
            true_len=np.int32(req.prompt_len)))
        self.active[slot] = req  # reserve (decoding stays False)

    def _run_prefill_chunks(self, now: float):
        if not self._jobs:
            return
        pending = sum(
            (j.tokens.shape[1] - j.next_off) // self.chunk for j in self._jobs)
        n = self.prefill_policy.chunks_this_tick(
            self.cfg, n_decoding=self.n_decoding, pending_chunks=pending,
            context=self.window)
        for _ in range(n):
            if not self._jobs:
                break
            job = self._jobs[0]
            chunk_toks = jax.lax.slice_in_dim(
                job.tokens, job.next_off, job.next_off + self.chunk, axis=1)
            tok, _, job.cache = self._prefill_chunk(
                self.params, job.cache, chunk_toks, job.true_len)
            job.next_off += self.chunk
            self.metrics.prefill_chunks += 1
            if job.next_off >= job.tokens.shape[1]:
                self._jobs.popleft()
                self._activate(job.req, job.slot, tok, job.cache, now)

    def _activate(self, req: Request, slot: int, tok, cache1, now: float):
        """Install a prefilled request into its slot: scatter the B=1 cache
        (donated, in-place), set the device token carry, record the first
        token. Forces a token flush first so the deferred-sync window only
        ever spans a fixed slot membership."""
        self._flush(now)
        self.cache = self._insert(self.cache, cache1, np.int32(slot))
        self._tokens = self._set_token(self._tokens, tok, np.int32(slot))
        req.output.append(int(tok[0]))
        req.prefill_done = now
        self.metrics.ttfts.append(req.ttft)
        self.active[slot] = req
        self.decoding[slot] = True

    # -- decode tick --------------------------------------------------------
    def step(self, now: float) -> List[Request]:
        """One engine tick: pump queued admissions, run prefill chunks per
        the interleave policy, then batched decode. In steady state (no
        pending admissions or prefill chunks, every active request has >=
        sync_every tokens to go) the whole deferred-sync window runs as ONE
        fused jitted scan — one dispatch and one host transfer per
        sync_every tokens. Scheduling boundaries fall back to single ticks.
        Returns the requests that finished (host-visible) this tick."""
        self._pump_admissions(now)
        self._run_prefill_chunks(now)
        if not any(self.decoding):
            return self._take_finished()
        if self._fusable():
            toks, hist, self.cache = self._decode_scan(
                self.params, self.cache, self._tokens)
            self._tokens = toks
            self.metrics.decode_ticks += self.sync_every
            self._distribute(np.asarray(hist), now)
            return self._take_finished()
        nxt, self.cache = self._decode(self.params, self.cache, self._tokens)
        self._tokens = nxt
        self._unsynced.append(nxt)
        self.metrics.decode_ticks += 1
        pend = len(self._unsynced)
        if (pend >= self.sync_every
                or any(r is not None and d
                       and len(r.output) + pend >= r.max_new_tokens
                       for r, d in zip(self.active, self.decoding))):
            self._flush(now)
        return self._take_finished()

    def _fusable(self) -> bool:
        return (self.sync_every > 1
                and not self._unsynced
                and not self._jobs
                and not self.backlog
                and not self.admission.pending
                and all(r.max_new_tokens - len(r.output) >= self.sync_every
                        for r, d in zip(self.active, self.decoding)
                        if r is not None and d))

    def _flush(self, now: float = None):
        """One host sync for the whole deferred window: transfers the
        stacked (T, B) token block and distributes tokens to requests."""
        if not self._unsynced:
            return
        toks = np.asarray(jnp.stack(self._unsynced))
        self._unsynced = []
        self._distribute(toks, now)

    def _distribute(self, toks: np.ndarray, now: float = None):
        """Hand a (T, B) host token block to the per-slot requests."""
        self.metrics.host_syncs += 1
        t_now = time.time() if now is None else now
        for i, r in enumerate(self.active):
            if r is None or not self.decoding[i]:
                continue
            for t in range(toks.shape[0]):
                if r.done:
                    break
                tok = int(toks[t, i])
                r.output.append(tok)
                if r.done or tok == self.eos_id:
                    r.finish_time = t_now
                    self._finished.append(r)
                    self.active[i] = None
                    self.decoding[i] = False
                    self.metrics.completed += 1
                    self.metrics.total_tokens += len(r.output)
                    self.metrics.jcts.append(t_now - r.arrival_time)
                    break

    def _take_finished(self) -> List[Request]:
        out, self._finished = self._finished, []
        return out

    def drain(self, now: float):
        """Flush any deferred tokens (end-of-run bookkeeping)."""
        self._flush(now)
        return self._take_finished()

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    @property
    def n_decoding(self) -> int:
        return sum(self.decoding)

    @property
    def n_prefilling(self) -> int:
        return len(self._jobs)


def _padded_len(n: int, chunk: int) -> int:
    return ((n + chunk - 1) // chunk) * chunk


def generate(cfg, params, prompt: np.ndarray, max_new_tokens: int,
             *, window: int = 512) -> List[int]:
    """Simple single-request generation helper (examples/quickstart)."""
    eng = ServingEngine(cfg, params, slots=1, window=window)
    req = Request(rid=0, prompt=prompt, max_new_tokens=max_new_tokens)
    assert eng.try_admit(req, now=0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    return req.output
