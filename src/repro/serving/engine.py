"""Serving engine: jit'd prefill / decode steps + a continuous-batching
executor (the survey's "adaptive batching" [8][4] in its modern form).

The engine maintains B decode slots backed by one batched cache pytree.
Each slot runs an independent request (per-slot positions / rolling KV).
The steady-state decode loop is zero-copy and zero-recompile:

  * buffer donation — the batched KV cache is donated to the jit'd decode
    tick and to the jit'd slot-scatter (``cache_insert``), so XLA updates
    it in place instead of copying every leaf every tick;
  * device-resident tokens — the sampled-token carry and (m)rope positions
    never leave the device in steady state; token values are synced to the
    host once every ``sync_every`` ticks in a single transfer;
  * bucketed prefill — prompts are padded to power-of-two buckets so jit's
    shape-keyed compile cache retraces once per bucket, not once per
    prompt length (``prefill_traces`` is the compile-count probe);
  * chunked prefill — long prompts are split into fixed-size chunks that
    interleave with decode ticks (``ChunkedPrefillPolicy`` decides how
    many chunks fit per tick from the cost model), so admitting a long
    request no longer stalls in-flight decode slots;
  * cost-model admission — slot count and queue flush deadlines come from
    ``repro.core.misd.batching.plan_admission`` instead of constants;
  * shared-prefix KV cache (opt-in ``prefix_cache=True``, paged only) —
    finished prompts' full pages stay in a radix ``PrefixIndex``; a new
    request aliases the longest cached prefix (refcounted pages, zero
    prefill compute for the hit) and prefills only its suffix from a
    nonzero offset, with copy-on-write for a partially-matched tail page;
  * device-resident sampling — per-request ``SamplingParams``
    (temperature / top-k / top-p / seed; greedy is the degenerate
    default) live in a per-slot device state next to the token carry:
    greedy and stochastic slots compose by masking inside the SAME
    decode trace and the SAME fused scan window (no per-config retrace),
    and noise is keyed by (seed, absolute position) so seeded streams
    are bit-identical across restarts, slot assignments, and replicas.

All steps are pure jit functions; the executor is the only stateful part.
"""
from __future__ import annotations

import contextlib
import math
import time
from collections import deque
from dataclasses import dataclass
from functools import partial
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import (
    collective_s_per_axis,
    estimate_backlog_s,
    estimate_decode,
    estimate_prefill,
    kv_bytes_per_token,
)
from repro.core.misd.batching import BatchAccumulator, plan_admission
from repro.core.misd.scheduler import ChunkedPrefillPolicy
from repro.core.simd.sharding import (
    cache_pspecs,
    paged_cache_pspecs,
    param_pspecs,
    serving_policy,
    to_shardings,
)
from repro.launch.mesh import make_serving_mesh
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    paged_ok,
    quantize_weights,
)
from repro.models.blocks import KV_CACHE_BLOCKS
from repro.models.layers import sample_tokens
from repro.models.model import block_program
from repro.models.moe import drop_free_group
from repro.serving.config import DeviceTopology, EngineConfig
from repro.serving.metrics import MetricsRegistry, latency_histogram
from repro.serving.paging import PageAllocator, PrefixHit, PrefixIndex
from repro.serving.request import (
    Request,
    RequestRejected,
    RequestState,
    SamplingParams,
    ServeMetrics,
)
from repro.serving.telemetry import LoadReport
from repro.serving.tracing import Trace, Tracer
from repro.util import sharding_hints

__all__ = [  # noqa: F822 — LoadReport/DeviceTopology re-exported for callers
    "DeviceTopology", "EngineConfig", "LoadReport", "PREEMPT_POLICIES",
    "ServingEngine", "bucketed_prefill_step", "cache_insert",
    "decode_scan_step", "decode_tick", "generate", "init_sampling_state",
    "page_table_append", "paged_prefill_step", "pages_insert",
    "pages_insert_prefix", "prefill_chunk_step", "prefill_step",
    "prefix_seed_cache", "prompt_bucket", "sampling_row", "sampling_set",
    "serve_step", "slot_release",
]


# ---------------------------------------------------------------------------
# jit'd steps (also the units the dry-run lowers)
# ---------------------------------------------------------------------------


def prefill_step(cfg, params, batch, *, window: int, kv_dtype: str = ""):
    """Full-prompt forward filling a fresh cache. Returns (last_token_logits,
    cache)."""
    b = (batch["frames"] if cfg.modality == "audio" else batch["tokens"]).shape[0]
    cache = init_cache(cfg, b, window, kv_dtype)
    logits, _, cache = forward(cfg, params, batch, mode="prefill", cache=cache)
    return logits[:, -1], cache


def bucketed_prefill_step(cfg, params, batch, true_len, *, window: int,
                          kv_dtype: str = ""):
    """Prefill a prompt padded (at the end) to a bucket length. ``true_len``
    is a traced int32 scalar, so every prompt length inside one bucket
    shares a single trace. Causality keeps the pad garbage out of the real
    tokens' keys; the returned cache's ``pos`` is clamped to ``true_len``
    so decode's validity mask hides the garbage slots until the rolling
    write index overwrites them. Returns (first_token (B,), last_true_token
    logits (B, V), cache)."""
    b = batch["tokens"].shape[0]
    cache = init_cache(cfg, b, window, kv_dtype)
    logits, _, cache = forward(cfg, params, batch, mode="prefill", cache=cache)
    true_len = jnp.asarray(true_len, jnp.int32)
    last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                        keepdims=False)
    cache["pos"] = jnp.full((b,), true_len, jnp.int32)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return tok, last, cache


def prefill_chunk_step(cfg, params, cache, tokens, true_len):
    """One chunk of incremental prefill into a (B=1) cache via the
    multi-token decode path. ``tokens`` (B, C) may carry end padding on the
    final chunk; ``true_len`` (traced int32) clamps the advanced position
    so the pad keys stay masked. Returns (token (B,) argmax at the last
    true position, last-true-position logits (B, V), new_cache)."""
    b, c = tokens.shape
    start = cache["pos"]
    batch = {"tokens": tokens}
    if cfg.rope_variant == "mrope":
        p = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        batch["positions"] = jnp.broadcast_to(p[None], (3, b, c))
    logits, new_cache = decode_step(cfg, params, cache, batch)
    true_len = jnp.asarray(true_len, jnp.int32)
    new_cache["pos"] = jnp.minimum(new_cache["pos"], true_len)
    idx = jnp.clip(true_len - 1 - start[0], 0, c - 1)
    last = jax.lax.dynamic_index_in_dim(logits, idx, axis=1, keepdims=False)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return tok, last, new_cache


def paged_prefill_step(cfg, params, batch, true_len, kv_dtype: str = ""):
    """Prefill for the paged engine: the B=1 cache window IS the padded
    prompt length (a LINEAR buffer — no rolling wrap), so every key of the
    padded prompt survives for the page scatter. ``true_len`` is traced;
    one trace serves every prompt inside a bucket. Returns (first_token
    (B,), last-true-token logits (B, V), linear cache with pos=true_len)."""
    padded = batch["tokens"].shape[1]
    return bucketed_prefill_step(cfg, params, batch, true_len, window=padded,
                                 kv_dtype=kv_dtype)


def pages_insert(paged_cache, linear_cache, pages, slot, true_len):
    """Admit a prefilled request into the paged cache: scatter the B=1
    linear prefill cache's K/V into the pool pages granted to the slot,
    then point the slot's page-table row at them and set its position.

    No other slot's state is touched — admission cost is O(prompt pages),
    not O(slots * window). ``pages`` (n,), ``slot`` and ``true_len`` may
    all be traced: one trace covers every slot index and page assignment
    for a given bucket size (n is static per bucket). The row is written
    in full, so entries past the prompt reset to the trash page."""
    n = pages.shape[0]

    def ins(pool, small):
        # pool: (P, ps, kv, hd), or (n_repeat, P, ps, kv, hd) for stacked
        # body leaves; small: the matching linear cache leaf holding the
        # prompt's n * ps tokens at the front of its window axis (wider
        # buffers — the shared chunked-prefill cache — are sliced down, so
        # every chunked job reuses ONE compiled chunk step; the batch axis
        # is 1 and is absorbed by the reshape).
        ax = small.ndim - 4
        ps = pool.shape[ax + 1]
        if small.shape[ax + 1] > n * ps:
            small = jax.lax.slice_in_dim(small, 0, n * ps, axis=ax + 1)
        if ax == 0:
            chunks = small.reshape((n, ps) + small.shape[2:])
            return pool.at[pages].set(chunks.astype(pool.dtype))
        chunks = small.reshape((small.shape[0], n, ps) + small.shape[3:])
        return pool.at[:, pages].set(chunks.astype(pool.dtype))

    table = paged_cache["page_table"]
    row = jnp.zeros((table.shape[1],), jnp.int32).at[:n].set(pages)
    true_len = jnp.asarray(true_len, jnp.int32)
    return {
        "body": jax.tree.map(ins, paged_cache["body"], linear_cache["body"]),
        "tail": jax.tree.map(ins, paged_cache["tail"], linear_cache["tail"]),
        "page_table": jax.lax.dynamic_update_slice(table, row[None], (slot, 0)),
        "pos": jax.lax.dynamic_update_slice(
            paged_cache["pos"], true_len[None], (slot,)),
    }


def prefix_seed_cache(paged_cache, pages, start):
    """Gather a cached page chain into a fresh B=1 LINEAR cache — the
    working buffer for suffix-offset prefill. ``pages`` (max_pages,) is
    the hit's chain (full pages + the shared COW tail) padded with the
    trash page, so its shape is FIXED: one trace covers every hit length.
    Page i lands at linear positions [i*ps, (i+1)*ps); ``start`` (traced)
    is the suffix-restart offset -> the cache's pos, which masks both the
    trash-page garbage beyond the chain and the donor's tokens beyond the
    matched span. Read-only over the pools (never donated)."""

    def gather(pool):
        ax = pool.ndim - 4  # page axis (stacked body leaves lead n_repeat)
        take = jnp.take(pool, pages, axis=ax)  # (..., n, ps, kv, hd)
        s = take.shape
        merged = take.reshape(s[:ax] + (s[ax] * s[ax + 1],) + s[ax + 2:])
        return jnp.expand_dims(merged, ax)  # B=1 axis where pages were

    return {
        "body": jax.tree.map(gather, paged_cache["body"]),
        "tail": jax.tree.map(gather, paged_cache["tail"]),
        "pos": jnp.full((1,), jnp.asarray(start, jnp.int32), jnp.int32),
    }


def pages_insert_prefix(paged_cache, linear_cache, scatter_pages, table_pages,
                        slot, true_len):
    """Admit a prefix-hit request: the slot's table row aliases the cached
    full pages while only privately-owned pages receive the linear
    cache's data. Both page rows are max_pages wide (the linear buffer IS
    max_seq tokens), so ONE trace covers every hit length / suffix shape.

    ``scatter_pages`` carries the trash page at every aliased (shared)
    position — shared pages are never written. This is where copy-on-
    write lands: the shared tail page's matched tokens were gathered into
    the linear buffer (prefix_seed_cache), the suffix prefill overwrote
    from the hit boundary on, and the whole span now scatters into the
    private replacement page named by ``table_pages``."""
    n = scatter_pages.shape[0]

    def ins(pool, small):
        ax = small.ndim - 4
        ps = pool.shape[ax + 1]
        if ax == 0:
            chunks = small.reshape((n, ps) + small.shape[2:])
            return pool.at[scatter_pages].set(chunks.astype(pool.dtype))
        chunks = small.reshape((small.shape[0], n, ps) + small.shape[3:])
        return pool.at[:, scatter_pages].set(chunks.astype(pool.dtype))

    table = paged_cache["page_table"]
    true_len = jnp.asarray(true_len, jnp.int32)
    return {
        "body": jax.tree.map(ins, paged_cache["body"], linear_cache["body"]),
        "tail": jax.tree.map(ins, paged_cache["tail"], linear_cache["tail"]),
        "page_table": jax.lax.dynamic_update_slice(
            table, table_pages[None], (slot, 0)),
        "pos": jax.lax.dynamic_update_slice(
            paged_cache["pos"], true_len[None], (slot,)),
    }


def page_table_append(paged_cache, slot, idx, page):
    """Grant one more page to a slot mid-decode: table[slot, idx] = page.
    All three indices are traced — one trace covers every grant."""
    new = dict(paged_cache)
    new["page_table"] = jax.lax.dynamic_update_slice(
        paged_cache["page_table"],
        jnp.asarray(page, jnp.int32)[None, None], (slot, idx))
    return new


def slot_release(paged_cache, slot):
    """Retire a finished slot: point its whole page-table row at the trash
    page and zero its position. The slot keeps riding in the fused decode
    batch, but its writes can no longer land in a reclaimed page."""
    table = paged_cache["page_table"]
    new = dict(paged_cache)
    new["page_table"] = jax.lax.dynamic_update_slice(
        table, jnp.zeros((1, table.shape[1]), jnp.int32), (slot, 0))
    new["pos"] = jax.lax.dynamic_update_slice(
        paged_cache["pos"], jnp.zeros((1,), jnp.int32), (slot,))
    return new


def serve_step(cfg, params, cache, batch):
    """One decode step for every active slot: ONE new token against the KV
    cache. Returns (next_tokens (B,), logits (B,V), new_cache)."""
    logits, new_cache = decode_step(cfg, params, cache, batch)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt, logits[:, -1], new_cache


def init_sampling_state(slots: int) -> dict:
    """Per-slot device-resident sampling state: the greedy mask, the logit-
    processor parameters, and each slot's PRNG key material (raw uint32
    pairs, scatterable like any other carry leaf). Defaults are all-greedy,
    so a fresh engine's decode pays no sampling work."""
    return {
        "greedy": jnp.ones((slots,), jnp.bool_),
        "temperature": jnp.ones((slots,), jnp.float32),
        "top_k": jnp.zeros((slots,), jnp.int32),
        "top_p": jnp.ones((slots,), jnp.float32),
        "key": jnp.zeros((slots, 2), jnp.uint32),
    }


_GREEDY_KEY = np.zeros((2,), np.uint32)


def sampling_row(sp: Optional[SamplingParams]) -> dict:
    """Host-side one-slot update for ``init_sampling_state`` leaves. Every
    value is passed traced, so one ``sampling_set`` trace covers every
    request configuration (no per-config retrace). Greedy rows skip the
    PRNG key init — their lane never draws."""
    sp = sp or SamplingParams()
    greedy = sp.greedy
    return {
        "greedy": np.bool_(greedy),
        "temperature": np.float32(1.0 if greedy
                                  else max(sp.temperature, 1e-6)),
        "top_k": np.int32(0 if greedy else sp.top_k),
        "top_p": np.float32(1.0 if greedy else sp.top_p),
        "key": (_GREEDY_KEY if greedy
                else np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)),
    }


def sampling_set(samp, slot, row):
    """Scatter one slot's sampling params into the per-slot state. ``slot``
    and every ``row`` value may be traced — one trace covers every slot
    index and parameter setting."""
    out = {}
    for name, leaf in samp.items():
        val = jnp.asarray(row[name], leaf.dtype)
        out[name] = jax.lax.dynamic_update_slice(
            leaf, val[None] if leaf.ndim == 1 else val[None, :],
            (slot,) + (0,) * (leaf.ndim - 1))
    return out


def decode_tick(cfg, params, cache, tokens, samp=None, *,
                logits_sharding=None):
    """The engine's steady-state step: ``tokens`` (B,) is the device-resident
    last-token carry; (m)rope positions are built on device from the cache's
    ``pos`` leaf — no host round-trip. ``samp`` (optional) is the per-slot
    sampling state: greedy slots take argmax, stochastic slots draw from the
    processed distribution with noise keyed by (seed, absolute position) —
    masked composition, so ONE trace serves any mix. Returns
    (next_tokens (B,), new_cache). Jitted with the cache donated: the KV
    pytree updates in place.

    ``logits_sharding``: sharded engines pass a replicated NamedSharding —
    the lm-head output is vocab-sharded under tensor parallelism, and the
    sampler's softmax/cumsum over a sharded vocab axis would reorder float
    sums (argmax is comparator-exact, the distributions are not).
    Constraining here inserts ONE all-gather (pure concatenation, bitwise
    exact) so sharded streams stay bit-identical to the 1-chip engine."""
    batch = {"tokens": tokens[:, None]}
    if cfg.rope_variant == "mrope":
        b = tokens.shape[0]
        batch["positions"] = jnp.broadcast_to(
            cache["pos"][None, :, None], (3, b, 1))
    logits, new_cache = decode_step(cfg, params, cache, batch)
    last = logits[:, -1]
    if logits_sharding is not None:
        last = jax.lax.with_sharding_constraint(last, logits_sharding)
    if samp is None:
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
    else:
        # the token being drawn lands at absolute position new_pos - 1 +
        # 1 == the post-step pos: the same fold key the prefill paths use
        # for the first token (pos = prompt_len), advanced per tick
        nxt = sample_tokens(last, samp, new_cache["pos"])
    return nxt, new_cache


def decode_scan_step(cfg, params, cache, tokens, samp=None, *, n: int,
                     logits_sharding=None):
    """``n`` fused decode ticks as one jitted ``lax.scan``: one dispatch and
    one host sync per ``n`` tokens instead of per token. The engine uses
    this whenever nothing interrupts the window (no pending admissions, no
    prefill chunks, every active request has >= n tokens to go), falling
    back to single ticks at scheduling boundaries. ``samp`` is scan-
    invariant (slot membership is fixed across the window; per-tick noise
    comes from the advancing cache ``pos``), so stochastic slots survive
    multi-tick fusion with the SAME single trace. Returns
    (final_tokens (B,), token_history (n, B), new_cache)."""

    def body(carry, _):
        toks, c = carry
        nxt, c = decode_tick(cfg, params, c, toks, samp,
                             logits_sharding=logits_sharding)
        return (nxt, c), nxt

    (toks, cache), hist = jax.lax.scan(body, (tokens, cache), None, length=n)
    return toks, hist, cache


def _cache_batch_axis(big_shape, small_shape, batch: int):
    """Find the slot (batch) axis of a batched cache leaf: the axis where
    the batched leaf has ``batch`` entries and the B=1 leaf has one. Both
    conditions are required — stacked body leaves carry an ``n_repeat``
    leading axis that can collide with ``batch`` by value."""
    for ax, (n_big, n_small) in enumerate(zip(big_shape, small_shape)):
        if n_big == batch and n_small == 1:
            return ax
    raise ValueError(f"no batch axis {batch} in {big_shape} vs {small_shape}")


def cache_insert(batched_cache, single_cache, slot, batch: int):
    """Scatter a B=1 cache into slot ``slot`` of a batched cache. ``slot``
    may be a traced int32 scalar — one trace covers every slot index (the
    engine jits this with the batched cache donated, making admission a
    true in-place scatter instead of a full-cache copy)."""

    def ins(big, small):
        ax = _cache_batch_axis(big.shape, small.shape, batch)
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, ax)

    return jax.tree.map(ins, batched_cache, single_cache)


def _token_set(tokens, tok, slot):
    """Write a (1,) token into the (B,) device carry at ``slot`` (traced)."""
    return jax.lax.dynamic_update_slice_in_dim(tokens, tok.astype(tokens.dtype),
                                               slot, 0)


# ---------------------------------------------------------------------------
# engine helpers
# ---------------------------------------------------------------------------


def _attn_only(cfg) -> bool:
    """True when every block's decode cache is a KV buffer (no recurrent
    state) — the precondition for end-padded bucketing and chunked prefill."""
    pattern, _, tail = block_program(cfg)
    return all(bt in KV_CACHE_BLOCKS for bt in pattern + tail)


def _min_cache_window(cfg, window: int) -> int:
    """Smallest KV ring among the model's attention blocks: bucketed /
    chunked prefill must fit entirely inside it (a multi-query chunk that
    wraps the ring would expose chunk-future keys to earlier queries)."""
    pattern, _, tail = block_program(cfg)
    w = window
    for bt in pattern + tail:
        if bt == "local_attn":
            w = min(w, cfg.local_window)
    return w


def prompt_bucket(n: int, *, min_bucket: int = 16) -> int:
    """Power-of-two bucket for a prompt of ``n`` tokens."""
    return max(min_bucket, 1 << max(n - 1, 1).bit_length())


@dataclass
class _PrefillJob:
    """A request mid-way through chunked prefill (slot reserved, B=1 cache
    accumulating chunks)."""

    req: Request
    slot: int
    cache: dict
    tokens: jnp.ndarray  # (1, padded_len) device-resident prompt
    true_len: np.int32
    next_off: int = 0
    # first-token logits come from the chunk containing position
    # true_len-1, which is NOT always the last chunk (the padded buffer
    # is quantum-aligned; trailing chunks can be pure pad) — stash both
    # the greedy token and the logits (a sampled request draws its first
    # token from these at activation)
    tok: Optional[jnp.ndarray] = None
    logits: Optional[jnp.ndarray] = None


@dataclass
class _HitAdmission:
    """Host-side plan for a prefix-hit admission, staged between
    reservation and activation: which table positions alias shared pages
    (scatter to trash) and which receive the suffix prefill's data."""

    scatter_pages: np.ndarray  # (max_pages,) trash at aliased positions
    table_pages: np.ndarray  # (max_pages,) the slot's full table row
    n_tabled: int  # owned pages written into the row (incl. decode tail)


# ---------------------------------------------------------------------------
# preemption victim policies (pluggable: name -> chooser)
# ---------------------------------------------------------------------------


def _urgency(req: Request):
    """Total order on request urgency: higher priority beats any deadline,
    then earlier TTFT deadline wins. Smaller tuple = more urgent."""
    return (-req.priority, req.ttft_deadline)


def _victim_latest_deadline(engine, eligible: List[int]) -> int:
    """Latest-deadline-first: evict the slot whose request is least urgent
    (ties: most remaining budget — it has paid the least per page)."""
    return max(eligible,
               key=lambda i: (_urgency(engine.active[i]),
                              engine.active[i].remaining_tokens, i))


def _victim_most_remaining(engine, eligible: List[int]) -> int:
    """Most-remaining-first: evict the slot with the most budget left —
    it frees decode capacity the longest (ties: latest deadline)."""
    return max(eligible,
               key=lambda i: (engine.active[i].remaining_tokens,
                              _urgency(engine.active[i]), i))


PREEMPT_POLICIES = {
    "latest-deadline": _victim_latest_deadline,
    "most-remaining": _victim_most_remaining,
}


# ---------------------------------------------------------------------------
# continuous-batching executor
# ---------------------------------------------------------------------------


class ServingEngine:
    """Single-instance engine (SISD quadrant) with continuous batching.

    ``slots``: max concurrent decode streams (0/None -> derived from the
    cost model via ``plan_admission``). ``window``: KV window.
    ``sync_every``: decode ticks between device->host token syncs (forced
    to 1 when ``eos_id`` >= 0, since stopping needs token values).
    ``chunk_prefill``: chunk size for interleaved prefill (0 disables).
    ``bucket_prompts``: pad prefill to power-of-two buckets.
    ``donate``: donate the KV cache to the jit'd steps (in-place update).

    ``paged``: serve from a paged KV cache (None -> auto: on whenever every
    block is pageable; recurrent / local-attention archs fall back to
    rolling windows). ``page_size``: tokens per page (power of two).
    ``max_seq``: per-request token cap (page-table width; defaults to
    ``window`` for cost parity with the rolling cache — raise it to serve
    prompts longer than the old window cap). ``pool_pages``: total device
    pages shared by all slots (defaults to full headroom
    ``slots * max_seq / page_size + 1``, the +1 being the reserved trash
    page; pass less to oversubscribe — admission then backpressures when
    the pool runs dry).
    ``kv_hbm_budget``: optional KV-memory budget (bytes) handed to
    ``plan_admission`` when ``slots=0`` — the paged cache only needs the
    *expected* resident tokens per slot rather than a full window, so the
    same budget admits more concurrent slots.
    ``prefix_cache``: keep finished prompts' full KV pages in a radix
    ``PrefixIndex`` so later requests sharing a prefix alias those pages
    (refcounted) and prefill only their suffix — zero prefill compute for
    the cached span. Requires the paged cache. Off by default: cached
    pages outlive their requests, so ``pages_in_use`` no longer drains to
    zero between waves (use ``clear_prefix_cache()`` / ``reset()``).
    """

    def __init__(self, cfg, params,
                 config: Optional[EngineConfig] = None, **legacy):
        if legacy:
            # the one-PR from_legacy_kwargs shim (PR 7) is gone: keyword
            # construction fails loudly with the migration recipe
            raise TypeError(
                "ServingEngine(cfg, params, slots=..., ...) keyword "
                "construction was removed — build an EngineConfig and pass "
                "ServingEngine(cfg, params, EngineConfig(slots=..., ...)). "
                "Field names match the former keywords one-for-one except "
                "n_chips -> modeled_chips; serving-path precision (int8 "
                "KV pages / weights) is EngineConfig(precision="
                "PrecisionConfig(...)). Unknown keywords: "
                f"{sorted(legacy)}")
        if config is None:
            config = EngineConfig()
        config.validate(cfg)
        self.config = config
        self.topology = config.topology
        # locals mirror the former keywords: the executor body predates the
        # config object and reads these names throughout
        slots, window = config.slots, config.window
        eos_id, sync_every = config.eos_id, config.sync_every
        donate, bucket_prompts = config.donate, config.bucket_prompts
        chunk_prefill, sla_s = config.chunk_prefill, config.sla_s
        prefill_policy, paged = config.prefill_policy, config.paged
        page_size, pool_pages = config.page_size, config.pool_pages
        max_seq, kv_hbm_budget = config.max_seq, config.kv_hbm_budget
        expected_len, prefix_cache = config.expected_len, config.prefix_cache
        preemption = config.preemption
        preempt_policy = config.preempt_policy
        shed_overdue = config.shed_overdue
        n_chips = config.n_chips

        self.cfg = cfg
        self.n_chips = n_chips
        if config.precision.quantized_weights:
            # weight-only int8 at load time: attention/MLP matmul leaves
            # become {"w_q": int8, "scale": fp32} (layers.linear
            # dispatches); validate() already rejected sharded replicas
            # and non-quantizable block types
            params = quantize_weights(cfg, params)
        # --- sharded replica: mesh + bit-exact GSPMD profile ---
        # serving_policy shards only concat-dim weights (output dims, the
        # vocab axis, MoE expert axis) and the KV pools' kv-head axis;
        # GSPMD then all-gathers activations (pure concatenation) instead
        # of psum-reducing partial products, so every reduction keeps the
        # 1-chip operand order and streams stay bit-identical.
        self.mesh = None
        self._policy = None
        self._replicated = None
        self._logits_sharding = None
        if self.topology.sharded:
            from jax.sharding import NamedSharding, PartitionSpec

            self.mesh = make_serving_mesh(self.topology)
            self._policy = serving_policy(cfg, self.mesh)
            params = jax.device_put(
                params,
                to_shardings(self.mesh,
                             param_pspecs(cfg, params, self._policy)))
            self._replicated = NamedSharding(self.mesh, PartitionSpec())
            self._logits_sharding = self._replicated
        self.params = params
        # EDF ordering of the admission backlog (earliest TTFT deadline
        # first); FIFO stays the default so single-trace probes and every
        # pre-cluster caller see identical admission order.
        self.edf_backlog = config.edf_backlog
        if paged and not paged_ok(cfg):
            raise ValueError(
                f"{cfg.name}: arch has non-pageable blocks (recurrent or "
                f"local-attention); pass paged=None to auto-fall back to "
                f"rolling windows")
        self.paged = paged_ok(cfg) if paged is None else bool(paged)
        # quantized KV pages: validate(cfg) guaranteed the paged cache is
        # available whenever a kv_cache_dtype is set (paged=None resolves
        # to paged=True here because the arch is fully pageable)
        self.kv_dtype = config.precision.kv_cache_dtype
        assert page_size > 0 and page_size & (page_size - 1) == 0, page_size
        self.page_size = page_size
        self.max_seq = _padded_len(int(max_seq or window), page_size)
        self.max_pages = self.max_seq // page_size
        self.plan = plan_admission(
            cfg, context=window, sla_s=sla_s, n_chips=n_chips,
            kv_hbm_budget_bytes=kv_hbm_budget,
            mean_context=(expected_len or None) if self.paged else window,
            kv_cache_dtype=self.kv_dtype)
        if not slots:
            slots = self.plan.slots
        # --- MoE capacity policy (overflow as typed backpressure) ---
        self.moe_capacity_policy = (config.resolved_moe_policy(cfg)
                                    if cfg.arch_type == "moe" else "")
        self._moe_gmax = 0  # drop-free group bound (backpressure only)
        # every model-forward trace runs under self._trace_ctx; it carries
        # the scalar hints the model reads at trace time: the strict-MoE
        # full-capacity opt and/or the quantized cache's prefill scale
        # granularity ("page" granularity coarsens single-shot prefill
        # scale writes to one per page — see blocks.quantize_kv)
        hint_kw = {}
        if self.kv_dtype and config.precision.kv_scale_granularity == "page":
            hint_kw["kv_scale_page"] = page_size
        self._trace_ctx = (partial(sharding_hints, **hint_kw) if hint_kw
                           else contextlib.nullcontext)
        if self.moe_capacity_policy == "strict":
            # every serving trace runs under the full-capacity hint: the
            # (N, g, E, C) combine buffer covers the whole group, so no
            # routing pattern can drop a token (see models.moe._capacity)
            self._trace_ctx = partial(sharding_hints,
                                      opts=frozenset({"moe_full_cap"}),
                                      **hint_kw)
        elif self.moe_capacity_policy == "backpressure":
            self._moe_gmax = drop_free_group(cfg)
            # the decode group IS the slot count (garbage lanes route too):
            # clamping here makes every decode tick provably drop-free
            slots = min(slots, self._moe_gmax)
        self.slots = slots
        self.window = window
        # cost-model latency of one batched decode tick (load_report);
        # sharded replicas bill per-axis collective time on top
        self._mesh_axes = (self.topology.mesh_axes
                           if self.topology.sharded else None)
        self._tick_est_s = estimate_decode(
            cfg, slots, window, n_chips=n_chips,
            mesh_axes=self._mesh_axes).latency_s
        self._axis_collective_s = (
            collective_s_per_axis(cfg, slots, mesh_axes=self._mesh_axes)
            if self._mesh_axes else {})
        self.eos_id = eos_id
        self.sync_every = 1 if eos_id >= 0 else max(1, sync_every)
        self.metrics = ServeMetrics()
        # --- observability: span tracing + profiling hooks ---
        # Stamping discipline: host timestamps only, and only at existing
        # sync points (the caller-supplied ``now`` the engine already has
        # in hand) — tracing never adds a device sync. With tracing off a
        # request's ``trace`` stays None and every stamp site is a single
        # attribute check.
        self._trace_on = bool(config.tracing)
        # head-sampling: trace rids where rid % trace_sample_n == 0 (1 =
        # everything); the rollups then cover the sampled subset only
        self._trace_every = max(1, config.trace_sample_n)
        self.tracer = Tracer(enabled=self._trace_on, ring=config.trace_ring)
        self._win_t0 = 0.0  # serving-clock start of the open decode window
        self._last_now = 0.0  # most recent caller clock (compile events)
        # jit traces per trace-cache key proxy (shape-derived): the "flat
        # compile count" invariants as a queryable metric
        self.compile_events: Dict[str, int] = {}
        self._tick_wall = latency_histogram()  # step() wall s (tracing only)
        self._profiling = False

        self._attn_only = _attn_only(cfg)
        self._min_window = _min_cache_window(cfg, window)
        self.bucket_prompts = bucket_prompts and self._attn_only
        if prefill_policy is not None:  # the policy's chunk size wins
            chunk_prefill = prefill_policy.chunk
        self.chunk = chunk_prefill if (chunk_prefill and self._attn_only) else 0
        self.prefill_policy = prefill_policy or ChunkedPrefillPolicy(
            chunk=self.chunk or 64)
        # chunked-prefill buffers must be both chunk- and page-aligned
        self._chunk_quantum = (math.lcm(self.chunk, page_size)
                               if self.chunk else page_size)

        # --- device state (exclusively owned: donation-safe) ---
        if prefix_cache and not (paged_ok(cfg) if paged is None else paged):
            raise ValueError(
                f"{cfg.name}: prefix_cache requires the paged KV cache "
                f"(rolling windows cannot alias another slot's KV)")
        # --- fault tolerance / lifecycle knobs ---
        if preemption and not self.paged:
            raise ValueError(
                f"{cfg.name}: preemption requires the paged KV cache (a "
                f"victim's pages must be releasable mid-stream)")
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"unknown preempt_policy {preempt_policy!r} "
                             f"(want one of {sorted(PREEMPT_POLICIES)})")
        self.preemption = preemption
        self.preempt_policy = preempt_policy
        self._preempt_victim_fn = PREEMPT_POLICIES[preempt_policy]
        # shed queued requests whose TTFT deadline already passed (graceful
        # degradation under overload: stop burning prefill/decode budget on
        # requests that can no longer meet their SLO). Off by default —
        # SLO-miss accounting tests rely on late requests still finishing.
        self.shed_overdue = shed_overdue
        if self.paged:
            self.pool_pages = pool_pages or slots * self.max_pages + 1
            self.allocator = PageAllocator(self.pool_pages, page_size)
            self.prefix_index = (PrefixIndex(self.allocator, page_size)
                                 if prefix_cache else None)
            self.cache = init_paged_cache(cfg, slots, self.pool_pages,
                                          page_size, self.max_pages,
                                          kv_dtype=self.kv_dtype)
            self._pos_h: List[int] = [0] * slots  # host mirror of cache pos
            # pages of the slot's reservation already written into its
            # device page-table row (the decode tail is appended lazily)
            self._tabled: List[int] = [0] * slots
        else:
            self.prefix_index = None
            self.cache = init_cache(cfg, slots, window)
        if self.mesh is not None:
            # KV pools shard over the kv-head axis; the page table, pos,
            # and recurrent/conv leaves replicate — host-side layouts
            # (PageAllocator / PrefixIndex / preemption snapshots) stay
            # identical to the 1-chip engine
            pfn = paged_cache_pspecs if self.paged else cache_pspecs
            self.cache = jax.device_put(
                self.cache,
                to_shardings(self.mesh,
                             pfn(cfg, self.cache, self._policy, self.mesh)))
        # staged prefix-hit admission plans, keyed by slot (consumed at
        # activation; see _HitAdmission)
        self._hit_pending: Dict[int, _HitAdmission] = {}
        self._tokens = jnp.zeros((slots,), jnp.int32)
        if self.mesh is not None:
            self._tokens = jax.device_put(self._tokens, self._replicated)
        # per-slot sampling state rides next to the token carry: scattered
        # at activation, reset to greedy on release (so a vacated slot's
        # garbage lane never re-enters the stochastic branch); the host
        # mirror of the greedy flags makes release a no-op for greedy slots
        self._samp = init_sampling_state(slots)
        if self.mesh is not None:
            self._samp = jax.device_put(self._samp, self._replicated)
        self._samp_greedy_h: List[bool] = [True] * slots
        self.active: List[Optional[Request]] = [None] * slots
        self.decoding: List[bool] = [False] * slots
        self._unsynced: List[jnp.ndarray] = []  # per-tick (B,) token arrays
        self._finished: List[Request] = []
        self._jobs: Deque[_PrefillJob] = deque()

        # --- admission queue (deadline from the cost model) ---
        self.backlog: Deque[Request] = deque()
        self.admission = BatchAccumulator(
            target_batch=slots, deadline_s=self.plan.flush_deadline_s)

        # --- jit'd steps with compile-count probes ---
        self.prefill_traces = 0
        self.decode_traces = 0
        donate_cache = (1,) if donate else ()

        # every model-forward trace runs under self._trace_ctx (the MoE
        # "strict" capacity hint; a no-op otherwise) — the hint is read at
        # TRACE time, and these closures are per-engine, so the contextvar
        # scope is safe
        def _probed_decode(params, cache, tokens, samp):
            self.decode_traces += 1
            self._note_compile("decode/tick")
            with self._trace_ctx():
                return decode_tick(cfg, params, cache, tokens, samp,
                                   logits_sharding=self._logits_sharding)

        def _probed_scan(params, cache, tokens, samp):
            self.decode_traces += 1
            self._note_compile(f"decode/scan{self.sync_every}")
            with self._trace_ctx():
                return decode_scan_step(
                    cfg, params, cache, tokens, samp, n=self.sync_every,
                    logits_sharding=self._logits_sharding)

        def _probed_bucketed(params, batch, true_len):
            self.prefill_traces += 1
            self._note_compile(f"prefill/bucket{_batch_len(batch)}")
            with self._trace_ctx():
                return bucketed_prefill_step(cfg, params, batch, true_len,
                                             window=window)

        def _probed_exact(params, batch):
            self.prefill_traces += 1
            self._note_compile(f"prefill/exact{_batch_len(batch)}")
            with self._trace_ctx():
                return prefill_step(cfg, params, batch, window=window)

        def _probed_paged_prefill(params, batch, true_len):
            self.prefill_traces += 1
            self._note_compile(f"prefill/paged{_batch_len(batch)}")
            with self._trace_ctx():
                return paged_prefill_step(cfg, params, batch, true_len,
                                          kv_dtype=self.kv_dtype)

        def _probed_suffix(params, cache, tokens, true_len):
            # suffix-offset prefill over a seeded linear cache: retraces
            # once per SUFFIX bucket width (cache width is always
            # max_seq), never per hit length — start/true_len are traced
            self.prefill_traces += 1
            self._note_compile(f"prefill/suffix{tokens.shape[1]}")
            with self._trace_ctx():
                return prefill_chunk_step(cfg, params, cache, tokens,
                                          true_len)

        def _chunk_step(params, cache, tokens, true_len):
            self._note_compile(f"prefill/chunk{tokens.shape[1]}")
            with self._trace_ctx():
                return prefill_chunk_step(cfg, params, cache, tokens,
                                          true_len)

        def _first_token(logits, samp1, pos):
            # prefill logits are vocab-sharded under TP; replicate before
            # the stochastic draw (see decode_tick's logits_sharding)
            if self._logits_sharding is not None:
                logits = jax.lax.with_sharding_constraint(
                    logits, self._logits_sharding)
            with self._trace_ctx():
                return sample_tokens(logits, samp1, pos)

        donate0 = (0,) if donate else ()
        self._decode = jax.jit(_probed_decode, donate_argnums=donate_cache)
        self._decode_scan = jax.jit(_probed_scan, donate_argnums=donate_cache)
        self._prefill_bucketed = jax.jit(_probed_bucketed)
        self._prefill_exact = jax.jit(_probed_exact)
        self._prefill_paged = jax.jit(_probed_paged_prefill)
        self._prefill_chunk = jax.jit(
            _chunk_step, donate_argnums=(1,) if donate else ())
        self._insert = jax.jit(
            partial(cache_insert, batch=slots),
            donate_argnums=donate0)
        self._pages_insert = jax.jit(pages_insert, donate_argnums=donate0)
        # prefix-hit path: the seed reads the pools (never donated); the
        # suffix step consumes the seeded linear cache; the scatter
        # donates the pools like every other admission write
        self._prefix_seed = jax.jit(prefix_seed_cache)
        self._prefill_suffix = jax.jit(
            _probed_suffix, donate_argnums=(1,) if donate else ())
        self._pages_insert_prefix = jax.jit(pages_insert_prefix,
                                            donate_argnums=donate0)
        self._table_append = jax.jit(page_table_append, donate_argnums=donate0)
        self._release = jax.jit(slot_release, donate_argnums=donate0)
        self._set_token = jax.jit(_token_set)
        # sampling: one scatter trace for every (slot, params) setting; one
        # B=1 sampler trace for every sampled request's FIRST token (the
        # decode ticks sample in-trace — see decode_tick)
        self._samp_set = jax.jit(sampling_set, donate_argnums=donate0)
        self._sample_first = jax.jit(_first_token)

    # -- observability helpers ---------------------------------------------
    def _note_compile(self, key: str):
        """Count one jit trace against its trace-cache key proxy. Runs at
        TRACE time only (inside the probed closures), so warm calls cost
        nothing; the key is shape-derived, so growth in any one key is a
        trace-cache regression."""
        self.compile_events[key] = self.compile_events.get(key, 0) + 1
        if self._trace_on:
            self.tracer.event("compile", self._last_now, key=key)

    def _tr(self, req: Request) -> Optional[Trace]:
        """The trace to stamp for ``req``: its existing one (a tracing
        frontend may have created it), a fresh one when engine tracing is
        on and the rid falls in the sample (``rid % trace_sample_n == 0``),
        or None (tracing off / rid sampled out — no stamping)."""
        t = req.trace
        if (t is None and self._trace_on
                and req.rid % self._trace_every == 0):
            t = req.trace = Trace(req.rid)
        return t

    def _tr_admit(self, req: Request, now: float, path: str, slot: int):
        """Close the queued span and open the prefill span at admission."""
        t = self._tr(req)
        if t is None:
            return
        if t.is_open("queued"):
            t.end("queued", now)
        t.begin("prefill", now, path=path, slot=slot)

    def _tr_terminal(self, req: Request, now: float, kind: str, **meta):
        """Stamp a terminal event (rejected/abort) and fold the trace into
        the engine rollup."""
        t = req.trace
        if t is None:
            return
        t.close_all(now)
        t.event(kind, now, **meta)
        self.tracer.collect(t)

    def start_profile(self) -> bool:
        """Arm ``jax.profiler`` tracing into ``config.profile_dir``; no-op
        (False) when no directory is configured or already profiling."""
        if not self.config.profile_dir or self._profiling:
            return False
        jax.profiler.start_trace(self.config.profile_dir)
        self._profiling = True
        if self._trace_on:
            self.tracer.event("profile_start", self._last_now,
                              dir=self.config.profile_dir)
        return True

    def stop_profile(self) -> bool:
        if not self._profiling:
            return False
        jax.profiler.stop_trace()
        self._profiling = False
        if self._trace_on:
            self.tracer.event("profile_stop", self._last_now)
        return True

    def metrics_registry(self) -> MetricsRegistry:
        """This engine's metrics as a registry (exposition-ready):
        ServeMetrics counters/histograms plus engine-level accounting —
        per-key compile events, per-kind span totals, per-step wall time."""
        reg = self.metrics.registry()
        reg.set_counter("serving_prefill_traces_total", self.prefill_traces)
        reg.set_counter("serving_decode_traces_total", self.decode_traces)
        for key, n in sorted(self.compile_events.items()):
            reg.set_counter(
                f"serving_compile_events_total{{key=\"{key}\"}}", n)
        for kind, (c, s) in sorted(self.tracer.span_totals.items()):
            reg.set_counter(f"serving_span_count_total{{kind=\"{kind}\"}}", c)
            reg.set_gauge(f"serving_span_seconds{{kind=\"{kind}\"}}", s)
        if self._tick_wall.count:
            reg.register("serving_step_wall_seconds", self._tick_wall)
        return reg

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request, now: float) -> bool:
        """Admit immediately while free capacity exists (holding a request
        back from an idle slot buys nothing); once saturated, queue and
        batch admissions up to the cost-model deadline (``plan_admission``)
        so freed slots refill in groups. Unservable requests (prompt beyond
        max_seq) are rejected HERE, before queueing — a poison request must
        never reach the backlog, where its admission failure would abort
        every subsequent tick. Rejection is a typed OUTCOME, not an
        exception: the request comes back FAILED (with ``fail_reason``)
        from the next ``step``, and ``False`` is returned so a frontend
        never tracks it as in-flight."""
        self._last_now = now
        t = self._tr(req)
        if t is not None and not t.is_open("queued"):
            t.begin("queued", now)
        try:
            self._check_servable(req)
        except RequestRejected as e:
            self._reject(req, now, str(e))
            return False
        if (not self.backlog and not self.admission.pending
                and self.try_admit(req, now)):
            return True
        flushed = self.admission.add(req, now)
        if flushed:
            self.backlog.extend(flushed)
            self._drain_backlog(now)
        return True

    def _reject(self, req: Request, now: float, reason: str):
        """Turn an unservable submission into a terminal FAILED outcome
        (surfaced by the next ``step`` like any finished request)."""
        req.state = RequestState.FAILED
        req.fail_reason = reason
        req.finish_time = now
        self.metrics.rejected += 1
        if req.tenant:
            self.metrics.tenant(req.tenant).rejected += 1
        self._tr_terminal(req, now, "rejected", reason=reason[:120])
        self._finished.append(req)

    def _pump_admissions(self, now: float):
        flushed = self.admission.poll(now)
        if flushed:
            self.backlog.extend(flushed)
        self._drain_backlog(now)

    def _drain_backlog(self, now: float):
        while self.backlog:
            idx = 0
            if self.edf_backlog:
                # earliest TTFT deadline first; FIFO among equal deadlines
                # (untracked requests have an infinite deadline and drain
                # after every SLO-tracked one)
                idx = min(range(len(self.backlog)),
                          key=lambda k: (self.backlog[k].ttft_deadline, k))
            if not self._admit_or_preempt(self.backlog[idx], now):
                break
            del self.backlog[idx]

    def _admit_or_preempt(self, req: Request, now: float) -> bool:
        """Admit ``req``; when admission backpressures (no slot / no pages)
        and preemption is on, evict strictly-less-urgent victims (policy-
        chosen) until it fits or no eligible victim remains. Victims
        requeue at the back of the backlog; strictness of the urgency
        comparison bounds preemption chains and prevents two requests
        from evicting each other forever."""
        if self.try_admit(req, now):
            return True
        if not self.preemption:
            return False
        while True:
            slot = self._choose_victim(req)
            if slot is None:
                return False
            victim = self.preempt(slot, now)
            if victim is not None:
                self.backlog.append(victim)
            if self.try_admit(req, now):
                return True

    def _choose_victim(self, cand: Request) -> Optional[int]:
        """Slot to evict so ``cand`` can run: decoding slots whose request
        is STRICTLY less urgent are eligible; the configured policy picks
        among them (default latest-deadline-first). None = don't preempt."""
        eligible = [i for i, (r, d) in enumerate(zip(self.active,
                                                     self.decoding))
                    if r is not None and d and _urgency(cand) < _urgency(r)]
        if not eligible:
            return None
        return self._preempt_victim_fn(self, eligible)

    def preempt(self, slot: int, now: float) -> Optional[Request]:
        """Evict the decoding request in ``slot`` mid-stream and return it
        for requeueing (state PREEMPTED). Deferred tokens are flushed
        first, so the victim's ``output`` is complete up to its cache
        position; the generated tokens fold into its prompt
        (``fold_output_into_prompt``) and — when the prefix cache is on —
        every full page of now-valid KV is registered in the
        ``PrefixIndex`` BEFORE the slot's references drop, so re-admission
        restores the stream with suffix-only prefill (recompute-free).
        Seeded sampling keys noise by absolute position, so the restored
        stream is bit-identical to an unpreempted run. Returns None when
        the flush finished the request (nothing to evict)."""
        assert self.paged, "preemption requires the paged KV cache"
        self._flush(now)
        req = self.active[slot]
        if req is None or not self.decoding[slot]:
            return None
        req.fold_output_into_prompt()
        if self.prefix_index is not None:
            # KV is valid through position pos-1 (= prompt_len-2 after the
            # fold: the newest token lives only in the device carry), so
            # only pages fully inside that span are indexable
            ps = self.page_size
            owned = self.allocator.owned(slot)
            n = min(self._pos_h[slot] // ps, len(owned))
            if n > 0:
                self.prefix_index.register(req.prompt[:n * ps], owned[:n])
        self.release_slot(slot)
        req.state = RequestState.PREEMPTED
        req.preemptions += 1
        self.metrics.preempted += 1
        t = req.trace
        if t is not None:
            if t.is_open("decode"):
                t.end("decode", now, tokens=len(req.output))
            t.event("preempt", now, slot=slot, policy=self.preempt_policy)
            t.begin("queued", now)  # the victim requeues for restore
        return req

    def try_admit(self, req: Request, now: float) -> bool:
        """Claim a free slot for ``req``. Long prompts (when chunking is on
        and the prompt fits the prefill buffer) enter chunked prefill: the
        slot is reserved and the prompt is processed ``chunk`` tokens per
        tick, interleaved with decode. Short prompts prefill immediately
        (bucketed when possible). In paged mode the request's worst-case
        pages (padded prompt + token budget) are reserved up front; an
        exhausted pool rejects the admission (backpressure — the request
        stays queued until pages free up)."""
        self._check_servable(req)
        for i, slot in enumerate(self.active):
            if slot is None and not any(j.slot == i for j in self._jobs):
                hit = None
                if self.prefix_index is not None:
                    hit = self.prefix_index.lookup(req.prompt)
                if self.paged and not self._reserve_pages(req, i, hit):
                    return False  # out of pages: backpressure
                if hit is not None:
                    self._admit_prefix(req, i, hit, now)
                elif self._chunkable(req):
                    self._start_chunked(req, i, now)
                else:
                    self._admit_now(req, i, now)
                return True
        return False

    def _check_servable(self, req: Request):
        if self.paged and req.prompt_len > self.max_seq:
            raise RequestRejected(
                f"prompt of {req.prompt_len} tokens exceeds max_seq="
                f"{self.max_seq}; raise ServingEngine(max_seq=...)")
        if self._moe_gmax and self._moe_prefill_group(req) > self._moe_gmax:
            raise RequestRejected(
                f"prefill group of {self._moe_prefill_group(req)} tokens "
                f"exceeds the drop-free MoE bound {self._moe_gmax} "
                f"(capacity_factor={self.cfg.moe_capacity_factor}): routing "
                f"could silently drop tokens; raise moe_capacity_factor, "
                f"use moe_capacity_policy='strict', or shorten the prompt")

    def _moe_prefill_group(self, req: Request) -> int:
        """Upper bound on the MoE routing group a prefill of ``req`` can
        see: chunked prefill routes one chunk at a time, single-shot
        prefill routes the padded prompt (``apply_moe`` caps groups at
        2048 and only ever SHRINKS to divide the token count)."""
        g = self.chunk if self._chunkable(req) else self._prefill_len(req)
        return min(2048, g)

    def _chunkable(self, req: Request) -> bool:
        cap = self.max_seq if self.paged else self._min_window
        quantum = self._chunk_quantum if self.paged else self.chunk
        return (self.chunk > 0
                and req.prompt_len > self.chunk
                and _padded_len(req.prompt_len, quantum) <= cap)

    def _bucket_for(self, plen: int) -> Optional[int]:
        if not self.bucket_prompts:
            return None
        if self.paged:
            b = prompt_bucket(plen, min_bucket=max(16, self.page_size))
            return b if b <= self.max_seq else None
        b = prompt_bucket(plen)
        return b if b <= self._min_window else None

    def _prefill_len(self, req: Request) -> int:
        """Token capacity the prefill path will occupy for ``req`` (the
        padded prompt length — every variant page-aligned in paged mode)."""
        plen = req.prompt_len
        if self._chunkable(req):
            quantum = self._chunk_quantum if self.paged else self.chunk
            return _padded_len(plen, quantum)
        bucket = self._bucket_for(plen)
        if bucket is not None:
            return bucket
        return _padded_len(plen, self.page_size) if self.paged else plen

    def _suffix_chunked(self, req: Request, hit: PrefixHit) -> bool:
        """Whether the hit's suffix goes through interleaved chunk steps
        (long suffix) instead of one synchronous bucketed suffix step."""
        return (self.chunk > 0
                and req.prompt_len - hit.tokens > self.chunk
                and _padded_len(req.prompt_len, self._chunk_quantum)
                <= self.max_seq)

    def _suffix_plan(self, req: Request, hit: PrefixHit):
        """(start, end) of the suffix-offset prefill in the linear buffer:
        tokens [start, end) are (re)computed — start <= hit.tokens keeps
        the span aligned to the chunk grid / bucket width so hit lengths
        share traces; end never exceeds max_seq (the linear buffer must
        not wrap)."""
        plen, h = req.prompt_len, hit.tokens
        if self._suffix_chunked(req, hit):
            s = (h // self.chunk) * self.chunk
            return s, _padded_len(plen, self._chunk_quantum)
        c = min(prompt_bucket(plen - h, min_bucket=max(16, self.page_size)),
                self.max_seq)
        s = min(h, self.max_seq - c)
        return s, s + c

    def _alloc_evicting(self, slot: int, n: int) -> bool:
        """All-or-nothing grant, evicting idle cached prefixes (LRU) to
        cover a shortfall before refusing."""
        if (not self.allocator.can_alloc(n)
                and self.prefix_index is not None):
            self.prefix_index.evict(n - self.allocator.free_pages)
        return self.allocator.alloc(slot, n) is not None

    def _reserve_pages(self, req: Request, slot: int,
                       hit: Optional[PrefixHit] = None) -> bool:
        """Grant ``req``'s worst-case lifetime pages to ``slot`` before any
        prefill compute runs: the padded prompt plus its full token budget
        (capped at max_seq). All-or-nothing — reserving the decode tail up
        front means pool shortage always surfaces HERE as admission
        backpressure, never as mid-stream exhaustion (requests that stop
        early at eos return the tail unused).

        With a prefix ``hit``, the matched full pages are SHARED into the
        slot (refcount+1, no pool spend) and only the remainder — the COW
        tail replacement, suffix pages, decode tail — is allocated. Under
        pool pressure, idle cached prefixes are evicted (oldest first)
        before the admission is refused."""
        if self.allocator.owned(slot):
            # Lifecycle bypassed (e.g. a slot vacated without release):
            # reclaim on device first so the stale table row can never
            # alias pages about to be re-granted.
            self.cache = self._release(self.cache, np.int32(slot))
            self.allocator.free_slot(slot)
            self._pos_h[slot] = 0
            self._tabled[slot] = 0
            self._hit_pending.pop(slot, None)
        # restore-aware lifetime: a preempted request's folded tokens are
        # already inside prompt_len AND inside max_new_tokens (its output
        # keeps them), so only the REMAINING budget extends the stream
        lifetime = min(req.prompt_len + max(1, req.remaining_tokens) - 1,
                       self.max_seq)
        if hit is None:
            n = self.allocator.pages_for(max(self._prefill_len(req), lifetime))
            return self._alloc_evicting(slot, n)
        # Share first: a shared page is no longer evictable, so the
        # eviction pass below can never reclaim the chain we are using.
        shared = self.allocator.share(slot, list(hit.full_pages))
        if hit.tail_page >= 0:
            self.allocator.retain(hit.tail_page)  # pin the COW source
        _, end = self._suffix_plan(req, hit)
        n_priv = self.allocator.pages_for(max(end, lifetime)) - len(shared)
        if not self._alloc_evicting(slot, n_priv):
            if hit.tail_page >= 0:
                self.allocator.release(hit.tail_page)
            self.allocator.free_slot(slot)  # drop the shares (rollback)
            return False
        return True

    def _admit_now(self, req: Request, slot: int, now: float):
        self._tr_admit(req, now, "full", slot)
        plen = req.prompt_len
        bucket = None if self.paged else self._bucket_for(plen)
        if self.paged:
            # page-aligned linear prefill (bucketed, or page-rounded exact)
            padded_len = self._prefill_len(req)
            padded = np.zeros((1, padded_len), np.int32)
            padded[0, :plen] = req.prompt
            batch = {"tokens": jnp.asarray(padded)}
            if self.cfg.rope_variant == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(padded_len, dtype=jnp.int32), (3, 1, padded_len))
            tok, last, cache1 = self._prefill_paged(
                self.params, batch, np.int32(plen))
        elif bucket is not None:
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :plen] = req.prompt
            batch = {"tokens": jnp.asarray(padded)}
            if self.cfg.rope_variant == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(bucket, dtype=jnp.int32), (3, 1, bucket))
            tok, last, cache1 = self._prefill_bucketed(
                self.params, batch, np.int32(plen))
        else:
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            if self.cfg.rope_variant == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(plen, dtype=jnp.int32), (3, 1, plen))
            last, cache1 = self._prefill_exact(self.params, batch)
            tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        self._activate(req, slot, tok, last, cache1, now)

    def _admit_prefix(self, req: Request, slot: int, hit: PrefixHit,
                      now: float):
        """Admit a request whose prefix is cached: alias the matched full
        pages into the slot's table row (zero prefill compute for the
        hit), gather the chain into a seeded linear buffer, and prefill
        ONLY the suffix from a nonzero offset — synchronously in one
        bucketed multi-token step, or through the interleaved chunk path
        when the suffix is long. A partially-matched tail page is never
        aliased: its matched tokens ride the gathered buffer and scatter
        into a private page at activation (copy-on-write)."""
        self._tr_admit(req, now, "prefix", slot)
        if req.trace is not None:
            req.trace.spans[-1].meta["prefix_hit"] = hit.tokens
        plen, ps = req.prompt_len, self.page_size
        n_full = len(hit.full_pages)
        owned = self.allocator.owned(slot)  # [shared full..., private...]
        start, end = self._suffix_plan(req, hit)
        # gather chain: full pages + COW tail source, trash-padded to the
        # fixed max_pages width (one seed trace for every hit length)
        chain = list(hit.full_pages)
        if hit.tail_page >= 0:
            chain.append(hit.tail_page)
        gpages = np.zeros((self.max_pages,), np.int32)
        gpages[:len(chain)] = chain
        # table row: aliased fulls, then privates (COW tail replacement,
        # suffix, decode tail); scatter row: privates only — a shared
        # page is never written
        trow = np.zeros((self.max_pages,), np.int32)
        trow[:len(owned)] = owned
        srow = np.zeros((self.max_pages,), np.int32)
        srow[n_full:len(owned)] = owned[n_full:]
        self._hit_pending[slot] = _HitAdmission(srow, trow, len(owned))
        req.prefix_hit_tokens = hit.tokens
        self.metrics.prefix_hits += 1
        self.metrics.prefix_hit_tokens += hit.tokens
        cache1 = self._prefix_seed(self.cache, jnp.asarray(gpages),
                                   np.int32(start))
        if hit.tail_page >= 0:
            self.allocator.release(hit.tail_page)  # gather done: unpin
        padded = np.zeros((1, end), np.int32)
        padded[0, :plen] = req.prompt
        if self._suffix_chunked(req, hit):
            self._jobs.append(_PrefillJob(
                req=req, slot=slot, cache=cache1,
                tokens=jnp.asarray(padded), true_len=np.int32(plen),
                next_off=start))
            req.state = RequestState.PREFILL
            self.active[slot] = req  # reserve (decoding stays False)
            return
        toks = jnp.asarray(padded[:, start:end])
        tok, last, cache1 = self._prefill_suffix(self.params, cache1, toks,
                                                 np.int32(plen))
        self._activate(req, slot, tok, last, cache1, now)

    def _put_linear(self, cache1):
        """Commit a host-built B=1 linear cache to the replica mesh (KV
        sharded over kv heads, like every other cache); identity on 1-chip
        engines. Keeps chunked-prefill working buffers from pinning a
        replicated copy on every device."""
        if self.mesh is None:
            return cache1
        return jax.device_put(
            cache1,
            to_shardings(self.mesh, cache_pspecs(self.cfg, cache1,
                                                 self._policy, self.mesh)))

    def _start_chunked(self, req: Request, slot: int, now: float):
        self._tr_admit(req, now, "chunked", slot)
        padded_len = self._prefill_len(req)
        padded = np.zeros((1, padded_len), np.int32)
        padded[0, :req.prompt_len] = req.prompt
        # paged: a LINEAR buffer at the shared max_seq width (every chunked
        # job then hits one compiled chunk step; pages_insert slices the
        # prompt's pages out at activation); rolling: the window-size ring.
        buf = self.max_seq if self.paged else self.window
        self._jobs.append(_PrefillJob(
            req=req, slot=slot,
            cache=self._put_linear(init_cache(self.cfg, 1, buf,
                                              self.kv_dtype)),
            tokens=jnp.asarray(padded),
            true_len=np.int32(req.prompt_len)))
        req.state = RequestState.PREFILL
        self.active[slot] = req  # reserve (decoding stays False)

    def _run_prefill_chunks(self, now: float):
        if not self._jobs:
            return
        pending = sum(
            (j.tokens.shape[1] - j.next_off) // self.chunk for j in self._jobs)
        n = self.prefill_policy.chunks_this_tick(
            self.cfg, n_decoding=self.n_decoding, pending_chunks=pending,
            context=self.window)
        for _ in range(n):
            if not self._jobs:
                break
            job = self._jobs[0]
            chunk_toks = jax.lax.slice_in_dim(
                job.tokens, job.next_off, job.next_off + self.chunk, axis=1)
            tok, last, job.cache = self._prefill_chunk(
                self.params, job.cache, chunk_toks, job.true_len)
            prev_off = job.next_off
            job.next_off += self.chunk
            if prev_off <= int(job.true_len) - 1 < job.next_off:
                # first-token logits live in the chunk holding position
                # true_len-1; later chunks (pure quantum padding) return
                # a clamped garbage index — keep the real one
                job.tok = tok
                job.logits = last
            self.metrics.prefill_chunks += 1
            if job.req.trace is not None:
                job.req.trace.event("prefill_chunk", now, offset=prev_off,
                                    slot=job.slot)
            if job.next_off >= job.tokens.shape[1]:
                self._jobs.popleft()
                self._activate(job.req, job.slot,
                               tok if job.tok is None else job.tok,
                               last if job.logits is None else job.logits,
                               job.cache, now)

    def _activate(self, req: Request, slot: int, tok, last, cache1,
                  now: float):
        """Install a prefilled request into its slot: scatter the B=1 cache
        (donated, in-place), set the device token carry, record the first
        token. Forces a token flush first so the deferred-sync window only
        ever spans a fixed slot membership. Paged mode scatters into the
        slot's reserved pool pages and writes its page-table row instead of
        copying into a per-slot window.

        ``last`` is the prompt's last-true-position logits (1, V): a
        stochastic request draws its first token from them here, with the
        same (seed, position=prompt_len) noise key every admission path —
        full, bucketed, chunked, or prefix-hit suffix — would produce, so
        a prompt's stream is independent of HOW it was prefilled. The
        slot's sampling state is scattered before the first decode tick
        can read it."""
        self._flush(now)
        sp = req.sampling or SamplingParams()
        if not (sp.greedy and self._samp_greedy_h[slot]):
            # greedy request on an already-greedy lane: no row to build,
            # no scatter — the default path stays key-init-free
            row = sampling_row(sp)
            self._samp = self._samp_set(self._samp, np.int32(slot), row)
        self._samp_greedy_h[slot] = sp.greedy
        if not sp.greedy:
            self.metrics.sampled_requests += 1
            samp1 = {k: jnp.asarray(v)[None] for k, v in row.items()}
            tok = self._sample_first(last, samp1,
                                     np.full((1,), req.prompt_len, np.int32))
        if self.paged:
            info = self._hit_pending.pop(slot, None)
            if info is not None:
                # prefix hit: the fixed-width scatter writes the suffix
                # into private pages (trash at aliased positions) and the
                # FULL table row — shared fulls, COW tail, decode tail —
                # in one go (one trace for every hit shape)
                self.cache = self._pages_insert_prefix(
                    self.cache, cache1, jnp.asarray(info.scatter_pages),
                    jnp.asarray(info.table_pages), np.int32(slot),
                    np.int32(req.prompt_len))
                self._pos_h[slot] = req.prompt_len
                self._tabled[slot] = info.n_tabled
            else:
                # scatter the prompt into the reservation's LEADING pages;
                # the decode-tail pages (also reserved) enter the table
                # row lazily as the stream grows, so pages_insert keeps
                # one trace per bucket regardless of each token budget
                n_pref = self.allocator.pages_for(self._prefill_len(req))
                pages = jnp.asarray(self.allocator.owned(slot)[:n_pref],
                                    jnp.int32)
                self.cache = self._pages_insert(
                    self.cache, cache1, pages, np.int32(slot),
                    np.int32(req.prompt_len))
                self._pos_h[slot] = req.prompt_len
                self._tabled[slot] = n_pref
            if self.prefix_index is not None:
                # register the finished prompt's FULL pages (only spans
                # entirely inside the prompt: an indexed page is never
                # appended to again — the COW invariant)
                n_full = req.prompt_len // self.page_size
                owned = self.allocator.owned(slot)
                if n_full:
                    self.prefix_index.register(req.prompt, owned[:n_full])
            # the page table caps a request's lifetime tokens at max_seq;
            # surface the truncation on the request instead of failing.
            # Restore-aware: a preempted request's folded tokens already
            # count against both prompt_len and output, so only the
            # REMAINING budget is compared against the cap.
            already = len(req.output)
            cap = max(1, self.max_seq - req.prompt_len)
            if req.max_new_tokens - already > cap:
                req.max_new_tokens = already + cap
                req.budget_capped = True
        else:
            self.cache = self._insert(self.cache, cache1, np.int32(slot))
        self._tokens = self._set_token(self._tokens, tok, np.int32(slot))
        req.output.append(int(tok[0]))
        if req.prefill_done < 0:
            req.prefill_done = now
            self.metrics.ttfts.append(req.ttft)
            # brownout is counted where the request SERVES (here), not at
            # the frontend that trimmed it — merged cluster metrics must
            # not double-count a request that crossed both layers
            if req.browned_out_tokens:
                self.metrics.browned_out += 1
            if req.tenant:
                tm = self.metrics.tenant(req.tenant)
                tm.admitted += 1
                tm.ttfts.append(req.ttft)
                if req.browned_out_tokens:
                    tm.browned_out += 1
                    tm.brownout_trimmed_tokens += req.browned_out_tokens
        if req.state is RequestState.PREEMPTED:
            self.metrics.preempt_restores += 1
        t = req.trace
        if t is not None:
            if t.is_open("queued"):  # direct try_admit paths skip submit
                t.end("queued", now)
            if t.is_open("prefill"):
                t.end("prefill", now, tokens=req.prompt_len)
            if not sp.greedy:
                t.event("sample", now, seed=sp.seed)
            if req.state is RequestState.PREEMPTED:
                t.event("restore", now, slot=slot,
                        preemptions=req.preemptions)
            t.begin("decode", now, slot=slot)
        req.state = RequestState.DECODE
        self.active[slot] = req
        self.decoding[slot] = True
        if req.done:
            # The prefill token alone met the budget (max_new_tokens <= 1,
            # or the prompt filled max_seq): finalize here — the decode
            # loop only finalizes requests as it appends tokens, and a
            # done-at-activation slot would otherwise zombie forever,
            # holding its pages.
            self._finalize_request(req, slot, now)

    # -- decode tick --------------------------------------------------------
    def step(self, now: float) -> List[Request]:
        """One engine tick: pump queued admissions, run prefill chunks per
        the interleave policy, then batched decode. In steady state (no
        pending admissions or prefill chunks, every active request has >=
        sync_every tokens to go) the whole deferred-sync window runs as ONE
        fused jitted scan — one dispatch and one host transfer per
        sync_every tokens. Scheduling boundaries fall back to single ticks.
        Returns the requests that finished (host-visible) this tick —
        including aborted ones (cancelled / timed out / shed / failed),
        which come back in a terminal ``RequestState`` with
        ``fail_reason`` set."""
        self._last_now = now
        if not self._trace_on:
            return self._step(now)
        # per-tick wall accounting (profiling hook): host wall seconds per
        # step() call — the virtual `now` clock says nothing about what a
        # tick actually cost
        w0 = time.perf_counter()
        try:
            return self._step(now)
        finally:
            self._tick_wall.observe(time.perf_counter() - w0)

    def _step(self, now: float) -> List[Request]:
        self._reap_doomed(now)
        self._pump_admissions(now)
        self._run_prefill_chunks(now)
        if not any(self.decoding):
            return self._take_finished()
        if self._fusable():
            if self.paged:
                self._ensure_headroom(self.sync_every, now)
            toks, hist, self.cache = self._decode_scan(
                self.params, self.cache, self._tokens, self._samp)
            self._tokens = toks
            self.metrics.decode_ticks += self.sync_every
            self._advance_pos(self.sync_every)
            self._distribute(np.asarray(hist), now)
            return self._take_finished()
        if self.paged:
            self._ensure_headroom(1, now)
        nxt, self.cache = self._decode(self.params, self.cache, self._tokens,
                                       self._samp)
        self._tokens = nxt
        self._unsynced.append(nxt)
        self.metrics.decode_ticks += 1
        self._advance_pos(1)
        pend = len(self._unsynced)
        if (pend >= self.sync_every
                or any(r is not None and d
                       and len(r.output) + pend >= r.max_new_tokens
                       for r, d in zip(self.active, self.decoding))):
            self._flush(now)
        return self._take_finished()

    # -- lifecycle: deadline-abort / cancel / shed --------------------------
    def _reap_doomed(self, now: float):
        """Abort every doomed request — client-cancelled, past its
        whole-request deadline, or (``shed_overdue``) queued past its TTFT
        deadline — wherever it sits: frontend-visible queues, chunked
        prefill, or a live decode slot. Freed slots and pages go back to
        the pool the same tick, so a doomed request never burns another
        decode step's budget."""

        def doom(req: Request) -> Optional[RequestState]:
            d = req.overdue(now)
            if d is not None:
                return d
            if (self.shed_overdue and req.prefill_done < 0
                    and now > req.ttft_deadline):
                return RequestState.TIMED_OUT  # shed (counted separately)
            return None

        # queued (backlog + admission accumulator): no resources held
        for queue in (self.backlog, self.admission.pending):
            doomed = [r for r in queue if doom(r) is not None]
            for req in doomed:
                queue.remove(req)
                self._abort(req, now, doom(req))
        # chunked prefill jobs: slot + page reservation held
        for job in [j for j in self._jobs if doom(j.req) is not None]:
            self._jobs.remove(job)
            state = doom(job.req)
            self.release_slot(job.slot)
            self._abort(job.req, now, state)
        # live decode slots: flush deferred tokens first so the abort
        # decision (and every OTHER slot's stream) sees a complete output
        if any(r is not None and d and doom(r) is not None
               for r, d in zip(self.active, self.decoding)):
            self._flush(now)
            for i, (r, d) in enumerate(zip(self.active, self.decoding)):
                if r is None or not d:
                    continue
                state = doom(r)
                if state is not None:
                    self.release_slot(i)
                    self._abort(r, now, state)

    def _abort(self, req: Request, now: float, state: RequestState):
        """Terminal bookkeeping for an aborted request (slot/pages already
        released by the caller)."""
        shed = (state is RequestState.TIMED_OUT
                and not req.cancel_requested and now <= req.jct_deadline)
        req.state = state
        req.finish_time = now
        if state is RequestState.CANCELLED:
            req.fail_reason = req.fail_reason or "cancelled by client"
            self.metrics.cancelled += 1
        elif shed:
            req.fail_reason = (f"shed: TTFT deadline "
                               f"{req.ttft_deadline:.4f} unreachable at "
                               f"{now:.4f} (overload)")
            self.metrics.shed += 1
            if req.tenant:
                self.metrics.tenant(req.tenant).shed += 1
        else:
            req.fail_reason = req.fail_reason or (
                f"timed out: exceeded timeout_s={req.timeout_s:.4f} "
                f"after arrival")
            self.metrics.timed_out += 1
        self._tr_terminal(req, now, "abort", state=state.value,
                          reason=req.fail_reason[:120])
        self._finished.append(req)

    def _fail_slot(self, slot: int, now: float, reason: str):
        """Fail ONLY the request in ``slot`` (mid-stream resource loss —
        e.g. a bypassed page reservation surfacing as pool exhaustion):
        the engine and every other stream keep running."""
        req = self.active[slot]
        self.release_slot(slot)
        req.state = RequestState.FAILED
        req.fail_reason = reason
        req.finish_time = now
        self.metrics.failed += 1
        self._tr_terminal(req, now, "abort", state="failed",
                          reason=reason[:120])
        self._finished.append(req)

    def takeover_queue(self) -> List[Request]:
        """Hand back every queued-but-unstarted request (backlog +
        admission accumulator, in drain order) — the migration primitive:
        a retiring replica's queue moves through the cluster frontend to
        survivors instead of waiting out the drain here. In-flight work
        (decode slots, chunk jobs) stays and finishes locally."""
        out = list(self.backlog)
        self.backlog.clear()
        out.extend(self.admission.flush())
        return out

    def _advance_pos(self, n: int):
        """Advance the host mirror of each decoding slot's cache position
        (paged mode tracks it to pre-allocate decode pages without a
        device sync)."""
        if not self.paged:
            return
        for i, d in enumerate(self.decoding):
            if d:
                self._pos_h[i] += n

    def _ensure_headroom(self, n: int, now: float = 0.0):
        """Write every decoding slot enough page-table entries to absorb
        ``n`` more tokens BEFORE the fused window runs — table writes are
        host decisions and cannot happen inside the scan. The pages come
        from the slot's admission-time reservation; allocating here is a
        defensive fallback (reachable only when the reservation lifecycle
        was bypassed). A shortage — after evicting idle cached prefixes —
        fails ONLY the starved request (loud ``OutOfPagesError`` text in
        its ``fail_reason``, naming the sizing fix); the engine and every
        other stream keep serving."""
        for i, (r, d) in enumerate(zip(self.active, self.decoding)):
            if r is None or not d:
                continue
            end = min(self._pos_h[i] + n, self.max_seq)
            need = self.allocator.pages_for(end)
            if need <= self._tabled[i]:
                continue
            owned = self.allocator.owned(i)
            if need > len(owned):
                if not self._alloc_evicting(i, need - len(owned)):
                    self._fail_slot(i, now, (
                        f"OutOfPagesError: slot {i} needs "
                        f"{need - len(owned)} page(s) mid-decode but the "
                        f"pool is exhausted ({self.allocator.pages_in_use}/"
                        f"{self.allocator.capacity} in use); size pool_pages "
                        f"for decode headroom "
                        f"(slots * max_seq / page_size + 1)"))
                    continue
                owned = self.allocator.owned(i)
            for k in range(self._tabled[i], need):
                self.cache = self._table_append(
                    self.cache, np.int32(i), np.int32(k), np.int32(owned[k]))
            self._tabled[i] = need

    def _finalize_request(self, req: Request, slot: int, now: float):
        """Retire a finished request: record metrics, free the slot (and
        its pages), and stage it for the caller."""
        req.state = RequestState.FINISHED
        req.finish_time = now
        self._finished.append(req)
        self.release_slot(slot)
        self.metrics.completed += 1
        self.metrics.total_tokens += len(req.output)
        if req.tenant:
            tm = self.metrics.tenant(req.tenant)
            tm.completed += 1
            tm.total_tokens += len(req.output)
        jct = now - req.arrival_time
        self.metrics.jcts.append(jct)
        self.metrics.latencies.append(jct)
        if req.tpot > 0:
            self.metrics.tpots.append(req.tpot)
        self.metrics.record_slo(req)
        t = req.trace
        if t is not None:
            if t.is_open("decode"):
                t.end("decode", now, tokens=len(req.output))
            self.tracer.collect(t)

    def release_slot(self, slot: int):
        """Retire ``slot`` (finished or cancelled request): return its pages
        to the allocator and neutralize its device page-table row."""
        self.active[slot] = None
        self.decoding[slot] = False
        self._hit_pending.pop(slot, None)
        if not bool(self._samp_greedy_h[slot]):
            # reset the lane to greedy so an all-greedy batch's decode
            # skips the sampling branch again (the lane's draws were
            # already inert: a vacated slot's tokens go nowhere)
            self._samp = self._samp_set(self._samp, np.int32(slot),
                                        sampling_row(None))
            self._samp_greedy_h[slot] = True
        if self.paged:
            self.cache = self._release(self.cache, np.int32(slot))
            self.allocator.free_slot(slot)  # decref: shared pages survive
            self._pos_h[slot] = 0
            self._tabled[slot] = 0

    def _fusable(self) -> bool:
        return (self.sync_every > 1
                and not self._unsynced
                and not self._jobs
                and not self.backlog
                and not self.admission.pending
                and all(r.max_new_tokens - len(r.output) >= self.sync_every
                        for r, d in zip(self.active, self.decoding)
                        if r is not None and d))

    def _flush(self, now: float = None):
        """One host sync for the whole deferred window: transfers the
        stacked (T, B) token block and distributes tokens to requests."""
        if not self._unsynced:
            return
        toks = np.asarray(jnp.stack(self._unsynced))
        self._unsynced = []
        self._distribute(toks, now)

    def _distribute(self, toks: np.ndarray, now: float = None):
        """Hand a (T, B) host token block to the per-slot requests."""
        self.metrics.host_syncs += 1
        t_now = time.time() if now is None else now
        for i, r in enumerate(self.active):
            if r is None or not self.decoding[i]:
                continue
            tr = r.trace
            n0 = len(r.output) if tr is not None else 0
            done = False
            for t in range(toks.shape[0]):
                if r.done:
                    break
                tok = int(toks[t, i])
                r.output.append(tok)
                if r.done or tok == self.eos_id:
                    done = True
                    break
            if tr is not None and len(r.output) > n0:
                # one span per fused window whose host sync delivered
                # tokens to this slot; t0 floors at the trace's latest
                # span so a freshly (re)activated request's window never
                # pre-dates its decode span (prefill_done keeps the FIRST
                # activation time across preempt/restore). Appended BEFORE
                # finalization so the terminal collect() sees it.
                t0 = max(self._win_t0, r.prefill_done)
                if tr.spans:
                    t0 = max(t0, tr.spans[-1].t0)
                tr.add("decode_window", min(t0, t_now), t_now,
                       tokens=len(r.output) - n0)
            if done:
                self._finalize_request(r, i, t_now)
        self._win_t0 = t_now

    def _take_finished(self) -> List[Request]:
        out, self._finished = self._finished, []
        return out

    def drain(self, now: float):
        """Flush any deferred tokens (end-of-run bookkeeping)."""
        self._flush(now)
        return self._take_finished()

    def reset(self):
        """Return the engine to an empty state — every slot vacated (pages
        reclaimed), queues and metrics cleared — while keeping its compiled
        steps warm, so bench/test rounds reuse one engine without paying
        recompiles. In-flight requests are abandoned, not finished."""
        self.drain(0.0)
        for i in range(self.slots):
            if self.active[i] is not None:
                self.release_slot(i)
        self._jobs.clear()
        self._hit_pending.clear()
        if self.prefix_index is not None:
            self.prefix_index.clear()  # cached pages back to the pool
        self.backlog.clear()
        self.admission.flush()
        self._unsynced = []
        self._finished = []
        self.metrics = ServeMetrics()
        # fresh span rollups + wall accounting; compile_events persist —
        # they mirror the jit caches, which reset() deliberately keeps warm
        self.tracer = Tracer(enabled=self._trace_on,
                             ring=self.config.trace_ring)
        self._tick_wall = latency_histogram()
        self._win_t0 = 0.0

    # -- prefix cache ------------------------------------------------------
    def prefix_match_len(self, tokens) -> int:
        """Cached-prefix length a prompt would hit HERE (0 when the index
        is off) — the cluster frontend's affinity probe. Read-only: no
        LRU touch, no counters."""
        if self.prefix_index is None:
            return 0
        return self.prefix_index.match_len(tokens)

    def clear_prefix_cache(self) -> int:
        """Drop every cached prefix (pages with no live alias return to
        the pool immediately). Returns pages freed."""
        if self.prefix_index is None:
            return 0
        return self.prefix_index.clear()

    # -- telemetry ---------------------------------------------------------
    def load_report(self) -> LoadReport:
        """Snapshot the engine's load for cluster routing: free slots and
        pages, queued prefill tokens, unfinished decode budgets (scalar
        and per-slot/per-queued for the frontend's slot-availability
        simulation), and the cost model's predicted seconds to drain it
        all. Pure host-side arithmetic — safe to call every dispatch
        without a device sync."""
        queued = list(self.backlog) + list(self.admission.pending)
        if self.edf_backlog:
            queued.sort(key=lambda r: r.ttft_deadline)
        chunks_left = {j.slot: -(-(j.tokens.shape[1] - j.next_off)
                                 // max(1, self.chunk))
                       for j in self._jobs}
        remaining = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            rem = max(0, r.max_new_tokens - len(r.output))
            remaining.append(rem + chunks_left.get(i, 0))
        q_pref = sum(r.prompt_len for r in queued)
        q_pref += sum(max(0, j.tokens.shape[1] - j.next_off)
                      for j in self._jobs)
        dec_rem = sum(remaining) + sum(r.max_new_tokens for r in queued)
        pre_s = (estimate_prefill(self.cfg, 1, q_pref,
                                  n_chips=self.n_chips,
                                  mesh_axes=self._mesh_axes).latency_s
                 if q_pref > 0 else 0.0)
        # backlog_s = prefill term (computed once, above) + decode term
        dec_s = estimate_backlog_s(
            self.cfg, queued_prefill_tokens=0,
            decode_tokens_remaining=dec_rem, slots=self.slots,
            context=self.window, n_chips=self.n_chips,
            mesh_axes=self._mesh_axes)
        idx = self.prefix_index
        tick = self._tick_est_s
        axis_cs = tuple(sorted(self._axis_collective_s.items()))
        return LoadReport(
            slots=self.slots,
            free_slots=sum(r is None for r in self.active),
            queued_requests=len(queued),
            queued_prefill_tokens=q_pref,
            decode_tokens_remaining=dec_rem,
            free_pages=self.allocator.free_pages if self.paged else -1,
            total_pages=self.allocator.capacity if self.paged else 0,
            backlog_s=pre_s + dec_s,
            tick_est_s=self._tick_est_s,
            queued_prefill_s=pre_s,
            active_remaining=tuple(remaining),
            queued_budgets=tuple(r.max_new_tokens for r in queued),
            prefix_cached_pages=idx.cached_pages if idx else 0,
            prefix_cached_tokens=idx.cached_tokens if idx else 0,
            prefix_hits=self.metrics.prefix_hits,
            prefix_hit_tokens=self.metrics.prefix_hit_tokens,
            rejected=self.metrics.rejected,
            cancelled=self.metrics.cancelled,
            timed_out=self.metrics.timed_out,
            shed=self.metrics.shed,
            failed=self.metrics.failed,
            preempted=self.metrics.preempted,
            mesh_axes=self.topology.mesh_axes,
            axis_collective_s=axis_cs,
            axis_util=tuple((a, s / tick if tick > 0 else 0.0)
                            for a, s in axis_cs),
            moe_capacity_policy=self.moe_capacity_policy,
            moe_drop_free_group=self._moe_gmax,
            histograms=self.metrics.histogram_wire(),
            span_totals=self.tracer.totals_wire(),
            compile_events=tuple(sorted(self.compile_events.items())),
            browned_out=self.metrics.browned_out,
            tenant_stats=self.metrics.tenant_wire(),
            kv_bytes_per_token=kv_bytes_per_token(self.cfg, self.kv_dtype),
            kv_cache_dtype=self.kv_dtype,
            weight_dtype=self.config.precision.weight_dtype)

    @property
    def mesh_axes(self):
        """((name, size), ...) of a sharded replica's mesh, None on 1-chip
        engines — the cost-model key for collective-aware estimates."""
        return self._mesh_axes

    @property
    def idle(self) -> bool:
        """No active, prefilling, or queued work (drain-complete test)."""
        return (self.n_active == 0 and not self._jobs and not self.backlog
                and not self.admission.pending and not self._unsynced)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    @property
    def n_decoding(self) -> int:
        return sum(self.decoding)

    @property
    def n_prefilling(self) -> int:
        return len(self._jobs)


def _padded_len(n: int, chunk: int) -> int:
    return ((n + chunk - 1) // chunk) * chunk


def _batch_len(batch) -> int:
    """Padded sequence length of a prefill batch (tokens or audio frames)
    — the shape component of its trace-cache key."""
    b = batch.get("tokens")
    if b is None:
        b = next(iter(batch.values()))
    return int(b.shape[1])


def generate(cfg, params, prompt: np.ndarray, max_new_tokens: int,
             *, window: int = 512,
             sampling: Optional[SamplingParams] = None) -> List[int]:
    """Simple single-request generation helper (examples/quickstart)."""
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=window))
    req = Request(rid=0, prompt=prompt, max_new_tokens=max_new_tokens,
                  sampling=sampling or SamplingParams())
    assert eng.try_admit(req, now=0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    return req.output
