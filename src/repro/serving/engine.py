"""Serving engine: jit'd prefill / decode steps + a continuous-batching
executor (the survey's "adaptive batching" [8][4] in its modern form).

The engine maintains B decode slots backed by one batched cache pytree.
Each slot runs an independent request (per-slot positions / rolling KV).
When a slot finishes, the next queued request is prefilled (B=1) and its
cache is scattered into the slot — decode never stalls for prefill sizing.

All steps are pure jit functions; the executor is the only stateful part.
"""
from __future__ import annotations

import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache
from repro.serving.request import Request, ServeMetrics


# ---------------------------------------------------------------------------
# jit'd steps (also the units the dry-run lowers)
# ---------------------------------------------------------------------------


def prefill_step(cfg, params, batch, *, window: int):
    """Full-prompt forward filling a fresh cache. Returns (last_token_logits,
    cache)."""
    b = (batch["frames"] if cfg.modality == "audio" else batch["tokens"]).shape[0]
    cache = init_cache(cfg, b, window)
    logits, _, cache = forward(cfg, params, batch, mode="prefill", cache=cache)
    return logits[:, -1], cache


def serve_step(cfg, params, cache, batch):
    """One decode step for every active slot: ONE new token against the KV
    cache. Returns (next_tokens (B,), logits (B,V), new_cache)."""
    logits, new_cache = decode_step(cfg, params, cache, batch)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt, logits[:, -1], new_cache


def _cache_batch_axis(path_leaf_shape, batch: int):
    """Find the batch axis of a cache leaf (0 for tail leaves, 1 for stacked
    body leaves)."""
    for ax, n in enumerate(path_leaf_shape):
        if n == batch:
            return ax
    raise ValueError(f"no batch axis {batch} in {path_leaf_shape}")


def cache_insert(batched_cache, single_cache, slot: int, batch: int):
    """Scatter a B=1 cache into slot `slot` of a batched cache."""

    def ins(big, small):
        ax = _cache_batch_axis(big.shape, batch)
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, ax)

    return jax.tree.map(ins, batched_cache, single_cache)


# ---------------------------------------------------------------------------
# continuous-batching executor
# ---------------------------------------------------------------------------


class ServingEngine:
    """Single-instance engine (SISD quadrant) with continuous batching.

    ``slots``: max concurrent decode streams. ``window``: KV window.
    """

    def __init__(self, cfg, params, *, slots: int = 4, window: int = 512,
                 eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.window = window
        self.eos_id = eos_id
        self.cache = init_cache(cfg, slots, window)
        self.active: List[Optional[Request]] = [None] * slots
        self._prefill = jax.jit(
            partial(prefill_step, cfg, window=window), static_argnames=())
        self._decode = jax.jit(partial(serve_step, cfg))
        self.metrics = ServeMetrics()

    # -- admission ---------------------------------------------------------
    def try_admit(self, req: Request, now: float) -> bool:
        for i, slot in enumerate(self.active):
            if slot is None:
                self._admit_at(req, i, now)
                return True
        return False

    def _admit_at(self, req: Request, slot: int, now: float):
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        if self.cfg.rope_variant == "mrope":
            s = req.prompt_len
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, 1, s))
        logits, cache1 = self._prefill(self.params, batch)
        self.cache = cache_insert(self.cache, cache1, slot, self.slots)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        req.prefill_done = now
        self.active[slot] = req

    # -- decode tick --------------------------------------------------------
    def step(self, now: float) -> List[Request]:
        """One batched decode step; returns requests finished this tick."""
        if not any(r is not None for r in self.active):
            return []
        last = [
            (r.output[-1] if r is not None and r.output else 0)
            for r in self.active
        ]
        batch = {"tokens": jnp.asarray(last, jnp.int32)[:, None]}
        if self.cfg.rope_variant == "mrope":
            pos = np.asarray(self.cache["pos"])
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32)[None, :, None], (3, self.slots, 1))
        nxt, _, self.cache = self._decode(self.params, self.cache, batch)
        nxt = np.asarray(nxt)
        finished = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = int(nxt[i])
            r.output.append(tok)
            if r.done or tok == self.eos_id:
                r.finish_time = now
                finished.append(r)
                self.active[i] = None
                self.metrics.completed += 1
                self.metrics.total_tokens += len(r.output)
                self.metrics.jcts.append(now - r.arrival_time)
        return finished

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)


def generate(cfg, params, prompt: np.ndarray, max_new_tokens: int,
             *, window: int = 512) -> List[int]:
    """Simple single-request generation helper (examples/quickstart)."""
    eng = ServingEngine(cfg, params, slots=1, window=window)
    req = Request(rid=0, prompt=prompt, max_new_tokens=max_new_tokens)
    assert eng.try_admit(req, now=0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    return req.output
