"""Inference request/response types for the serving stack.

These are the schedulable units of the survey's taxonomy: the MISD/MIMD
schedulers (repro.core) operate on ``Request`` metadata; the engine
(repro.serving.engine) executes the token work; the cluster frontend
(repro.serving.cluster) routes on the SLO fields.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.metrics import Histogram, latency_histogram
from repro.serving.tracing import Trace


class RequestState(str, enum.Enum):
    """Explicit request lifecycle (survey: availability and tail latency,
    not just throughput, define serving quality — a request must be
    cancellable, abortable, and preemptible at every stage).

    ::

        QUEUED -> PREFILL -> DECODE -> FINISHED
           |         |         |----> CANCELLED   (client cancel())
           |         |         |----> TIMED_OUT   (deadline-abort / shed)
           |         |         |----> FAILED      (rejection, replica loss,
           |         |         |                   retry budget exhausted)
           |         |         '----> PREEMPTED -> QUEUED  (restore)
           |         '---- same terminal edges ----'
           '------- same terminal edges -----------'

    PREEMPTED is the only non-terminal exit: the victim's generated
    tokens fold into its prompt and it requeues; the prefix-cache hit
    path restores it with suffix-only prefill, bit-identical to an
    unpreempted run (seeded sampling is keyed by absolute position).
    """

    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    FAILED = "failed"
    PREEMPTED = "preempted"

    @property
    def terminal(self) -> bool:
        return self in _TERMINAL


_TERMINAL = frozenset({RequestState.FINISHED, RequestState.CANCELLED,
                       RequestState.TIMED_OUT, RequestState.FAILED})


class RequestRejected(ValueError):
    """A request that cannot be served as submitted (oversize prompt,
    unknown model pool, tenant rate limit, overload rejection).
    ``ServingEngine.submit`` / ``ClusterFrontend.submit`` catch it and
    turn the request into a FAILED outcome with ``fail_reason`` set
    (counted in ``ServeMetrics.rejected``) instead of letting one poison
    request crash the serving loop; the low-level ``try_admit`` path
    still raises it for direct callers. Subclasses ``ValueError`` for
    backward compatibility.

    ``retry_after_s`` is the rejection contract under overload (survey:
    serverless inference makes typed retry-after the saturated-pool
    protocol): cost-model-derived seconds after which a resubmission has
    a real chance of admission. 0.0 means "permanent" — the request is
    malformed and retrying will never help (oversize prompt); a finite
    positive value means "come back later" (rate limit / load shedding).
    """

    def __init__(self, reason: str = "", retry_after_s: float = 0.0):
        super().__init__(reason)
        self.retry_after_s = float(retry_after_s)


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decode sampling configuration (survey: widening the
    workload mix a serving stack can host beyond deterministic decode).

    Greedy argmax is the degenerate case ``temperature <= 0`` — the
    default, so every existing caller keeps deterministic streams. A
    stochastic request's token stream is a pure function of ``seed`` and
    the absolute token position (the engine keys its PRNG noise by
    ``fold_in(key(seed), position)``), so a fixed seed reproduces the
    stream bit-for-bit across engine restarts, slot assignments, batch
    compositions, and cluster replicas.
    """

    temperature: float = 0.0  # <= 0: greedy argmax (deterministic)
    top_k: int = 0  # keep the k largest logits; 0 = no top-k cut
    top_p: float = 1.0  # nucleus mass; >= 1 = no top-p cut
    seed: int = 0  # PRNG stream identity (stable under routing)

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    priority: int = 0  # higher = more urgent
    sla_ms: float = 0.0  # legacy whole-request SLA; 0 = best-effort
    model: str = ""  # routing pool tag (cluster frontend); "" = default pool
    # --- multi-tenant SLO classes (overload control; see serving/overload) ---
    # tenant identity for weighted-fair admission; "" = untagged traffic
    # (single-tenant path: no per-tenant accounting, no fair queueing)
    tenant: str = ""
    # SLO tier (higher = more protected). Stamped by the frontend from the
    # registered TenantClass at submit; the degradation ladder sheds /
    # brownouts / rejects strictly from the lowest tier upward.
    tier: int = 0
    # --- per-request SLOs (survey §3.2.3; 0 = untracked) ---
    ttft_slo_s: float = 0.0  # time-to-first-token deadline after arrival
    tpot_slo_s: float = 0.0  # mean time-per-output-token bound
    # --- filled during serving ---
    output: List[int] = field(default_factory=list)
    prefill_done: float = -1.0
    finish_time: float = -1.0
    routed_to: str = ""  # cluster frontend: name of the serving replica
    # True when the engine shortened max_new_tokens to fit its per-request
    # token capacity (paged KV: prompt + output <= max_seq) — the stream
    # ends early by budget, not by eos.
    budget_capped: bool = False
    # tokens the overload ladder's brownout trimmed off max_new_tokens at
    # dispatch (per-tier budget trim under saturation); 0 = full budget.
    # A browned-out stream is a bit-identical PREFIX of the unclamped one
    # (greedy/seeded decode is position-keyed), so the degradation is
    # "shorter answer", never "different answer".
    browned_out_tokens: int = 0
    # rejection contract: finite seconds after which a resubmission has a
    # real chance (set with a "rejected:"/"shed:" fail_reason; 0 = n/a)
    retry_after_s: float = 0.0
    # prompt tokens served from the shared-prefix KV cache (their prefill
    # was skipped: the pages were aliased from the PrefixIndex); 0 = cold
    prefix_hit_tokens: int = 0
    # decode sampling configuration; the default is greedy argmax
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # --- lifecycle (fault tolerance) ---
    state: RequestState = RequestState.QUEUED
    # whole-request deadline after arrival; 0 = never times out
    timeout_s: float = 0.0
    fail_reason: str = ""  # set with CANCELLED/TIMED_OUT/FAILED
    cancel_requested: bool = False  # set by cancel(); acted on next tick
    retries: int = 0  # failover re-submissions consumed (cluster frontend)
    preemptions: int = 0  # times this request was evicted mid-stream
    # generated tokens folded into ``prompt`` by preemption (restore
    # context); ``output`` keeps them too, so the client-visible stream
    # is unchanged and ``done`` keeps counting against the full budget
    restored_tokens: int = 0
    # --- observability ---
    # span trace stamped by engine/frontend at phase boundaries; None
    # unless tracing is enabled somewhere along the request's path.
    # Survives preemption AND failover (reset_for_retry leaves it alone)
    # so one trace tells the request's whole story across replicas.
    trace: Optional[Trace] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens

    @property
    def ttft(self) -> float:
        """Time to first token (prefill completion) relative to arrival."""
        if self.prefill_done < 0:
            return -1.0
        return self.prefill_done - self.arrival_time

    @property
    def tpot(self) -> float:
        """Mean time per output token over the decode phase (excludes the
        prefill token); -1 before completion or for single-token streams."""
        if self.finish_time < 0 or self.prefill_done < 0:
            return -1.0
        n_decode = len(self.output) - 1
        if n_decode <= 0:
            return 0.0
        return (self.finish_time - self.prefill_done) / n_decode

    @property
    def ttft_deadline(self) -> float:
        """Absolute deadline for the first token — the EDF ordering key.
        Untracked requests sort last (infinite deadline)."""
        if self.ttft_slo_s <= 0:
            return float("inf")
        return self.arrival_time + self.ttft_slo_s

    def meets_slo(self) -> Optional[bool]:
        """True/False once finished against the declared SLOs; None when
        the request declares no SLO (untracked — excluded from goodput)."""
        if self.ttft_slo_s <= 0 and self.tpot_slo_s <= 0:
            return None
        ok = True
        if self.ttft_slo_s > 0:
            ok = ok and 0 <= self.ttft <= self.ttft_slo_s
        if self.tpot_slo_s > 0:
            ok = ok and 0 <= self.tpot <= self.tpot_slo_s
        return ok

    # -- lifecycle ---------------------------------------------------------
    @property
    def remaining_tokens(self) -> int:
        """Tokens still owed against the budget (restore-aware: a
        preempted request's folded tokens are already in ``output``)."""
        return max(0, self.max_new_tokens - len(self.output))

    @property
    def jct_deadline(self) -> float:
        """Absolute whole-request abort deadline (inf = never)."""
        if self.timeout_s <= 0:
            return float("inf")
        return self.arrival_time + self.timeout_s

    def cancel(self):
        """Client-side cancellation: flags the request; the engine (or the
        frontend, if still queued there) aborts it at its next tick and
        frees the slot and pages it holds. Idempotent; a no-op once the
        request reached a terminal state."""
        self.cancel_requested = True

    def overdue(self, now: float) -> Optional["RequestState"]:
        """The terminal state a doomed request should abort into at
        ``now`` — CANCELLED beats TIMED_OUT — or None while healthy."""
        if self.cancel_requested:
            return RequestState.CANCELLED
        if now > self.jct_deadline:
            return RequestState.TIMED_OUT
        return None

    def fold_output_into_prompt(self):
        """Preemption support: generated-but-unfolded tokens become prompt
        context, so re-admission treats them as prefill input (and the
        prefix-cache hit path can restore them with zero recompute). The
        tokens stay in ``output`` — the client-visible stream and the
        ``done`` budget arithmetic are unchanged."""
        new = self.output[self.restored_tokens:]
        if new:
            self.prompt = np.concatenate(
                [np.asarray(self.prompt, np.int32),
                 np.asarray(new, np.int32)])
            self.restored_tokens = len(self.output)

    def reset_for_retry(self):
        """Rewind to a just-submitted state for failover replay on a
        surviving replica: unfold any preemption context and drop every
        generated token. Seeded sampling keys noise by (seed, absolute
        position), so the replayed stream is bit-identical to the lost
        one — replay is safe to stream to a deduplicating client."""
        if self.restored_tokens:
            self.prompt = np.asarray(
                self.prompt[:self.prompt_len - self.restored_tokens],
                np.int32)
            self.restored_tokens = 0
        self.output = []
        self.prefill_done = -1.0
        self.finish_time = -1.0
        self.routed_to = ""
        self.prefix_hit_tokens = 0
        self.state = RequestState.QUEUED


@dataclass
class TenantMetrics:
    """Per-tenant serving counters + TTFT tail (overload control's
    accounting unit). Exactly mergeable across replicas like everything
    else in ``ServeMetrics``: counters add, the histogram merges bucket-
    for-bucket — so cluster-wide per-tenant goodput needs no sample
    shipping. Ships on the ``LoadReport`` v4 wire via ``to_wire``."""

    admitted: int = 0  # requests that reached a slot (first token emitted)
    completed: int = 0
    total_tokens: int = 0
    rejected: int = 0  # typed rejections (rate limit / ladder / unservable)
    shed: int = 0  # dropped by the degradation ladder or deadline-doom
    browned_out: int = 0  # served with a ladder-trimmed token budget
    brownout_trimmed_tokens: int = 0  # tokens the trims removed in total
    slo_tracked: int = 0
    slo_met: int = 0
    ttfts: Histogram = field(default_factory=latency_histogram)

    @property
    def goodput(self) -> float:
        if not self.slo_tracked:
            return 1.0
        return self.slo_met / self.slo_tracked

    def merge(self, other: "TenantMetrics") -> "TenantMetrics":
        self.admitted += other.admitted
        self.completed += other.completed
        self.total_tokens += other.total_tokens
        self.rejected += other.rejected
        self.shed += other.shed
        self.browned_out += other.browned_out
        self.brownout_trimmed_tokens += other.brownout_trimmed_tokens
        self.slo_tracked += other.slo_tracked
        self.slo_met += other.slo_met
        self.ttfts.merge(other.ttfts)
        return self

    _COUNTERS = ("admitted", "completed", "total_tokens", "rejected",
                 "shed", "browned_out", "brownout_trimmed_tokens",
                 "slo_tracked", "slo_met")

    def to_wire(self) -> tuple:
        """Hashable ((counter values...), ttft-histogram-wire-or-()) —
        one ``LoadReport.tenant_stats`` row body."""
        return (tuple(getattr(self, f) for f in self._COUNTERS),
                self.ttfts.to_wire() if self.ttfts.count else ())

    @classmethod
    def from_wire(cls, w) -> "TenantMetrics":
        counters, hist = w
        tm = cls(**dict(zip(cls._COUNTERS, (int(c) for c in counters))))
        if hist:
            tm.ttfts = Histogram.from_wire(hist)
        return tm


@dataclass
class ServeMetrics:
    """Aggregated server-side + client-side metrics (survey §3.2.3).

    Latency series are bounded fixed-bucket histograms (see
    repro.serving.metrics), not sample lists: memory stays O(buckets)
    under sustained traffic, ``merge`` stays exact across replicas
    (bucket counts and sum/count/min/max add), and percentiles come from
    the histogram within one bucket width of the sample-exact value.
    The old list call sites keep working — ``Histogram.append`` is an
    ``observe`` alias and ``extend`` folds iterables.
    """

    completed: int = 0
    total_tokens: int = 0
    total_time: float = 0.0
    latencies: Histogram = field(default_factory=latency_histogram)
    jcts: Histogram = field(default_factory=latency_histogram)  # completion
    ttfts: Histogram = field(default_factory=latency_histogram)  # first token
    tpots: Histogram = field(default_factory=latency_histogram)  # per token
    sla_violations: int = 0
    decode_ticks: int = 0  # batched decode steps executed
    host_syncs: int = 0  # device->host token transfers (1 per N ticks)
    prefill_chunks: int = 0  # chunked-prefill pieces interleaved with decode
    # --- shared-prefix KV cache ---
    prefix_hits: int = 0  # admissions that aliased cached prefix pages
    prefix_hit_tokens: int = 0  # prompt tokens whose prefill was skipped
    # --- stochastic decode ---
    sampled_requests: int = 0  # admissions with non-greedy SamplingParams
    # --- SLO attainment (requests declaring ttft_slo_s / tpot_slo_s) ---
    slo_tracked: int = 0  # finished requests that declared any SLO
    slo_met: int = 0  # ...that met every declared SLO
    ttft_slo_misses: int = 0
    tpot_slo_misses: int = 0
    # --- fault tolerance / lifecycle ---
    rejected: int = 0  # typed RequestRejected outcomes (never admitted)
    cancelled: int = 0  # client cancel() honored
    timed_out: int = 0  # whole-request deadline aborts
    shed: int = 0  # SLO-doomed requests dropped under overload
    browned_out: int = 0  # requests served with a ladder-trimmed budget
    failed: int = 0  # mid-stream failures (e.g. bypassed reservation)
    preempted: int = 0  # slot evictions (victim requeued for restore)
    preempt_restores: int = 0  # preempted requests re-admitted
    retried: int = 0  # failover re-submissions (cluster frontend)
    failed_over: int = 0  # requests harvested from a failed replica
    # --- multi-tenant overload control (keyed by Request.tenant; untagged
    # traffic stays out of this dict, so the single-tenant path is free) ---
    tenants: Dict[str, TenantMetrics] = field(default_factory=dict)

    def tenant(self, name: str) -> TenantMetrics:
        """The named tenant's accumulator (created on first touch)."""
        tm = self.tenants.get(name)
        if tm is None:
            tm = self.tenants[name] = TenantMetrics()
        return tm

    @property
    def qps(self) -> float:
        return self.completed / self.total_time if self.total_time else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.total_tokens / self.total_time if self.total_time else 0.0

    def p(self, q: float) -> float:
        return self.latencies.percentile(q)

    @property
    def mean_jct(self) -> float:
        return self.jcts.mean  # exact: histogram keeps a raw-sum accumulator

    def ttft_p(self, q: float) -> float:
        return self.ttfts.percentile(q)

    def tpot_p(self, q: float) -> float:
        return self.tpots.percentile(q)

    # -- SLO attainment ----------------------------------------------------
    def record_slo(self, req: Request):
        """Fold one finished request's SLO verdict into the counters
        (called by the engine at finalize; no-op for untracked requests)."""
        verdict = req.meets_slo()
        if verdict is None:
            return
        self.slo_tracked += 1
        if verdict:
            self.slo_met += 1
        if req.tenant:
            tm = self.tenant(req.tenant)
            tm.slo_tracked += 1
            if verdict:
                tm.slo_met += 1
        if req.ttft_slo_s > 0 and not (0 <= req.ttft <= req.ttft_slo_s):
            self.ttft_slo_misses += 1
        if req.tpot_slo_s > 0 and not (0 <= req.tpot <= req.tpot_slo_s):
            self.tpot_slo_misses += 1

    @property
    def goodput(self) -> float:
        """Fraction of SLO-tracked completions meeting every declared SLO
        (1.0 when nothing is tracked — no SLO means nothing to violate)."""
        if not self.slo_tracked:
            return 1.0
        return self.slo_met / self.slo_tracked

    def merge(self, other: "ServeMetrics"):
        """Accumulate another engine's counters (cluster-wide rollup)."""
        self.completed += other.completed
        self.total_tokens += other.total_tokens
        self.total_time = max(self.total_time, other.total_time)
        self.latencies.merge(other.latencies)  # exact histogram merge
        self.jcts.merge(other.jcts)
        self.ttfts.merge(other.ttfts)
        self.tpots.merge(other.tpots)
        self.sla_violations += other.sla_violations
        self.decode_ticks += other.decode_ticks
        self.host_syncs += other.host_syncs
        self.prefill_chunks += other.prefill_chunks
        self.prefix_hits += other.prefix_hits
        self.prefix_hit_tokens += other.prefix_hit_tokens
        self.sampled_requests += other.sampled_requests
        self.slo_tracked += other.slo_tracked
        self.slo_met += other.slo_met
        self.ttft_slo_misses += other.ttft_slo_misses
        self.tpot_slo_misses += other.tpot_slo_misses
        self.rejected += other.rejected
        self.cancelled += other.cancelled
        self.timed_out += other.timed_out
        self.shed += other.shed
        self.browned_out += other.browned_out
        self.failed += other.failed
        self.preempted += other.preempted
        self.preempt_restores += other.preempt_restores
        self.retried += other.retried
        self.failed_over += other.failed_over
        for name, tm in other.tenants.items():
            self.tenant(name).merge(tm)

    # -- observability -----------------------------------------------------
    _HISTOGRAMS = (("latency_s", "latencies"), ("jct_s", "jcts"),
                   ("ttft_s", "ttfts"), ("tpot_s", "tpots"))

    def histogram_wire(self) -> tuple:
        """Non-empty latency histograms in LoadReport wire form:
        ((name, sparse-histogram-tuple), ...)."""
        return tuple((name, getattr(self, attr).to_wire())
                     for name, attr in self._HISTOGRAMS
                     if getattr(self, attr).count)

    def tenant_wire(self) -> tuple:
        """Per-tenant rollups in LoadReport v4 wire form:
        ((tenant, (counters...), ttft-wire-or-()), ...), sorted by name."""
        return tuple((name, *tm.to_wire())
                     for name, tm in sorted(self.tenants.items()))

    def registry(self, prefix: str = "serving_") -> "MetricsRegistry":
        """Snapshot this struct as a MetricsRegistry for exposition.
        Histograms are registered by reference (zero copies); counters
        are copied point-in-time values."""
        from repro.serving.metrics import MetricsRegistry
        reg = MetricsRegistry()
        for name, attr in self._HISTOGRAMS:
            reg.register(f"{prefix}{name.rsplit('_', 1)[0]}_seconds",
                         getattr(self, attr))
        for f in ("completed", "total_tokens", "rejected", "cancelled",
                  "timed_out", "shed", "browned_out", "failed", "preempted",
                  "preempt_restores", "retried", "failed_over",
                  "decode_ticks", "host_syncs", "prefill_chunks",
                  "prefix_hits", "prefix_hit_tokens", "sampled_requests",
                  "slo_tracked", "slo_met", "ttft_slo_misses",
                  "tpot_slo_misses"):
            reg.set_counter(f"{prefix}{f}_total", getattr(self, f))
        for name, tm in sorted(self.tenants.items()):
            lbl = f'{{tenant="{name}"}}'
            for f in TenantMetrics._COUNTERS:
                reg.set_counter(f"{prefix}tenant_{f}_total{lbl}",
                                getattr(tm, f))
            reg.set_gauge(f"{prefix}tenant_goodput{lbl}", tm.goodput)
            if tm.ttfts.count:
                reg.register(f"{prefix}tenant_ttft_seconds{lbl}", tm.ttfts)
        reg.set_gauge(f"{prefix}goodput", self.goodput)
        reg.set_gauge(f"{prefix}qps", self.qps)
        reg.set_gauge(f"{prefix}throughput_tokens_per_s",
                      self.throughput_tps)
        return reg
