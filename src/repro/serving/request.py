"""Inference request/response types for the serving stack.

These are the schedulable units of the survey's taxonomy: the MISD/MIMD
schedulers (repro.core) operate on ``Request`` metadata; the engine
(repro.serving.engine) executes the token work.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    priority: int = 0  # higher = more urgent
    sla_ms: float = 0.0  # latency SLA; 0 = best-effort
    # --- filled during serving ---
    output: List[int] = field(default_factory=list)
    prefill_done: float = -1.0
    finish_time: float = -1.0
    # True when the engine shortened max_new_tokens to fit its per-request
    # token capacity (paged KV: prompt + output <= max_seq) — the stream
    # ends early by budget, not by eos.
    budget_capped: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens

    @property
    def ttft(self) -> float:
        """Time to first token (prefill completion) relative to arrival."""
        if self.prefill_done < 0:
            return -1.0
        return self.prefill_done - self.arrival_time


@dataclass
class ServeMetrics:
    """Aggregated server-side + client-side metrics (survey §3.2.3)."""

    completed: int = 0
    total_tokens: int = 0
    total_time: float = 0.0
    latencies: List[float] = field(default_factory=list)
    jcts: List[float] = field(default_factory=list)  # job completion times
    ttfts: List[float] = field(default_factory=list)  # time to first token
    sla_violations: int = 0
    decode_ticks: int = 0  # batched decode steps executed
    host_syncs: int = 0  # device->host token transfers (1 per N ticks)
    prefill_chunks: int = 0  # chunked-prefill pieces interleaved with decode

    @property
    def qps(self) -> float:
        return self.completed / self.total_time if self.total_time else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.total_tokens / self.total_time if self.total_time else 0.0

    def p(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies), q))

    @property
    def mean_jct(self) -> float:
        return float(np.mean(self.jcts)) if self.jcts else 0.0

    def ttft_p(self, q: float) -> float:
        if not self.ttfts:
            return 0.0
        return float(np.percentile(np.asarray(self.ttfts), q))
