"""Bounded serving metrics: counters, gauges, fixed-bucket histograms.

The survey frames serving as a closed loop between measurement and
scheduling: SLO attainment and tail latency can only be optimized if the
system can *see* them, cheaply, forever.  Python lists of per-request
latencies (the pre-observability `ServeMetrics`) grow without bound and
cannot be merged across replicas without shipping every sample.  This
module replaces them with fixed-bucket histograms:

- **Bounded**: memory is O(buckets), independent of request count.
- **Exactly mergeable**: two histograms over the same bounds merge by
  elementwise count addition plus exact sum/count/min/max accumulators —
  ``merge(a, b)`` equals the histogram of the concatenated samples,
  bucket-for-bucket, which is what lets a cluster frontend aggregate
  replica reports without bias.
- **Quantile-accurate to one bucket width**: ``percentile(q)`` walks the
  cumulative counts and linearly interpolates inside the target bucket,
  so the answer is always within the containing bucket's bounds.

Buckets are *fixed at construction* (no rebinning): log-spaced for
latencies (constant relative error), linear for residuals.  Named
presets in ``BUCKET_PRESETS`` keep the ``LoadReport`` wire form small —
a histogram serializes as ``(preset-or-bounds, nonzero (idx, count)
pairs, sum, count, min, max)`` rather than the full bucket vector.

``MetricsRegistry`` is the exposition layer: named counters / gauges /
histograms rendered either as Prometheus-style text (cumulative
``_bucket{le=...}`` lines) or a JSON snapshot, behind
``launch/serve.py --metrics-out``.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "BUCKET_PRESETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "latency_histogram",
    "residual_histogram",
]


def _log_bounds(lo: float, hi: float, per_decade: int) -> Tuple[float, ...]:
    """Increasing log-spaced bucket upper bounds covering [lo, hi]."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


def _linear_bounds(lo: float, hi: float, n: int) -> Tuple[float, ...]:
    """n+1 evenly spaced bucket upper bounds from lo to hi inclusive."""
    step = (hi - lo) / n
    return tuple(lo + i * step for i in range(n + 1))


# Latencies (TTFT / TPOT / JCT / tick wall): virtual-time benches emit
# values from sub-millisecond ticks up to multi-thousand-second JCTs on
# slow virtual clocks; 8 buckets per decade bounds quantile error at
# ~33% relative (one bucket width), plenty for p50/p99 gating.
LATENCY_BOUNDS = _log_bounds(1e-5, 1e4, per_decade=8)

# Interference-predictor residuals: observe_latency clamps actuals to
# [0.25p, 4p], so residuals -(a-p)/p live in [-3, 0.75]; a linear grid
# over [-4, 1] covers them with uniform resolution.
RESIDUAL_BOUNDS = _linear_bounds(-4.0, 1.0, 100)

# Wire-form presets: histograms built from a preset serialize by NAME,
# not by shipping ~80 bound floats per LoadReport (load_report() runs on
# every routing dispatch).
BUCKET_PRESETS: Dict[str, Tuple[float, ...]] = {
    "latency_s": LATENCY_BOUNDS,
    "residual": RESIDUAL_BOUNDS,
}


class Histogram:
    """Fixed-bucket histogram with exact sum/count/min/max side state.

    ``bounds`` are increasing bucket *upper* bounds; an implicit +inf
    overflow bucket catches everything above ``bounds[-1]``, so
    ``counts`` has ``len(bounds) + 1`` entries.  Bucket i holds values
    ``v <= bounds[i]`` (first bucket also absorbs anything below the
    range).  ``sum`` accumulates raw values, so ``mean`` is exact even
    though individual samples are binned.
    """

    __slots__ = ("bounds", "counts", "sum", "count", "vmin", "vmax", "preset")

    def __init__(self, bounds: Sequence[float], preset: Optional[str] = None):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or any(b <= a for a, b in zip(bounds, bounds[1:])):
            raise ValueError("histogram bounds must be non-empty and increasing")
        self.bounds = bounds
        self.counts: List[int] = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.preset = preset

    # -- recording ---------------------------------------------------------

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    # list-compat shims: ServeMetrics call sites did latencies.append(x)
    append = observe

    def extend(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.observe(v)

    def bucket_index(self, v: float) -> int:
        return bisect_left(self.bounds, float(v))

    # -- reading -----------------------------------------------------------

    def __len__(self) -> int:  # `if not hist:` == empty, like the old lists
        return self.count

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1]; linear interpolation inside the target bucket.

        Matches ``np.percentile``'s rank convention (h = q*(n-1)) at the
        bucket level, so the result is within one bucket width of the
        exact sample quantile.
        """
        if self.count == 0:
            return 0.0
        if q <= 0.0 or self.count == 1:
            return self.vmin
        if q >= 1.0:
            return self.vmax
        rank = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c > rank:
                lo = self.bounds[i - 1] if i > 0 else self.vmin
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                if hi <= lo:
                    return lo
                return lo + (hi - lo) * min(1.0, (rank - cum) / c)
            cum += c
        return self.vmax  # unreachable unless counts were mutated externally

    def percentile(self, q: float) -> float:
        """q in [0, 100] — np.percentile-shaped front door."""
        return self.quantile(q / 100.0)

    # -- merging -----------------------------------------------------------

    def merge(self, other: "Histogram") -> "Histogram":
        """Exact in-place merge; equals histogramming the concatenation."""
        if self.bounds != other.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds "
                f"({len(self.bounds)} vs {len(other.bounds)} buckets; "
                f"presets {self.preset!r} vs {other.preset!r})")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def delta(self, prev: "Histogram") -> "Histogram":
        """Windowed view: the histogram of samples observed since ``prev``
        (a past snapshot of this same series). Counts and sum subtract
        exactly; min/max are NOT recoverable from cumulative state, so
        the window's vmin/vmax are approximated by its populated bucket
        bounds — quantiles stay within one bucket width, same guarantee
        as everywhere else. Used by the overload detector to get a recent
        p99 out of cumulative LoadReport histograms."""
        if self.bounds != prev.bounds:
            raise ValueError("delta requires identical bucket bounds")
        h = Histogram(self.bounds, preset=self.preset)
        for i, (a, b) in enumerate(zip(self.counts, prev.counts)):
            if a < b:
                raise ValueError(
                    f"bucket {i} went backwards ({b} -> {a}); delta needs "
                    f"snapshots of one monotonically growing histogram")
            h.counts[i] = a - b
        h.sum = self.sum - prev.sum
        h.count = self.count - prev.count
        if h.count:
            nz = [i for i, c in enumerate(h.counts) if c]
            lo = self.bounds[nz[0] - 1] if nz[0] > 0 else self.vmin
            hi = (self.bounds[nz[-1]] if nz[-1] < len(self.bounds)
                  else self.vmax)
            h.vmin, h.vmax = min(lo, hi), max(lo, hi)
        return h

    def copy(self) -> "Histogram":
        h = Histogram(self.bounds, preset=self.preset)
        h.counts = list(self.counts)
        h.sum, h.count = self.sum, self.count
        h.vmin, h.vmax = self.vmin, self.vmax
        return h

    # -- wire form ---------------------------------------------------------

    def to_wire(self) -> tuple:
        """Sparse, hashable, JSON-round-trippable tuple form.

        ``(preset-name-or-bounds, ((bucket, count), ...), sum, count,
        min, max)`` — empty histograms ship min/max as 0.0 so plain JSON
        readers never see Infinity.
        """
        key = self.preset if self.preset is not None else self.bounds
        nz = tuple((i, c) for i, c in enumerate(self.counts) if c)
        vmin = self.vmin if self.count else 0.0
        vmax = self.vmax if self.count else 0.0
        return (key, nz, self.sum, self.count, vmin, vmax)

    @classmethod
    def from_wire(cls, w: Sequence) -> "Histogram":
        key, nz, s, n, vmin, vmax = w
        if isinstance(key, str):
            h = cls(BUCKET_PRESETS[key], preset=key)
        else:
            h = cls(key)
        for i, c in nz:
            h.counts[int(i)] = int(c)
        h.sum, h.count = float(s), int(n)
        if h.count:
            h.vmin, h.vmax = float(vmin), float(vmax)
        return h

    def __eq__(self, other) -> bool:
        if not isinstance(other, Histogram):
            return NotImplemented
        return (self.bounds == other.bounds and self.counts == other.counts
                and self.sum == other.sum and self.count == other.count
                and self.vmin == other.vmin and self.vmax == other.vmax)

    def __repr__(self) -> str:
        return (f"Histogram(count={self.count}, mean={self.mean:.4g}, "
                f"buckets={len(self.counts)}, preset={self.preset!r})")


def latency_histogram() -> Histogram:
    """The shared latency preset (TTFT / TPOT / JCT / tick wall)."""
    return Histogram(LATENCY_BOUNDS, preset="latency_s")


def residual_histogram() -> Histogram:
    """Interference-predictor residual preset."""
    return Histogram(RESIDUAL_BOUNDS, preset="residual")


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


class Gauge:
    """Point-in-time value."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v


class MetricsRegistry:
    """Named metrics with Prometheus-style text + JSON exposition.

    Registration order is preserved in both outputs so expositions diff
    cleanly across runs.
    """

    def __init__(self):
        self._metrics: Dict[str, tuple] = {}  # name -> (kind, help, obj)

    def _add(self, name: str, kind: str, obj, help_: str):
        if name in self._metrics:
            existing = self._metrics[name]
            if existing[0] != kind:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{existing[0]}, not {kind}")
            return existing[2]
        self._metrics[name] = (kind, help_, obj)
        return obj

    def counter(self, name: str, help: str = "") -> Counter:
        return self._add(name, "counter", Counter(), help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._add(name, "gauge", Gauge(), help)

    def histogram(self, name: str, bounds: Sequence[float] = LATENCY_BOUNDS,
                  help: str = "", preset: Optional[str] = "latency_s",
                  ) -> Histogram:
        return self._add(name, "histogram", Histogram(bounds, preset=preset),
                         help)

    def register(self, name: str, obj, help: str = ""):
        """Adopt an externally owned metric (e.g. a ServeMetrics histogram)."""
        kind = ("histogram" if isinstance(obj, Histogram)
                else "gauge" if isinstance(obj, Gauge) else "counter")
        return self._add(name, kind, obj, help)

    def set_counter(self, name: str, value: float, help: str = "") -> None:
        self.counter(name, help).value = value

    def set_gauge(self, name: str, value: float, help: str = "") -> None:
        self.gauge(name, help).set(value)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str, default=None):
        entry = self._metrics.get(name)
        return entry[2] if entry is not None else default

    # -- exposition --------------------------------------------------------

    def exposition(self) -> str:
        """Prometheus text format (cumulative le= histogram buckets)."""
        lines: List[str] = []
        for name, (kind, help_, obj) in self._metrics.items():
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            if kind in ("counter", "gauge"):
                lines.append(f"{name} {_fmt(obj.value)}")
                continue
            cum = 0
            for i, c in enumerate(obj.counts):
                cum += c
                le = (_fmt(obj.bounds[i]) if i < len(obj.bounds) else "+Inf")
                lines.append(f'{name}_bucket{{le="{le}"}} {cum}')
            lines.append(f"{name}_sum {_fmt(obj.sum)}")
            lines.append(f"{name}_count {obj.count}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-safe dict: scalars verbatim, histograms in wire form plus
        convenience quantiles."""
        out = {}
        for name, (kind, _help, obj) in self._metrics.items():
            if kind in ("counter", "gauge"):
                out[name] = obj.value
            else:
                out[name] = {
                    "wire": _listify(obj.to_wire()),
                    "count": obj.count,
                    "mean": obj.mean,
                    "p50": obj.percentile(50),
                    "p90": obj.percentile(90),
                    "p99": obj.percentile(99),
                }
        return out


def _fmt(v: float) -> str:
    """Render ints without a trailing .0 (Prometheus-conventional)."""
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _listify(x):
    if isinstance(x, tuple):
        return [_listify(v) for v in x]
    return x
