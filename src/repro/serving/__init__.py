from repro.serving.engine import ServingEngine, generate, prefill_step, serve_step
from repro.serving.request import Request, ServeMetrics

__all__ = [
    "ServingEngine",
    "generate",
    "prefill_step",
    "serve_step",
    "Request",
    "ServeMetrics",
]
