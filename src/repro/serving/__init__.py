from repro.serving.cluster import ClusterFrontend, EngineInstance
from repro.serving.engine import (
    LoadReport,
    ServingEngine,
    bucketed_prefill_step,
    cache_insert,
    decode_scan_step,
    decode_tick,
    generate,
    page_table_append,
    paged_prefill_step,
    pages_insert,
    prefill_chunk_step,
    prefill_step,
    prompt_bucket,
    serve_step,
    slot_release,
)
from repro.serving.paging import OutOfPagesError, PageAllocator
from repro.serving.request import Request, ServeMetrics

__all__ = [
    "ClusterFrontend",
    "EngineInstance",
    "LoadReport",
    "OutOfPagesError",
    "PageAllocator",
    "ServingEngine",
    "bucketed_prefill_step",
    "cache_insert",
    "decode_scan_step",
    "decode_tick",
    "generate",
    "page_table_append",
    "paged_prefill_step",
    "pages_insert",
    "prefill_chunk_step",
    "prefill_step",
    "prompt_bucket",
    "serve_step",
    "slot_release",
    "Request",
    "ServeMetrics",
]
