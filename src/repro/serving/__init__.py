from repro.serving.engine import (
    ServingEngine,
    bucketed_prefill_step,
    cache_insert,
    decode_scan_step,
    decode_tick,
    generate,
    prefill_chunk_step,
    prefill_step,
    prompt_bucket,
    serve_step,
)
from repro.serving.request import Request, ServeMetrics

__all__ = [
    "ServingEngine",
    "bucketed_prefill_step",
    "cache_insert",
    "decode_scan_step",
    "decode_tick",
    "generate",
    "prefill_chunk_step",
    "prefill_step",
    "prompt_bucket",
    "serve_step",
    "Request",
    "ServeMetrics",
]
