"""Fig. 3 reproduction: multi-tenant co-location.

(a) co-running two models raises throughput 25%+ while each model's
    latency degrades only 5-10%;
(b) across ~250 co-location combinations, ~90% of pairs show < 17%
    latency degradation.

Demand vectors come from the cost model over the assigned archs at small-
query serving operating points (the survey's premise: a lone query cannot
saturate the accelerator — ResNet's 4 GFLOPs vs 130 TFLOPS — so each
stream carries a sub-1.0 occupancy; see costmodel.stream_occupancy).
Fig. 3a is measured arrival-limited, as in the survey: the offered load
modestly exceeds single-tenant capacity and co-location absorbs it.
"""
from __future__ import annotations

import copy
import itertools

import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.costmodel import estimate_decode, estimate_prefill, stream_occupancy
from repro.core.misd import Job, pairwise_degradation
from repro.core.sisd import run_multi_tenant, run_single_tenant

N_CHIPS = 8


def tenant_profiles():
    """(name, demand, service_s) small-query operating points."""
    out = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.param_count() > 100e9:
            continue  # giants need SIMD scale-out, not an 8-chip meshlet
        points = []
        if cfg.supports_decode:
            points += [("decode-b4", estimate_decode(cfg, 4, 4096,
                                                     n_chips=N_CHIPS), 4),
                       ("decode-b8", estimate_decode(cfg, 8, 8192,
                                                     n_chips=N_CHIPS), 8),
                       ("decode-b16", estimate_decode(cfg, 16, 8192,
                                                      n_chips=N_CHIPS), 16)]
        points += [("prefill-b1", estimate_prefill(cfg, 1, 2048,
                                                   n_chips=N_CHIPS), 1)]
        for kind, est, b in points:
            occ = stream_occupancy(b)
            out.append((f"{arch}:{kind}", est.demand_at(occ), est.latency_s))
    return out


def run(report):
    tenants = tenant_profiles()

    # --- (b): pairwise degradation across all combinations -----------------
    degs = []
    for (n1, d1, s1), (n2, d2, s2) in itertools.product(tenants, tenants):
        degs.append(pairwise_degradation(d1, d2))
    degs = np.asarray(degs)
    frac_under_17 = float((degs < 1.17).mean())
    p90 = float(np.percentile(degs, 90))
    report("fig3b_pairs", len(degs), "co-location pairs evaluated")
    report("fig3b_frac_under_17pct", round(frac_under_17, 3),
           "survey: ~0.9 of 250 combos < 17% degradation")
    report("fig3b_p90_degradation", round(p90, 3),
           "90th-percentile latency inflation")

    # --- (a): arrival-limited throughput for a representative mixed pair ---
    # pick a compute-leaning and a memory-leaning tenant (GoogLeNet+ResNet
    # analogue), offer 1.5x single-tenant capacity
    comp = max(tenants, key=lambda t: t[1][0] - t[1][1])
    memb = max(tenants, key=lambda t: t[1][1] - t[1][0])
    (n1, d1, s1), (n2, d2, s2) = comp, memb
    mean_s = (s1 + s2) / 2
    gap = mean_s / 1.5  # offered load = 1.5x serial capacity
    jobs = []
    for i in range(300):
        name, dem, svc = (n1, d1, s1) if i % 2 else (n2, d2, s2)
        jobs.append(Job(i, name, dem, svc, arrival=i * gap))
    single = run_single_tenant(copy.deepcopy(jobs))
    multi = run_multi_tenant(copy.deepcopy(jobs), max_tenants=2)
    tput_gain = multi.qps / single.qps - 1.0
    lat_deg = multi.mean_slowdown() - 1.0
    report("fig3a_pair", f"{n1}|{n2}", "compute-bound + memory-bound pair")
    report("fig3a_throughput_gain", round(tput_gain, 3),
           "survey: >= +25% QPS from co-location")
    report("fig3a_latency_degradation", round(lat_deg, 3),
           "survey: 5-10% per-model latency cost")
    return {
        "frac_under_17": frac_under_17,
        "tput_gain": tput_gain,
        "lat_deg": lat_deg,
    }
