"""Span-diff triage gate: catch *phase-level* serving regressions in CI
by diffing per-span-kind rollups against a committed baseline.

    PYTHONPATH=src python benchmarks/span_diff.py            # gate
    PYTHONPATH=src python benchmarks/span_diff.py --update   # re-baseline

A fixed, seeded workload (greedy + seeded-sampled requests, preemption
enabled, a 2-replica cluster frontend with tracing on) runs entirely on
the VIRTUAL serving clock, so every span timestamp — and therefore every
per-kind (count, seconds) rollup in ``Tracer.span_totals`` — is exactly
reproducible: the only way the numbers move is a code change in how the
serving stack spends its phases. The gate diffs each kind against
``SPAN_BASELINE.json`` and fails naming the regressed phase:

    span-diff: REGRESSED phase 'prefill': seconds +41.3% (2.10 -> 2.97)

which turns "the cluster bench got slower" into "prefill time grew" at
triage time, before anyone opens a profiler. Kinds appearing or
vanishing also fail (a new phase is a behavior change someone must
acknowledge via --update; a vanished one usually means stamps were
dropped). Tolerance is deliberately loose (25% default) — the gate
exists to catch step-change regressions, not noise; deliberate changes
re-baseline with --update in the same PR.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    ClusterFrontend,
    EngineConfig,
    Request,
    SamplingParams,
    ServingEngine,
)

BASELINE = os.path.join(os.path.dirname(__file__), "SPAN_BASELINE.json")


def workload(vocab, *, n=20, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab,
                                int(rng.integers(8, 25))).astype(np.int32),
            max_new_tokens=int(rng.integers(6, 13)),
            arrival_time=float(i) * 1.5,
            ttft_slo_s=20.0,
            sampling=(SamplingParams(temperature=0.7, top_k=20,
                                     seed=9000 + i)
                      if i % 3 == 0 else SamplingParams())))
    return reqs


def collect_span_totals(*, arch="granite-8b", seed=0):
    """Run the fixed traced workload; return {kind: [count, seconds]}
    summed across replicas. Virtual clock throughout — deterministic."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    engines = [ServingEngine(cfg, params, EngineConfig(
        slots=2, window=96, max_seq=160, sync_every=4, tracing=True,
        preemption=True))
        for _ in range(2)]
    fe = ClusterFrontend(engines, policy="predicted", seed=seed,
                         tracing=True)
    reqs = workload(cfg.vocab_size, seed=seed)
    pending = sorted(reqs, key=lambda r: (r.arrival_time, r.rid))
    i, now, resolved = 0, 0.0, 0
    while resolved < len(reqs):
        while i < len(pending) and pending[i].arrival_time <= now:
            fe.submit(pending[i], now)
            i += 1
        resolved += len(fe.step(now))
        now += 1.0
        if now > 2000:
            raise RuntimeError("span workload did not converge")
    totals = {}
    for eng in fe.engines:
        for kind, (c, s) in eng.tracer.span_totals.items():
            cur = totals.setdefault(kind, [0, 0.0])
            cur[0] += c
            cur[1] += round(s, 9)
    return {k: [c, round(s, 6)] for k, (c, s) in sorted(totals.items())}


def diff(baseline, current, *, tolerance):
    """Regression lines (empty = green), each naming the phase."""
    problems = []
    for kind in sorted(set(baseline) | set(current)):
        if kind not in current:
            problems.append(f"phase '{kind}' VANISHED (baseline "
                            f"{baseline[kind][0]} spans) — stamps dropped?")
            continue
        if kind not in baseline:
            c, s = current[kind]
            problems.append(f"NEW phase '{kind}' ({c} spans, {s:.4g}s) — "
                            f"acknowledge with --update")
            continue
        (c0, s0), (c, s) = baseline[kind], current[kind]
        if abs(c - c0) / max(1.0, c0) > tolerance:
            problems.append(
                f"REGRESSED phase '{kind}': count "
                f"{(c - c0) / max(1.0, c0):+.1%} ({c0} -> {c})")
        if abs(s - s0) > 1e-6 and abs(s - s0) / max(abs(s0), 1e-9) > tolerance:
            problems.append(
                f"REGRESSED phase '{kind}': seconds "
                f"{(s - s0) / max(abs(s0), 1e-9):+.1%} "
                f"({s0:.4g} -> {s:.4g})")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="max relative drift per phase (count and seconds)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baseline from this run")
    args = ap.parse_args()

    current = collect_span_totals(arch=args.arch, seed=args.seed)
    if args.update:
        with open(args.baseline, "w") as f:
            json.dump({"arch": args.arch, "seed": args.seed,
                       "span_totals": current}, f, indent=2)
            f.write("\n")
        print(f"span-diff: baseline updated ({args.baseline}): "
              f"{len(current)} phases")
        return 0
    if not os.path.exists(args.baseline):
        print(f"span-diff: no baseline at {args.baseline}; "
              f"run with --update to create it")
        return 1
    with open(args.baseline) as f:
        base = json.load(f)["span_totals"]
    problems = diff(base, current, tolerance=args.tolerance)
    for p in problems:
        print(f"span-diff: {p}")
    if problems:
        print(f"span-diff: FAILED ({len(problems)} phase regression(s); "
              f"deliberate changes: --update)")
        return 1
    print(f"span-diff: green — {len(current)} phases within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
