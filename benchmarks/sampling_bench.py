"""Stochastic-decode benchmark: sampled vs greedy decode tok/s at equal
batch, plus the seeded-replay determinism gate.

    PYTHONPATH=src python benchmarks/sampling_bench.py [--arch granite-8b]
        [--slot-counts 4,8] [--ticks 128] [--out BENCH_sampling.json]
    PYTHONPATH=src python benchmarks/sampling_bench.py --smoke   # CI gate

The A/B interleaves greedy and sampled measurement rounds on the same
engines and reports the median of per-round back-to-back ratios (host
noise on the shared container is time-correlated; pairing cancels it),
so the headline isolates the cost of the in-trace sampling stage —
temperature scale, radix-select top-k/top-p masks, inverse-CDF draw:
ISSUE 5 accepts at sampled >= 0.95x greedy at equal batch. That stage is
a FIXED ~0.2 ms of vector work per tick (independent of model size),
so the reduced 2-layer bench model shows it worst-case: the default
regime (slot counts 4 and 8, 512-token KV window) makes the decode tick
just large enough to represent a real serving step, while a batch-2,
256-context tick on this tiny model (~2.5 ms) would overstate the
relative cost ~4x vs any real model. Host noise is
mitigated and recorded through ``bench_noise`` (threads pinned before
the first jax import; loadavg in the JSON).

``--smoke`` is the CI determinism gate: it replays a seeded sampled
workload on two fresh engines with DIFFERENT submission orders (so slot
assignments differ), once more on a reused engine after ``reset()``
(engine-restart analogue with a warm jit cache), and fails on any stream
divergence, on decode-trace growth vs greedy (the mixed batch must share
the greedy batch's single tick + single fused-window trace), or on
prefill-trace growth per bucket.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine

SAMPLED = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=0)


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _prime(eng, slots, prompt_len, budget, *, sampled, warmup=2, seed=0):
    """(Re)admit ``slots`` fresh streams (greedy or seeded-sampled) and
    warm the jit cache; admission stays outside the timed window."""
    eng.reset()
    for i in range(slots):
        sp = (SamplingParams(temperature=SAMPLED.temperature,
                             top_k=SAMPLED.top_k, top_p=SAMPLED.top_p,
                             seed=seed * 1000 + i)
              if sampled else SamplingParams())
        req = Request(rid=i, prompt=_prompt(prompt_len, seed=seed * 100 + i),
                      max_new_tokens=budget, sampling=sp)
        assert eng.try_admit(req, now=0.0)
    for _ in range(warmup):
        eng.step(0.0)
    jax.block_until_ready(eng.cache)


def _measure(eng, slots, ticks):
    done = 0
    t0 = time.perf_counter()
    while done < ticks:
        c0 = eng.metrics.decode_ticks
        eng.step(0.0)
        n = eng.metrics.decode_ticks - c0
        if n == 0 and not any(eng.decoding):
            break
        done += n
    eng.drain(0.0)
    jax.block_until_ready(eng.cache)
    return done * slots / (time.perf_counter() - t0)


def _ab_rounds(eng, slots, ticks, rounds, prompt_len, budget):
    """Greedy/sampled rounds interleaved on the SAME engine (A/B/A/B...);
    returns (greedy_median_tps, sampled_median_tps, per_round_ratios).
    Host noise on the shared container is strongly time-correlated, so
    the headline estimator is built from PER-ROUND ratios (each sampled
    round against its back-to-back greedy partner), not a ratio of
    medians taken seconds apart."""
    g_tps, s_tps = [], []
    for r in range(rounds):
        _prime(eng, slots, prompt_len, budget, sampled=False, seed=r)
        g_tps.append(_measure(eng, slots, ticks))
        _prime(eng, slots, prompt_len, budget, sampled=True, seed=r)
        s_tps.append(_measure(eng, slots, ticks))
    ratios = [s / g for g, s in zip(g_tps, s_tps)]
    return (float(np.median(g_tps)), float(np.median(s_tps)), ratios)


# ---------------------------------------------------------------------------
# determinism replay (shared by the full bench and the CI smoke)
# ---------------------------------------------------------------------------


WORKLOAD_PLENS = (9, 14, 21, 33)  # 3 distinct power-of-two buckets


def _workload(n, *, plens=WORKLOAD_PLENS):
    """Seeded mixed greedy/sampled workload; request identity (prompt,
    params, seed) depends only on rid."""
    reqs = []
    for rid in range(n):
        sp = (SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                             seed=500 + rid)
              if rid % 2 else SamplingParams())
        reqs.append(Request(rid=rid,
                            prompt=_prompt(plens[rid % len(plens)], seed=rid),
                            max_new_tokens=8, sampling=sp))
    return reqs


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r, 0.0)
    t = 0.0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t)
    return {r.rid: r.output for r in reqs}


def determinism_check(cfg, params, *, n_requests=6, slots=3):
    """Replay the seeded workload under different slot orders and an
    engine restart; returns (ok, detail dict). Also enforces the trace
    budget: the mixed batch must cost no more decode traces than greedy
    serving (<= 2: single tick + fused scan)."""
    mk = lambda: ServingEngine(cfg, params, EngineConfig(  # noqa: E731
        slots=slots, window=128, sync_every=4))
    eng = mk()
    a = _serve(eng, _workload(n_requests))
    traces_mixed = eng.decode_traces
    prefill_a = eng.prefill_traces
    # different submission order -> different slot assignment
    reqs = _workload(n_requests)
    b = _serve(eng := mk(), list(reversed(reqs)))
    # reused engine after reset (restart analogue, warm jit cache)
    eng.reset()
    c = _serve(eng, _workload(n_requests))
    traces_after = eng.decode_traces
    # greedy-only engine: the trace baseline
    geng = mk()
    _serve(geng, [Request(rid=r.rid, prompt=r.prompt, max_new_tokens=8)
                  for r in _workload(n_requests)])
    from repro.serving.engine import prompt_bucket

    buckets = len({prompt_bucket(p, min_bucket=16) for p in WORKLOAD_PLENS})
    detail = {
        "streams_slot_order_identical": a == b,
        "streams_restart_identical": a == c,
        "decode_traces_mixed": traces_mixed,
        "decode_traces_greedy": geng.decode_traces,
        "prefill_traces": prefill_a,
        "prefill_trace_budget": buckets,
        "trace_growth_vs_greedy": traces_mixed - geng.decode_traces,
    }
    ok = (a == b and a == c
          and traces_mixed <= max(2, geng.decode_traces)
          and traces_after <= traces_mixed
          and prefill_a <= buckets)
    return ok, detail


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def run(report, *, arch="granite-8b", slot_counts=(4, 8), ticks=128,
        rounds=9, sync_every=16, out=""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    # window 512: a serving-representative KV depth — the sampling stage
    # is a fixed ~0.2 ms of vector work per tick, and the tiny bench
    # model needs a realistic attention span for the tick it perturbs to
    # be representative of any real decode step
    window, prompt_len = 512, 32
    budget = window - prompt_len
    assert budget >= (2 + 1) * sync_every + ticks, (window, ticks)
    results = {"arch": arch, "window": window, "ticks": ticks,
               "rounds": rounds, "sync_every": sync_every,
               "slot_counts": list(slot_counts),
               "sampling": {"temperature": SAMPLED.temperature,
                            "top_k": SAMPLED.top_k, "top_p": SAMPLED.top_p},
               **noise_report(),  # loadavg + thread pinning when measured
               "greedy": {}, "sampled": {}, "ratio": {}}
    all_ratios = []
    for slots in slot_counts:
        eng = ServingEngine(cfg, params, EngineConfig(
            slots=slots, window=window, sync_every=sync_every))
        g, s, ratios = _ab_rounds(eng, slots, ticks, rounds, prompt_len,
                                  budget)
        ratio = float(np.median(ratios))
        all_ratios.extend(ratios)
        results["greedy"][slots] = {"decode_tps": g}
        results["sampled"][slots] = {"decode_tps": s}
        results["ratio"][slots] = ratio
        results.setdefault("round_ratios", {})[slots] = [
            round(x, 4) for x in ratios]
        report(f"sampling_decode_tps_b{slots}_greedy", round(g, 1), "")
        report(f"sampling_decode_tps_b{slots}_sampled", round(s, 1),
               f"ratio {ratio:.3f} vs greedy (median of per-round "
               f"back-to-back pairs)")
    worst = min(results["ratio"].values())
    # headline: pooled median over every equal-batch back-to-back pair —
    # per-slot medians over a handful of rounds still wobble +-0.05 on
    # the shared box, the pooled estimator does not
    pooled = float(np.median(all_ratios))
    results["ratio_worst"] = worst
    results["ratio_pooled_median"] = pooled
    results["ratio_geomean"] = float(
        np.exp(np.mean(np.log(list(results["ratio"].values())))))
    report("sampling_decode_ratio_pooled", round(pooled, 3),
           f"median over {len(all_ratios)} equal-batch greedy/sampled "
           f"pairs (target >= 0.95)")

    ok, detail = determinism_check(cfg, params)
    results["determinism"] = detail
    results["determinism_ok"] = ok
    report("sampling_determinism", "ok" if ok else "FAIL",
           f"slot-order + restart replay, trace growth "
           f"{detail['trace_growth_vs_greedy']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("sampling_bench_json", out, "full results")
    return results


def smoke(*, arch="granite-8b") -> int:
    """CI determinism gate (make bench-sampling-smoke): seeded sampled
    workload replayed across slot orders and an engine restart, plus the
    compile-count budget with mixed greedy/sampled batches. Perf is NOT
    gated here (CI boxes are noisy); the tracked ratio lives in
    BENCH_sampling.json from the full run."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    ok, detail = determinism_check(cfg, params)
    for k, v in detail.items():
        print(f"smoke:{k}: {v}")
    if not ok:
        print("smoke: FAILED (stream divergence or decode-trace growth)")
        return 1
    print("smoke: sampled streams bit-identical across slot orders and "
          "restart; no trace growth vs greedy")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--slot-counts", default="4,8")
    ap.add_argument("--ticks", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=9)
    ap.add_argument("--sync-every", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: determinism replay + trace budget")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_sampling.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch,
              slot_counts=tuple(int(x) for x in args.slot_counts.split(",")),
              ticks=args.ticks, rounds=args.rounds,
              sync_every=args.sync_every, out=args.out)
    print(f"# sampled/greedy decode ratio: pooled median "
          f"{res['ratio_pooled_median']:.3f} (target >= 0.95), per-slot "
          f"medians {res['ratio']}; determinism "
          f"{'ok' if res['determinism_ok'] else 'FAIL'}")


if __name__ == "__main__":
    main()
