"""Fig. 4 reproduction: serving throughput and power efficiency, CPU vs
accelerators. The survey's claim: accelerator serving reaches up to ~100x
CPU throughput at ~3x the power -> ~30x average power-per-query reduction.

We evaluate batched decode throughput (queries/s at the adaptive batch
size) for each assigned arch on each chip's roofline constants.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.costmodel import estimate_decode
from repro.core.hardware import CHIPS, TPU_V5E, XEON_4116
from repro.core.misd.batching import adaptive_batch_size

CONTEXT = 2048
SLA_S = 0.2


def throughput_qps(cfg, chip, *, n_chips: int = 1) -> float:
    best = 0.0
    b = 1
    while b <= 512:
        est = estimate_decode(cfg, b, CONTEXT, chip=chip, n_chips=n_chips)
        if est.latency_s <= SLA_S:
            best = max(best, b / est.latency_s)
        b *= 2
    return best


def run(report):
    from repro.core.hardware import RTX_2080TI

    rows = {"tpu": [], "rtx": []}
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if not cfg.supports_decode or cfg.param_count() > 40e9:
            continue
        q_cpu = throughput_qps(cfg, XEON_4116)
        if q_cpu <= 0:
            continue
        for key, chip in (("tpu", TPU_V5E), ("rtx", RTX_2080TI)):
            q = throughput_qps(cfg, chip)
            r_tput = q / q_cpu
            r_power = (q / chip.tdp_watts) / (q_cpu / XEON_4116.tdp_watts)
            rows[key].append((r_tput, r_power))
            if key == "tpu":
                report(f"fig4_tput_ratio_{arch}", round(r_tput, 1),
                       f"qps tpu={q:.1f} cpu={q_cpu:.2f}")
    # the survey's exact pairing: RTX2080Ti (250W) vs Xeon-4116 (85W)
    rtx_t = [t for t, _ in rows["rtx"]]
    rtx_p = [p for _, p in rows["rtx"]]
    report("fig4_rtx_max_tput_ratio", round(max(rtx_t), 1),
           "survey: RTX2080Ti up to ~100x Xeon throughput")
    report("fig4_rtx_mean_power_reduction", round(float(np.mean(rtx_p)), 1),
           "survey: ~30x average power-per-query reduction")
    tpu_t = [t for t, _ in rows["tpu"]]
    tpu_p = [p for _, p in rows["tpu"]]
    report("fig4_tpu_max_tput_ratio", round(max(tpu_t), 1),
           "our target chip (v5e) vs Xeon")
    report("fig4_tpu_mean_power_reduction", round(float(np.mean(tpu_p)), 1),
           "v5e perf/W advantage")
    return {"max_tput": max(rtx_t), "mean_power": float(np.mean(rtx_p))}
