"""Generate the data-driven sections of EXPERIMENTS.md (§Dry-run table,
§Roofline table) from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report > results/report.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import analyze, load_records

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def dryrun_table() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL | — | — | — |")
            continue
        mem = r.get("memory_analysis") or {}
        arg_gb = r["arg_bytes_per_device"] / 2 ** 30
        coll = r["collective_total_per_device"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"({r['compile_s']:.0f}s) | {arg_gb:.2f} | "
            f"{r['flops']:.2e} | {coll:.2e} |")
    hdr = ("| arch | shape | mesh | compile | args GiB/dev | "
           "HLO FLOPs/dev | collective B/dev |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table() -> str:
    from benchmarks.roofline import markdown_table

    rows = [analyze(r) for r in load_records("single")]
    return markdown_table(rows)


def main():
    print("## Generated: §Dry-run table\n")
    print(dryrun_table())
    print("\n## Generated: §Roofline table (single pod, 256 chips)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
