"""Table 1 reproduction: the MISD scheduler family compared on one mixed
workload — the survey's per-row claims checked against our own stack:

  [52] op-level scheduling  -> (query-level here) SJF reduces makespan
  [28] interference-aware   -> reduced latency (slowdown)
  [50] online scheduling    -> reduced latency vs naive
  [5]  PREMA                -> reduced high-priority JCT, SLA kept
"""
from __future__ import annotations

import copy

import numpy as np

from repro.configs import get_config
from repro.core.costmodel import estimate_decode, estimate_prefill
from repro.core.misd import (
    SCHEDULERS,
    Device,
    Job,
    MISDSimulator,
)

N_CHIPS = 8


def workload(n=240, seed=0):
    rng = np.random.default_rng(seed)
    profiles = []
    for arch in ("granite-8b", "chatglm3-6b", "phi3-medium-14b",
                 "mamba2-1.3b", "qwen2-vl-7b"):
        cfg = get_config(arch)
        profiles.append((f"{arch}:dec",
                         estimate_decode(cfg, 16, 4096, n_chips=N_CHIPS)))
        profiles.append((f"{arch}:pre",
                         estimate_prefill(cfg, 1, 2048, n_chips=N_CHIPS)))
    jobs = []
    t = 0.0
    for i in range(n):
        name, est = profiles[rng.integers(len(profiles))]
        t += float(rng.exponential(est.latency_s / 2.2))
        jobs.append(Job(
            i, name, est.demand, est.latency_s, arrival=t,
            priority=8 if rng.random() < 0.15 else 0,
            sla_s=est.latency_s * 6.0,
        ))
    return jobs


def run(report):
    jobs = workload()
    rows = {}
    for name, sched_cls in SCHEDULERS.items():
        devices = [Device("meshlet0", max_tenants=4),
                   Device("meshlet1", max_tenants=4)]
        res = MISDSimulator(devices, sched_cls()).run(copy.deepcopy(jobs))
        hi = [j for j in res.completed if j.priority > 0]
        hi_jct = float(np.mean([j.finish - j.arrival for j in hi])) if hi else 0
        rows[name] = {
            "qps": res.qps,
            "mean_jct": res.mean_jct(),
            "p99": res.p99_latency(),
            "sla": res.sla_attainment(),
            "hi_jct": hi_jct,
            "slowdown": res.mean_slowdown(),
        }
        report(f"table1_{name}_qps", round(res.qps, 1),
               f"jct={res.mean_jct()*1e3:.1f}ms p99={res.p99_latency()*1e3:.1f}ms "
               f"sla={res.sla_attainment():.2f} hi_jct={hi_jct*1e3:.1f}ms")
    # survey-claim checks
    report("table1_prema_hi_jct_gain",
           round(rows["fifo"]["hi_jct"] / max(rows["prema"]["hi_jct"], 1e-9), 2),
           "PREMA [5]: high-priority JCT reduction vs FIFO (x)")
    report("table1_ia_slowdown_vs_fifo",
           round(rows["fifo"]["slowdown"] - rows["interference-aware"]["slowdown"], 3),
           "[28]: interference-aware slowdown reduction")
    return rows
