"""Serving-engine benchmark: decode tokens/s, TTFT, and per-token latency
percentiles at several slot counts, comparing the zero-copy engine against
a faithful port of the pre-refactor hot path (per-tick host syncs, no
donation, eager full-cache-copy slot insert, per-prompt-length retrace).

    PYTHONPATH=src python benchmarks/serving_bench.py [--arch granite-8b]
        [--slot-counts 2,4,8] [--ticks 192] [--out BENCH_serving.json]

Both variants run in the same process on the same reduced model, so the
speedup column isolates the engine changes (donation + deferred sync +
jit'd scatter), not machine noise. Results land in ``BENCH_serving.json``
to start the serving perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from functools import partial
from typing import List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_cache, init_params
from repro.serving import EngineConfig, Request, ServingEngine
from repro.serving.engine import cache_insert, prefill_step, serve_step


# ---------------------------------------------------------------------------
# pre-refactor baseline (faithful port of the seed ServingEngine hot path)
# ---------------------------------------------------------------------------


class BaselineEngine:
    """The seed engine's steady-state loop: host-built batch every tick,
    ``np.asarray`` round-trip every tick, non-donated decode jit, and an
    eager (copying) cache scatter on admission."""

    def __init__(self, cfg, params, *, slots: int, window: int):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.window = window
        self.cache = init_cache(cfg, slots, window)
        self.active: List[Optional[Request]] = [None] * slots
        self._prefill = jax.jit(partial(prefill_step, cfg, window=window))
        self._decode = jax.jit(partial(serve_step, cfg))

    def try_admit(self, req: Request, now: float) -> bool:
        for i, slot in enumerate(self.active):
            if slot is None:
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                logits, cache1 = self._prefill(self.params, batch)
                self.cache = cache_insert(self.cache, cache1, i, self.slots)
                req.output.append(int(jnp.argmax(logits[0])))
                req.prefill_done = now
                self.active[i] = req
                return True
        return False

    decode_ticks = 0

    def step(self, now: float) -> List[Request]:
        if not any(r is not None for r in self.active):
            return []
        self.decode_ticks += 1
        last = [(r.output[-1] if r is not None and r.output else 0)
                for r in self.active]
        batch = {"tokens": jnp.asarray(last, jnp.int32)[:, None]}
        nxt, _, self.cache = self._decode(self.params, self.cache, batch)
        nxt = np.asarray(nxt)
        finished = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.output.append(int(nxt[i]))
            if r.done:
                r.finish_time = now
                finished.append(r)
                self.active[i] = None
        return finished


# ---------------------------------------------------------------------------
# measurements
# ---------------------------------------------------------------------------


def _release_all(eng):
    """Vacate every slot (and, on the paged engine, reclaim its pages)."""
    if hasattr(eng, "drain"):
        eng.drain(0.0)
    for i, r in enumerate(eng.active):
        if r is None:
            continue
        if hasattr(eng, "release_slot"):
            eng.release_slot(i)
        else:
            eng.active[i] = None


def _prime(eng, slots: int, prompt_len: int, vocab: int, budget: int,
           *, warmup: int = 2, seed: int = 0):
    """(Re)admit ``slots`` fresh streams with a finite token budget and warm
    the jit cache. Finite budgets keep every variant's attention working at
    the same KV width (a paged request's lifetime tokens cannot wrap the way
    a rolling ring does), so rounds re-prime instead of running one endless
    stream per slot — admission cost stays outside the timed window."""
    _release_all(eng)
    rng = np.random.default_rng(seed)
    for i in range(slots):
        req = Request(rid=i,
                      prompt=rng.integers(0, vocab, prompt_len).astype(np.int32),
                      max_new_tokens=budget)
        assert eng.try_admit(req, now=0.0)
    for _ in range(warmup):
        eng.step(0.0)
    jax.block_until_ready(eng.cache)


def _tick_count(eng) -> int:
    m = getattr(eng, "metrics", None)
    return m.decode_ticks if m is not None else eng.decode_ticks


def _measure_round(eng, slots: int, ticks: int):
    """Time ~``ticks`` decode ticks on a primed engine. One engine step may
    fuse several ticks (the scanned deferred-sync window), so tokens are
    counted from the engine's tick counter, and per-token latencies divide
    each step's wall time by the ticks it produced. Returns
    (tokens_per_s, per-token seconds list)."""
    tok_s = []
    done = 0
    t0 = time.perf_counter()
    while done < ticks:
        c0 = _tick_count(eng)
        s0 = time.perf_counter()
        eng.step(0.0)
        dt = time.perf_counter() - s0
        n = _tick_count(eng) - c0
        if n == 0 and not any(getattr(eng, "decoding", eng.active)):
            break  # all streams ended (e.g. token budget): don't spin
        done += n
        tok_s.extend([dt / n] * n if n else [])
    if hasattr(eng, "drain"):
        eng.drain(0.0)
    jax.block_until_ready(eng.cache)
    wall = time.perf_counter() - t0
    return done * slots / wall, tok_s


def _ab_rounds(base, eng, slots: int, ticks: int, rounds: int,
               prompt_len: int, vocab: int, budget: int):
    """Interleave baseline/engine measurement rounds (A/B/A/B...) so slow
    drift in machine load hits both variants equally; report the median
    round. Each round runs on freshly primed streams (same seed for both
    variants). Returns (base_tps, base_ticks, eng_tps, eng_ticks)."""
    base_tps, eng_tps = [], []
    base_ticks, eng_ticks = [], []
    for r in range(rounds):
        _prime(base, slots, prompt_len, vocab, budget, seed=r)
        tps, ts = _measure_round(base, slots, ticks)
        base_tps.append(tps)
        base_ticks.extend(ts)
        _prime(eng, slots, prompt_len, vocab, budget, seed=r)
        tps, ts = _measure_round(eng, slots, ticks)
        eng_tps.append(tps)
        eng_ticks.extend(ts)
    return (float(np.median(base_tps)), base_ticks,
            float(np.median(eng_tps)), eng_ticks)


def _ttft_sweep(make_engine, lengths, vocab: int):
    """Admission wall time per prompt length on a fresh engine. The first
    admission is the cold (compile-inclusive) TTFT; the rest show whether
    new prompt lengths retrace (baseline) or hit the bucket cache (engine)."""
    eng = make_engine()
    rng = np.random.default_rng(1)
    times = []
    for i, plen in enumerate(lengths):
        req = Request(rid=100 + i,
                      prompt=rng.integers(0, vocab, plen).astype(np.int32),
                      max_new_tokens=10 ** 9)
        t0 = time.perf_counter()
        assert eng.try_admit(req, now=0.0)
        jax.block_until_ready(eng.cache)
        times.append(time.perf_counter() - t0)
        # free the slot so the sweep never exhausts capacity (and, for the
        # paged engine, returns the prompt's pages to the allocator)
        for j, r in enumerate(eng.active):
            if r is req:
                if hasattr(eng, "release_slot"):
                    eng.release_slot(j)
                else:
                    eng.active[j] = None
    traces = getattr(eng, "prefill_traces", len(lengths))
    return times, traces


def run(report, *, arch: str = "granite-8b", slot_counts=(2, 4, 8),
        ticks: int = 64, rounds: int = 5, sync_every: int = 16, out: str = ""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    window, prompt_len = 256, 32
    results = {"arch": arch, "window": window, "ticks": ticks,
               "rounds": rounds, "sync_every": sync_every,
               "slot_counts": list(slot_counts),
               **noise_report(),  # loadavg + thread pinning when measured
               "baseline": {}, "engine": {}, "speedup": {}}

    # per-round stream budget: warmup + measured ticks (with fused-scan
    # overshoot) must fit the window, so the paged engine (whose lifetime
    # tokens cannot wrap) and the rolling ring attend at the same KV width
    budget = window - prompt_len
    assert budget >= (2 + 1) * sync_every + ticks, (window, ticks)

    for slots in slot_counts:
        base = BaselineEngine(cfg, params, slots=slots, window=window)
        eng = ServingEngine(cfg, params, EngineConfig(
            slots=slots, window=window, sync_every=sync_every))
        base_tps, base_ticks, eng_tps, eng_ticks = _ab_rounds(
            base, eng, slots, ticks, rounds, prompt_len, cfg.vocab_size,
            budget)
        speedup = eng_tps / base_tps
        results["baseline"][slots] = {
            "decode_tps": base_tps,
            "tok_p50_us": float(np.percentile(base_ticks, 50) * 1e6),
            "tok_p95_us": float(np.percentile(base_ticks, 95) * 1e6),
        }
        results["engine"][slots] = {
            "decode_tps": eng_tps,
            "tok_p50_us": float(np.percentile(eng_ticks, 50) * 1e6),
            "tok_p95_us": float(np.percentile(eng_ticks, 95) * 1e6),
            "host_syncs": eng.metrics.host_syncs,
            "decode_ticks": eng.metrics.decode_ticks,
        }
        results["speedup"][slots] = speedup
        report(f"serving_decode_tps_b{slots}_baseline", round(base_tps, 1),
               f"p50={np.percentile(base_ticks,50)*1e6:.0f}us "
               f"p95={np.percentile(base_ticks,95)*1e6:.0f}us")
        report(f"serving_decode_tps_b{slots}_engine", round(eng_tps, 1),
               f"p50={np.percentile(eng_ticks,50)*1e6:.0f}us "
               f"p95={np.percentile(eng_ticks,95)*1e6:.0f}us "
               f"syncs={eng.metrics.host_syncs}/{eng.metrics.decode_ticks}")
        report(f"serving_decode_speedup_b{slots}", round(speedup, 2),
               "engine vs pre-refactor baseline, same run")

    geomean = float(np.exp(np.mean(np.log(list(results["speedup"].values())))))
    results["speedup_geomean"] = geomean
    report("serving_decode_speedup_geomean", round(geomean, 2),
           f"across slot counts {list(slot_counts)} (small batches are "
           f"host-bound: the hot-path rebuild's target regime)")

    # TTFT: varying prompt lengths inside one power-of-two bucket
    lengths = [17, 21, 25, 29, 31, 32]
    base_ttft, base_traces = _ttft_sweep(
        lambda: BaselineEngine(cfg, params, slots=2, window=window),
        lengths, cfg.vocab_size)
    eng_ttft, eng_traces = _ttft_sweep(
        lambda: ServingEngine(cfg, params, EngineConfig(
            slots=2, window=window, chunk_prefill=0)),
        lengths, cfg.vocab_size)
    results["ttft"] = {
        "prompt_lengths": lengths,
        "baseline_ms": [t * 1e3 for t in base_ttft],
        "engine_ms": [t * 1e3 for t in eng_ttft],
        "baseline_warm_p50_ms": float(np.percentile(base_ttft[1:], 50) * 1e3),
        "engine_warm_p50_ms": float(np.percentile(eng_ttft[1:], 50) * 1e3),
        "engine_prefill_traces": eng_traces,
    }
    report("serving_ttft_warm_p50_ms_baseline",
           round(results["ttft"]["baseline_warm_p50_ms"], 2),
           f"{len(lengths)} prompt lengths -> {base_traces} traces")
    report("serving_ttft_warm_p50_ms_engine",
           round(results["ttft"]["engine_warm_p50_ms"], 2),
           f"{len(lengths)} prompt lengths -> {eng_traces} trace(s), bucketed")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("serving_bench_json", out, "full results")
    return results


def smoke(*, arch: str = "granite-8b") -> int:
    """CI gate: a tiny serving run that fails (non-zero exit) on a
    compile-count regression — the zero-recompile invariants the engine
    is built around:

      * one prefill trace per power-of-two bucket (``prefill_traces``);
      * at most two decode traces (the single tick + the fused scan),
        regardless of slot membership churn or request count;
      * steady-state host syncs stay ~1 per ``sync_every`` ticks.
    """
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    sync_every = 4
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=3, window=128, sync_every=sync_every, chunk_prefill=0))
    rng = np.random.default_rng(0)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    # two buckets of prompt lengths, several lengths per bucket
    reqs = [Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=9)
            for i, plen in enumerate((9, 12, 15, 17, 21, 31))]
    t = 0.0
    for r in reqs:
        eng.submit(r, t)
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t)
    check("prefill_traces_per_bucket", eng.prefill_traces == 2,
          f"{eng.prefill_traces} traces for 2 buckets")
    check("decode_traces", eng.decode_traces <= 2,
          f"{eng.decode_traces} traces")
    m = eng.metrics
    check("deferred_host_sync",
          m.host_syncs <= m.decode_ticks / sync_every + len(reqs) + 1,
          f"{m.host_syncs} syncs / {m.decode_ticks} ticks")
    check("completed", m.completed == len(reqs), f"{m.completed} completed")
    if hasattr(eng, "allocator"):
        check("pages_reclaimed", eng.allocator.pages_in_use == 0,
              f"{eng.allocator.pages_in_use} pages leaked")
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: all compile-count probes green")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--slot-counts", default="2,4,8")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--sync-every", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fail on compile-count regression")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch,
              slot_counts=tuple(int(x) for x in args.slot_counts.split(",")),
              ticks=args.ticks, rounds=args.rounds,
              sync_every=args.sync_every, out=args.out)
    print(f"# decode speedup over baseline: geomean "
          f"{res['speedup_geomean']:.2f}x, worst slot count "
          f"{min(res['speedup'].values()):.2f}x")


if __name__ == "__main__":
    main()
