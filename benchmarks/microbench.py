"""Wall-clock microbenchmarks of the real jitted steps (reduced models on
the CPU container): us_per_call for prefill/decode/train across the block
families, plus the MISD simulator's own scheduling overhead."""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import prefill_step, serve_step
from repro.models import init_cache
from repro.training import init_adamw, train_step
from repro.util import timeit


def _time(fn, *args, iters=10, warmup=2):
    return timeit(fn, *args, iters=iters, warmup=warmup) * 1e6  # us


def run(report):
    for arch in ("granite-8b", "mamba2-1.3b", "recurrentgemma-9b",
                 "grok-1-314b"):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.key(0))
        b, s, w = 4, 64, 128
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}

        pf = jax.jit(partial(prefill_step, cfg, window=w))
        us = _time(pf, params, batch)
        report(f"micro_prefill_{arch}", round(us, 1),
               f"b={b} s={s} tok/s={b*s/(us/1e6):,.0f}")

        _, cache = pf(params, batch)
        dec = jax.jit(partial(serve_step, cfg))
        dbatch = {"tokens": jnp.zeros((b, 1), jnp.int32)}
        us = _time(dec, params, cache, dbatch)
        report(f"micro_decode_{arch}", round(us, 1),
               f"b={b} tok/s={b/(us/1e6):,.0f}")

        opt = init_adamw(params)
        tbatch = dict(batch, labels=batch["tokens"])
        ts = jax.jit(partial(train_step, cfg))
        us = _time(ts, params, opt, tbatch, iters=3)
        report(f"micro_train_{arch}", round(us, 1),
               f"b={b} s={s} tok/s={b*s/(us/1e6):,.0f}")

    # scheduler overhead: events/sec of the MISD simulator
    from repro.core.misd import Device, FIFOScheduler, Job, MISDSimulator

    jobs = [Job(i, "m", (0.5, 0.5), 0.01, arrival=i * 0.001)
            for i in range(2000)]
    t0 = time.perf_counter()
    MISDSimulator([Device("d", 4)], FIFOScheduler()).run(jobs)
    dt = time.perf_counter() - t0
    report("micro_sim_jobs_per_s", round(2000 / dt, 0),
           "MISD event-driven simulator throughput")
