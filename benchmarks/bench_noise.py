"""Host-noise mitigation shared by the BENCH_*.json benchmarks.

The container's CPU is shared, so wall-clock numbers drift with whatever
else the host runs. Two mitigations:

  * ``pin_host_threads()`` — call BEFORE the first ``import jax``: caps
    BLAS/XLA host parallelism (oversubscribed thread pools are the
    biggest variance source on a loaded box; single-threaded eigen is
    slower but far steadier). Existing settings are respected
    (``setdefault`` / append), so CI or a user can still override.
  * ``loadavg()`` — record the 1/5/15-minute load averages into every
    BENCH_*.json, so cross-PR comparisons can be qualified ("was the box
    busy when this number was taken?").

Every benchmark (and every new one) routes through this module —
``pin_host_threads()`` before its first jax import, ``noise_report()``
into its BENCH_*.json — instead of re-pinning BLAS threads or reading
loadavg by hand, so the mitigation story stays in one place.
"""
from __future__ import annotations

import os
import sys

_EIGEN_FLAG = "--xla_cpu_multi_thread_eigen=false"
_PINNED = False


def pin_host_threads() -> bool:
    """Pin BLAS/XLA host threads for steadier CPU benchmarks. Only
    effective before jax is imported (XLA reads these at backend init):
    when another module already loaded jax — e.g. `-m benchmarks.run`
    importing several benchmarks into one process — pinning is skipped
    with a warning rather than failing the harness. Returns whether the
    pins apply to this process's jax."""
    global _PINNED
    if "jax" in sys.modules:
        if not _PINNED:
            print("bench_noise: jax already imported; host-thread pinning "
                  "skipped (numbers may be noisier)", file=sys.stderr)
        return _PINNED
    os.environ.setdefault("OMP_NUM_THREADS", "1")
    os.environ.setdefault("OPENBLAS_NUM_THREADS", "1")
    flags = os.environ.get("XLA_FLAGS", "")
    if _EIGEN_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_EIGEN_FLAG}".strip()
    # report what actually holds: a pre-existing OMP_NUM_THREADS=8 export
    # survives the setdefault, and the JSON must say so
    _PINNED = (os.environ["OMP_NUM_THREADS"] == "1"
               and os.environ["OPENBLAS_NUM_THREADS"] == "1"
               and _EIGEN_FLAG in os.environ["XLA_FLAGS"])
    return _PINNED


def loadavg() -> list:
    """[1m, 5m, 15m] host load averages (json-serializable; [] where the
    platform has no getloadavg)."""
    try:
        return [round(x, 3) for x in os.getloadavg()]
    except (AttributeError, OSError):  # pragma: no cover - non-POSIX
        return []


def noise_report() -> dict:
    """The host-noise block every BENCH_*.json records: current load
    averages plus whether this process's jax actually runs with the
    pinned host-thread settings."""
    return {"loadavg": loadavg(), "threads_pinned": _PINNED}
