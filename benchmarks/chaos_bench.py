"""Chaos benchmark: the fault-tolerance layer under injected failures.

    PYTHONPATH=src python benchmarks/chaos_bench.py [--replicas 4]
        [--requests 48] [--rate 0.8] [--out BENCH_chaos.json]
    PYTHONPATH=src python benchmarks/chaos_bench.py --smoke   # CI gate

A Poisson, fully-SAMPLED workload (every stream is stochastic — the
strong replay claim) runs through a 4-replica cluster frontend four
times on the same engines (reset between rounds, jit caches warm):

  baseline — failure-free reference: outputs + goodput + TTFT tail;
  kill     — one replica crashes mid-workload (``EngineFailure`` on its
             next step); the frontend harvests its outstanding ledger
             and replays on survivors;
  hang     — one replica wedges (accepts work, makes no progress); only
             the staleness watchdog can catch it, after
             ``health_timeout_s`` of frozen progress signature;
  slow     — one replica drops to 1/4 speed but keeps making progress:
             it must NOT be declared failed (the closed-loop residual
             absorbs it), and nothing is lost or replayed.

Plus a single-engine ``preempt-churn`` round: a tight-slot prefix-cache
engine where late high-priority arrivals evict decoding victims
(generated prefix cached → suffix-only restore), asserting zero page
leaks and bit-identical victim streams.

Time is VIRTUAL (one cost-model decode tick per cluster step — same
determinism trick as cluster_bench), so the fault schedule, detection
latency, and recovery cost are exactly reproducible from the seed.

Gates (--smoke, wired into CI):
  * zero lost requests: every request resolves FINISHED with a full
    token budget, across kill AND hang AND slow;
  * bit-identical: every stream — including failed-over ones — matches
    the failure-free baseline token-for-token;
  * zero page leaks on survivors (pages_in_use == 0, total_refs == 0
    after clearing the prefix cache);
  * bounded retries: no request exceeds its retry budget, and total
    retries stay under the in-flight ceiling of the dead replica;
  * goodput retention: chaos-round token throughput >= 0.70x baseline.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import numpy as np

from repro.configs import get_config
from repro.core.costmodel import estimate_decode, suggest_health_timeout_s
from repro.models import init_params
from repro.serving import (
    EngineConfig,
    ClusterFrontend,
    FaultInjector,
    FaultyEngine,
    Request,
    RequestState,
    SamplingParams,
    ServingEngine,
)
from repro.serving.trace_export import (
    request_traces,
    validate_chrome_trace,
    write_chrome_trace,
)


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------


def make_workload(n: int, *, rate: float, vocab: int, seed: int,
                  tick_s: float = 1.0, priority_frac: float = 0.0):
    """Poisson arrivals, every request SAMPLED (seed 7000+rid): the replay
    gates then prove the strong claim — stochastic streams survive
    preemption and failover bit-identically. ``priority_frac`` > 0 marks
    a late fraction high-priority with tight deadlines (preemption
    bait for the churn round)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n)) * tick_s
    reqs = []
    for i in range(n):
        hot = priority_frac > 0 and rng.random() < priority_frac and i >= n // 3
        plen = int(rng.integers(8, 33))
        budget = int(rng.integers(8, 17))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=budget,
            arrival_time=float(arrivals[i]),
            ttft_slo_s=(4.0 if hot else 24.0) * tick_s,
            priority=1 if hot else 0,
            sampling=SamplingParams(temperature=0.7, top_k=20, top_p=0.95,
                                    seed=7000 + i),
        ))
    return reqs


# ---------------------------------------------------------------------------
# virtual-time drive with a fault schedule
# ---------------------------------------------------------------------------


def drive(server, reqs, *, injector=None, dt: float = 1.0,
          max_steps: int = 200_000):
    """Open-loop replay in virtual time: fire due fault events, submit
    arrivals as the clock passes them, step once per dt, and collect
    EVERY resolved request (finished, failed, aborted) — the zero-lost
    ledger. Returns (resolved_by_rid, makespan)."""
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    resolved = {}
    i, now = 0, 0.0
    for _ in range(max_steps):
        if injector is not None:
            injector.tick(now)
        while i < len(pending) and pending[i].arrival_time <= now:
            server.submit(pending[i], now)
            i += 1
        for req in server.step(now):
            resolved[req.rid] = req
        if len(resolved) >= len(reqs):
            break
        now += dt
    else:
        raise RuntimeError(
            f"workload did not drain in {max_steps} steps "
            f"({len(resolved)}/{len(reqs)} resolved — requests LOST)")
    for req in server.drain(now):
        resolved[req.rid] = req
    return resolved, now


# ---------------------------------------------------------------------------
# rounds
# ---------------------------------------------------------------------------


def build_proxies(cfg, params, *, replicas, slots, window, max_seq,
                  sync_every, tick_s):
    # tracing on: the chaos run doubles as the repo's trace-demo source
    # (virtual-time drive, so stamping cost is invisible here anyway)
    return [FaultyEngine(ServingEngine(cfg, params, EngineConfig(
                slots=slots, window=window, max_seq=max_seq,
                sync_every=sync_every, sla_s=4.0 * tick_s, tracing=True)))
            for _ in range(replicas)]


def run_round(proxies, reqs, *, fault, victim, t_fault, seed, tick_s,
              health_s, max_retries=3, slow_every=4):
    """One chaos round on shared engines: reset, arm the schedule, drive."""
    for p in proxies:
        p.inject("recover")
        p.engine.reset()
    cluster = ClusterFrontend(proxies, policy="predicted", seed=seed,
                              health_timeout_s=health_s,
                              max_retries=max_retries,
                              retry_backoff_s=tick_s, tracing=True)
    injector = None
    if fault is not None:
        name = cluster.instances[victim].name
        injector = FaultInjector({name: proxies[victim]})
        injector.schedule(t_fault, name, fault, slow_every=slow_every)
    resolved, makespan = drive(cluster, reqs, injector=injector, dt=tick_s)
    m = cluster.merged_metrics()
    survivors = [inst.engine for inst in
                 cluster.instances + cluster.draining + cluster.retired]
    leaks = []
    for eng in survivors:
        if eng.paged:
            eng.clear_prefix_cache()
            leaks.append((eng.allocator.pages_in_use,
                          eng.allocator.total_refs))
    ttfts = np.asarray([r.ttft for r in reqs if r.ttft >= 0]) / tick_s
    ticks = makespan / tick_s
    # useful output only: tokens DELIVERED to clients per tick (work the
    # dead replica generated and lost does not count toward goodput)
    tokens_out = sum(len(r.output) for r in resolved.values())
    return {
        "fault": fault or "none",
        "resolved": len(resolved),
        "finished": sum(r.state is RequestState.FINISHED
                        for r in resolved.values()),
        "full_budget": sum(len(r.output) == r.max_new_tokens
                           for r in resolved.values()),
        "ttft_p50": float(np.percentile(ttfts, 50)) if len(ttfts) else -1.0,
        "ttft_p99": float(np.percentile(ttfts, 99)) if len(ttfts) else -1.0,
        "makespan_ticks": ticks,
        "throughput_tpt": tokens_out / ticks if ticks else 0.0,
        "goodput": m.goodput,
        "retried": m.retried,
        "failed_over": m.failed_over,
        "max_request_retries": max((r.retries for r in resolved.values()),
                                   default=0),
        "preempted": m.preempted,
        "preempt_restores": m.preempt_restores,
        "failed_replicas": [i.name for i in cluster.failed],
        "survivor_leaks": leaks,  # (pages_in_use, total_refs) per survivor
        "outputs": {r.rid: list(map(int, r.output))
                    for r in resolved.values()},
        "_reqs": list(resolved.values()),  # popped by run() for trace export
    }


def run_churn(cfg, params, *, requests, rate, seed, tick_s, slots=2,
              window=128, max_seq=192, sync_every=4):
    """Single-engine preemption churn: tight slots + late high-priority
    arrivals evict decoding victims; the restore path (cached generated
    prefix -> suffix-only prefill) must reproduce every stream."""
    reqs = make_workload(requests, rate=rate, vocab=cfg.vocab_size,
                         seed=seed + 1, tick_s=tick_s, priority_frac=0.5)

    def build(preemption):
        return ServingEngine(cfg, params, EngineConfig(
            slots=slots, window=window, max_seq=max_seq,
            sync_every=sync_every, sla_s=4.0 * tick_s, prefix_cache=True,
            preemption=preemption, edf_backlog=True, tracing=preemption))

    ref_reqs = copy.deepcopy(reqs)
    ref, _ = drive(build(False), ref_reqs, dt=tick_s)
    eng = build(True)
    resolved, makespan = drive(eng, reqs, dt=tick_s)
    eng.clear_prefix_cache()
    return {
        "resolved": len(resolved),
        "finished": sum(r.state is RequestState.FINISHED
                        for r in resolved.values()),
        "preempted": eng.metrics.preempted,
        "preempt_restores": eng.metrics.preempt_restores,
        "bit_identical_to_unpreempted": all(
            list(resolved[rid].output) == list(ref[rid].output)
            for rid in ref),
        "pages_in_use": eng.allocator.pages_in_use,
        "total_refs": eng.allocator.total_refs,
        "makespan_ticks": makespan / tick_s,
        "_reqs": list(resolved.values()),  # popped by run() for trace export
    }


# ---------------------------------------------------------------------------
# full bench
# ---------------------------------------------------------------------------


def run(report, *, arch="granite-8b", replicas=4, slots=2, window=128,
        max_seq=192, sync_every=4, requests=48, rate=0.8, seed=0,
        rounds=("kill", "hang", "slow"), churn=True, out="",
        trace_out=""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    tick_s = estimate_decode(cfg, slots, window).latency_s
    health_s = suggest_health_timeout_s(cfg, slots=slots, context=window)
    proxies = build_proxies(cfg, params, replicas=replicas, slots=slots,
                            window=window, max_seq=max_seq,
                            sync_every=sync_every, tick_s=tick_s)

    def workload():
        return make_workload(requests, rate=rate, vocab=cfg.vocab_size,
                             seed=seed, tick_s=tick_s)

    # the fault lands mid-workload: at the median arrival
    arrivals = sorted(r.arrival_time for r in workload())
    t_fault = arrivals[len(arrivals) // 2]

    results = {"arch": arch, "replicas": replicas, "slots": slots,
               "window": window, "max_seq": max_seq,
               "sync_every": sync_every, "requests": requests,
               "rate": rate, "seed": seed, "tick_s": tick_s,
               "health_timeout_ticks": health_s / tick_s,
               "t_fault_ticks": t_fault / tick_s,
               **noise_report(),
               "note": "virtual-time drive; latencies in cost-model decode "
                       "ticks; every request stochastic (seeded sampling) "
                       "so replay gates cover the strong claim",
               "rounds": {}}

    traced = []  # (lane, Trace) pairs accumulated for --trace-out

    base = run_round(proxies, workload(), fault=None, victim=0,
                     t_fault=0.0, seed=seed, tick_s=tick_s,
                     health_s=health_s)
    baseline_outputs = base.pop("outputs")
    traced += request_traces(base.pop("_reqs"), prefix="baseline/")
    results["rounds"]["baseline"] = base
    report("chaos_baseline_ttft_p99", round(base["ttft_p99"], 2),
           f"tpt={base['throughput_tpt']:.2f} goodput={base['goodput']:.3f}")

    for fault in rounds:
        r = run_round(proxies, workload(), fault=fault, victim=0,
                      t_fault=t_fault, seed=seed, tick_s=tick_s,
                      health_s=health_s)
        round_traces = request_traces(r.pop("_reqs"), prefix=f"{fault}/")
        traced += round_traces
        r["span_kinds"] = sorted({k for _, t in round_traces
                                  for k in t.kinds()})
        r["bit_identical_to_baseline"] = r.pop("outputs") == baseline_outputs
        r["goodput_retention"] = (r["throughput_tpt"] / base["throughput_tpt"]
                                  if base["throughput_tpt"] else 0.0)
        r["ttft_p99_inflation"] = (r["ttft_p99"] / base["ttft_p99"]
                                   if base["ttft_p99"] else 1.0)
        results["rounds"][fault] = r
        report(f"chaos_{fault}_goodput_retention",
               round(r["goodput_retention"], 3),
               f"ttft_p99 x{r['ttft_p99_inflation']:.2f} "
               f"retried={r['retried']} failed_over={r['failed_over']} "
               f"bit_identical={r['bit_identical_to_baseline']}")

    if churn:
        c = run_churn(cfg, params, requests=max(12, requests // 2),
                      rate=rate, seed=seed, tick_s=tick_s, slots=slots,
                      window=window, max_seq=max_seq,
                      sync_every=sync_every)
        churn_traces = request_traces(c.pop("_reqs"), prefix="churn/")
        traced += churn_traces
        c["span_kinds"] = sorted({k for _, t in churn_traces
                                  for k in t.kinds()})
        results["preempt_churn"] = c
        report("chaos_churn_preemptions", c["preempted"],
               f"restores={c['preempt_restores']} "
               f"bit_identical={c['bit_identical_to_unpreempted']} "
               f"leaks={c['pages_in_use']}p/{c['total_refs']}r")

    # span-integrity rollup across every exported trace (whether or not a
    # viewer file is requested): terminal traces must be well-formed
    span_problems = [p for _, t in traced for p in t.validate()]
    results["trace"] = {
        "traced_requests": len(traced),
        "span_problems": span_problems[:20],
    }
    if trace_out:
        doc = write_chrome_trace(trace_out, traced)
        results["trace"]["events"] = len(doc["traceEvents"])
        results["trace"]["doc_problems"] = validate_chrome_trace(doc)[:20]
        report("chaos_trace_json", trace_out,
               f"{len(doc['traceEvents'])} events from {len(traced)} "
               f"request traces (open in https://ui.perfetto.dev)")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("chaos_bench_json", out, "full results")
    return results


# ---------------------------------------------------------------------------
# CI smoke gate
# ---------------------------------------------------------------------------


def smoke(*, arch="granite-8b") -> int:
    """Seeded kill-one-of-4 scenario (+hang/slow/churn): fail on any lost
    request, page leak, unbounded retry, diverged stream, goodput
    collapse, or malformed span trace."""
    trace_out = os.path.join(os.path.dirname(__file__), "..",
                             "TRACE_chaos.json")
    res = run(lambda *a: None, arch=arch, replicas=4, slots=2, window=128,
              max_seq=192, sync_every=4, requests=24, rate=0.8, seed=0,
              trace_out=trace_out)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    n = res["requests"]
    for fault in ("kill", "hang", "slow"):
        r = res["rounds"][fault]
        check(f"{fault}_zero_lost",
              r["resolved"] == n and r["finished"] == n
              and r["full_budget"] == n,
              f"resolved={r['resolved']} finished={r['finished']} "
              f"full_budget={r['full_budget']} of {n}")
        check(f"{fault}_bit_identical", r["bit_identical_to_baseline"],
              "streams vs failure-free baseline")
        check(f"{fault}_no_survivor_leaks",
              all(l == [0, 0] or l == (0, 0) for l in r["survivor_leaks"]),
              f"(pages_in_use, total_refs)={r['survivor_leaks']}")
        check(f"{fault}_bounded_retries",
              r["max_request_retries"] <= 3 and r["retried"] <= n,
              f"max={r['max_request_retries']} total={r['retried']}")
        check(f"{fault}_goodput_retention",
              r["goodput_retention"] >= 0.70,
              f"{r['goodput_retention']:.3f} (gate 0.70)")
    check("kill_replica_failed",
          res["rounds"]["kill"]["failed_replicas"] != [],
          res["rounds"]["kill"]["failed_replicas"])
    check("hang_watchdog_tripped",
          res["rounds"]["hang"]["failed_replicas"] != [],
          res["rounds"]["hang"]["failed_replicas"])
    check("slow_not_declared_dead",
          res["rounds"]["slow"]["failed_replicas"] == []
          and res["rounds"]["slow"]["failed_over"] == 0,
          f"failed={res['rounds']['slow']['failed_replicas']} "
          f"failed_over={res['rounds']['slow']['failed_over']}")
    c = res["preempt_churn"]
    check("churn_preempts", c["preempted"] > 0 and c["preempt_restores"] > 0,
          f"preempted={c['preempted']} restores={c['preempt_restores']}")
    check("churn_bit_identical", c["bit_identical_to_unpreempted"],
          "victim streams vs unpreempted run")
    check("churn_zero_leaks",
          c["pages_in_use"] == 0 and c["total_refs"] == 0,
          f"pages_in_use={c['pages_in_use']} total_refs={c['total_refs']}")
    check("churn_all_finish", c["finished"] == c["resolved"],
          f"{c['finished']}/{c['resolved']}")
    tr = res["trace"]
    check("trace_spans_well_formed",
          tr["traced_requests"] > 0 and tr["span_problems"] == [],
          f"{tr['traced_requests']} traces, "
          f"problems={tr['span_problems'][:3]}")
    check("trace_doc_valid", tr.get("doc_problems") == [],
          f"doc_problems={tr.get('doc_problems', ['missing'])[:3]}")
    fault_kinds = sorted({k for fault in ("kill", "hang", "slow")
                          for k in res["rounds"][fault].get("span_kinds", [])})
    check("failover_retry_span", "failover_retry" in fault_kinds,
          f"fault-round span kinds: {fault_kinds}")
    churn_kinds = c.get("span_kinds", [])
    check("churn_preempt_restore_spans",
          {"preempt", "restore"} <= set(churn_kinds),
          f"churn span kinds: {churn_kinds}")
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: chaos gates green — zero lost, bit-identical replay, "
          "zero leaks, bounded retries")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--max-seq", type=int, default=192)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=0.8,
                    help="Poisson arrivals per virtual second")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: seeded kill/hang/slow/churn scenario")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_chaos.json"))
    ap.add_argument("--trace-out", default="",
                    help="export every request's span trace as Chrome-trace "
                         "JSON (open in https://ui.perfetto.dev)")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, replicas=args.replicas,
              slots=args.slots, window=args.window, max_seq=args.max_seq,
              sync_every=args.sync_every, requests=args.requests,
              rate=args.rate, seed=args.seed, out=args.out,
              trace_out=args.trace_out)
    k = res["rounds"]["kill"]
    print(f"# kill 1/{args.replicas}: goodput retention "
          f"{k['goodput_retention']:.3f}, ttft p99 "
          f"x{k['ttft_p99_inflation']:.2f}, {k['retried']} retries, "
          f"bit_identical={k['bit_identical_to_baseline']}")


if __name__ == "__main__":
    main()
