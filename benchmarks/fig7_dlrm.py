"""Fig. 7 reproduction: capacity-driven DLRM scale-out ([26] Lui et al.).

A TB-scale DLRM cannot fit one host; sharding embedding tables across N
nodes adds lookup fan-out traffic (the survey's RPC pattern; all_to_all
under pjit here). We sweep N and report fit, per-query latency, and the
communication share — reproducing the paper's observation that scale-out
is capacity-driven (you pay latency for memory capacity).

Also includes the heterogeneous-memory alternative ([47][49]): HBM+host
tiering on fewer nodes at Zipf access locality.
"""
from __future__ import annotations

import numpy as np

from repro.configs.dlrm import CONFIG as DLRM
from repro.core.costmodel import WorkEstimate
from repro.core.hardware import TPU_V5E
from repro.core.simd.embedding import lookup_traffic_bytes
from repro.core.simd.offload import plan_offload

BATCH = 256


def scale_out_estimate(n_nodes: int) -> dict:
    table_bytes = DLRM.embedding_params() * 4.0
    per_node = table_bytes / n_nodes
    fits = per_node <= 0.8 * TPU_V5E.hbm_bytes
    mlp_flops = 2.0 * DLRM.mlp_params() * BATCH
    # each node scans its shard of lookups; traffic = gathered rows
    traffic = lookup_traffic_bytes(DLRM, BATCH) * (n_nodes - 1) / max(n_nodes, 1)
    est = WorkEstimate(
        flops=mlp_flops,
        hbm_bytes=per_node + BATCH * DLRM.num_tables * DLRM.multi_hot
        * DLRM.embed_dim * 4.0 / n_nodes,
        collective_bytes=traffic,
        n_chips=n_nodes,
    )
    return {"fits": fits, "latency_s": est.latency_s,
            "comm_share": est.collective_s / est.latency_s if est.latency_s else 0}


def run(report):
    table_gb = DLRM.embedding_params() * 4 / 2 ** 30
    report("fig7_table_size_gb", round(table_gb, 1),
           f"{DLRM.num_tables} tables x {DLRM.rows_per_table} rows")
    first_fit = None
    for n in (1, 2, 4, 8, 16, 32, 64):
        r = scale_out_estimate(n)
        if r["fits"] and first_fit is None:
            first_fit = n
        report(f"fig7_nodes_{n}",
               "fits" if r["fits"] else "OOM",
               f"latency={r['latency_s']*1e6:.1f}us comm_share={r['comm_share']:.2f}")
    report("fig7_min_nodes", first_fit, "capacity-driven scale-out point")

    # heterogeneous-memory alternative on ONE node
    # production CTR traffic is strongly skewed; alpha ~1.05 ([47] Fig. 4)
    plan = plan_offload(
        DLRM.num_tables * DLRM.rows_per_table, DLRM.embed_dim * 4,
        hbm_budget_bytes=0.5 * TPU_V5E.hbm_bytes, alpha=1.05)
    report("fig7_offload_hit_rate", round(plan.hit_rate, 3),
           "[47][49]: hot-row HBM cache over Zipf accesses")
    report("fig7_offload_slowdown", round(plan.slowdown_vs_hbm, 2),
           "effective slowdown vs all-HBM (raw PCIe gap ~25x)")
    return {"min_nodes": first_fit, "offload_slowdown": plan.slowdown_vs_hbm}
