"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the compiled dry-run artifacts in results/dryrun/.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(cost_analysis reports the per-partition SPMD module, so the per-device
form is identical to the global form divided by chip count.)

Also reports MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)
and the MODEL/HLO ratio — the "useful compute" fraction that catches
remat and masked-attention waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config, get_shape
from repro.core.costmodel import model_flops
from repro.core.hardware import TPU_V5E

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh: str = "single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("ok"):
            recs.append(r)
    return recs


def analyze(rec: dict) -> dict:
    from repro.core.costmodel import estimate

    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    n = rec["n_chips"]
    flops_dev = rec["flops"]
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec["collective_total_per_device"]
    compute_s = flops_dev / TPU_V5E.peak_flops
    # HLO bytes_accessed counts every fusion operand (XLA:CPU granularity)
    # and is an UPPER bound on HBM traffic; the analytic term (params +
    # KV/state + activation residency, perfectly fused) is the LOWER bound.
    # Dominance uses the analytic term so inflated fusion accounting cannot
    # mask a collective bottleneck (EXPERIMENTS.md §Roofline).
    memory_hlo_s = bytes_dev / TPU_V5E.hbm_bw
    memory_s = estimate(cfg, shape, n_chips=n).memory_s
    coll_s = coll_dev / TPU_V5E.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    ratio = mf / (flops_dev * n) if flops_dev > 0 else 0.0
    suggest = {
        "compute": "cut redundant FLOPs (masked-attention waste, remat) or "
                   "widen the model axis",
        "memory": "shrink resident bytes: KV int8, fewer cache copies, "
                  "fuse elementwise chains",
        "collective": "reshard to cut all-gathers (expert-parallel / "
                      "sequence-parallel) or overlap collectives with compute",
    }[dominant]
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "n_chips")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * n,
        "useful_ratio": ratio,
        "suggestion": suggest,
    }


def run(report, mesh: str = "single"):
    rows = [analyze(r) for r in load_records(mesh)]
    worst = sorted(rows, key=lambda r: r["useful_ratio"])[:3]
    coll_bound = [r for r in rows if r["dominant"] == "collective"]
    for r in rows:
        report(
            f"roofline_{r['arch']}_{r['shape']}",
            r["dominant"],
            f"compute={r['compute_s']*1e3:.3f}ms memory={r['memory_s']*1e3:.3f}ms "
            f"collective={r['collective_s']*1e3:.3f}ms useful={r['useful_ratio']:.2f}",
        )
    report("roofline_combos", len(rows), f"{mesh}-pod analyzed")
    report("roofline_collective_bound", len(coll_bound),
           ",".join(f"{r['arch']}:{r['shape']}" for r in coll_bound[:6]))
    report("roofline_worst_useful",
           ",".join(f"{r['arch']}:{r['shape']}={r['useful_ratio']:.2f}"
                    for r in worst), "lowest MODEL/HLO ratios")
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms, analytic / HLO-ub) | "
           "collective (ms) | dominant | MODEL/HLO | next lever |"
           "\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.3f} | "
            f"{r['memory_s']*1e3:.3f} / {r['memory_hlo_s']*1e3:.0f} | "
            f"{r['collective_s']*1e3:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['suggestion']} |")
    return "\n".join(lines)
