"""Sharded-serving benchmark: one tensor/expert-parallel replica vs the
1-chip engine on the SAME workload.

    PYTHONPATH=src python benchmarks/sharded_bench.py [--arch granite-8b]
        [--ticks 96] [--out BENCH_sharded.json]
    PYTHONPATH=src python benchmarks/sharded_bench.py --smoke   # CI gate

The benchmark forces 8 XLA host-platform devices (set BEFORE the first
jax import — the backend reads the flag once) and serves the same seeded
workload through a ``DeviceTopology(tp=8)`` engine and a 1-chip engine:

  * decode tok/s for both (on a CPU host the "sharded speedup" is noise —
    8 fake devices share the same silicon; the artifact records the
    OVERHEAD of the partitioned program, and the modeled per-axis
    collective seconds from ``LoadReport.axis_collective_s`` say what a
    real interconnect would add);
  * stream bit-identity: the sharded engine must produce exactly the
    1-chip streams (greedy AND sampled) — the exact-profile contract;
  * compile-count parity: tensor parallelism must not multiply traces
    (same prefill/decode trace counts on both engines);
  * page accounting: the sharded paged engine drains to zero pages.

``--smoke`` runs the three gates above plus an expert-parallel MoE
bit-identity pass on a tiny config and exits nonzero on any failure.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

# 8 host-platform devices for the (1 x 8) serving mesh; must land in the
# environment before jax initializes its backend
N_DEV = 8
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + f" --xla_force_host_platform_device_count={N_DEV}"
                           ).strip()

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    DeviceTopology,
    EngineConfig,
    Request,
    SamplingParams,
    ServingEngine,
)


def _shard_cfg(arch: str):
    """The bench config: reduced, with 8 kv heads so the kv-head axis of
    the paged pools actually splits 8 ways (reduced() caps heads at 4)."""
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, num_heads=N_DEV, num_kv_heads=N_DEV)


def _moe_cfg():
    cfg = get_config("grok-1-314b").reduced()
    return dataclasses.replace(cfg, num_heads=N_DEV, num_kv_heads=N_DEV,
                               num_experts=N_DEV, moe_expert_parallel=True)


def _workload(n, vocab, *, seed=0, max_new=8):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, 8 + 2 * i).astype(np.int32),
                    max_new_tokens=max_new,
                    sampling=(SamplingParams() if i % 2 == 0 else
                              SamplingParams(temperature=0.8, top_k=40,
                                             seed=100 + i)))
            for i in range(n)]


def _engine(cfg, params, tp, **kw):
    return ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, chunk_prefill=16,
        topology=DeviceTopology(tp=tp), **kw))


def _serve(eng, reqs):
    t = 0.0
    for r in reqs:
        eng.submit(r, t)
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t + 1.0)
    return [tuple(r.output) for r in reqs]


def _decode_tps(eng, *, ticks, prompt_len, vocab):
    """Steady-state decode throughput: keep every slot saturated with
    window-sized streams (each stream ends at the context cap, so a fresh
    one is admitted as slots free up), then time ~``ticks`` decode ticks
    (one warmup step first — it compiles the fused window)."""
    rng = np.random.default_rng(0)
    budget = eng.window - prompt_len - 1
    rid = iter(range(1000, 1_000_000))

    def refill():
        while eng.try_admit(Request(
                rid=next(rid),
                prompt=rng.integers(0, vocab, prompt_len).astype(np.int32),
                max_new_tokens=budget), 0.0):
            pass

    refill()
    eng.step(0.0)
    jax.block_until_ready(eng.cache)
    c0 = eng.metrics.decode_ticks
    t0 = time.perf_counter()
    while eng.metrics.decode_ticks - c0 < ticks:
        refill()
        eng.step(0.0)
    jax.block_until_ready(eng.cache)
    dt = time.perf_counter() - t0
    return (eng.metrics.decode_ticks - c0) * eng.slots / dt


def run(report, *, arch="granite-8b", ticks=96, seed=0, out=""):
    cfg = _shard_cfg(arch)
    params = init_params(cfg, jax.random.key(seed))

    results = {"arch": arch, "devices": jax.local_device_count(),
               "ticks": ticks, "seed": seed, **noise_report()}

    base = _engine(cfg, params, 1)
    shard = _engine(cfg, params, N_DEV)
    reqs_b = _workload(6, cfg.vocab_size, seed=seed)
    reqs_s = _workload(6, cfg.vocab_size, seed=seed)
    streams_b = _serve(base, reqs_b)
    streams_s = _serve(shard, reqs_s)
    identical = streams_b == streams_s
    report("sharded_streams_bit_identical", identical,
           f"tp{N_DEV} vs 1-chip, greedy+sampled mix")
    results["streams_bit_identical"] = identical

    traces = {"base": (base.prefill_traces, base.decode_traces),
              "shard": (shard.prefill_traces, shard.decode_traces)}
    results["traces"] = {k: {"prefill": v[0], "decode": v[1]}
                         for k, v in traces.items()}
    report("sharded_trace_parity", traces["base"] == traces["shard"],
           f"base={traces['base']} shard={traces['shard']}")

    tps_b = _decode_tps(base, ticks=ticks, prompt_len=16,
                        vocab=cfg.vocab_size)
    tps_s = _decode_tps(shard, ticks=ticks, prompt_len=16,
                        vocab=cfg.vocab_size)
    results["decode_tps"] = {"1chip": tps_b, f"tp{N_DEV}": tps_s,
                             "ratio": tps_s / tps_b}
    report("sharded_decode_tps", round(tps_s, 1),
           f"1chip={tps_b:.1f} ratio={tps_s / tps_b:.3f} (CPU host: 8 fake "
           f"devices share one socket; ratio measures partition overhead)")

    rep = shard.load_report()
    results["sharded_report"] = rep.to_dict()
    results["axis_collective_s"] = dict(rep.axis_collective_s)
    results["axis_util"] = dict(rep.axis_util)
    report("sharded_axis_collective_s",
           {a: f"{s:.3g}" for a, s in rep.axis_collective_s},
           "modeled per-axis collective seconds per full-batch decode tick")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2, default=str)
        report("sharded_bench_json", out, "full results")
    return results


def smoke(*, arch="granite-8b"):
    failures = []

    def check(name, ok, got=""):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    cfg = _shard_cfg(arch)
    params = init_params(cfg, jax.random.key(0))
    base = _engine(cfg, params, 1)
    shard = _engine(cfg, params, N_DEV)
    sb = _serve(base, _workload(4, cfg.vocab_size))
    ss = _serve(shard, _workload(4, cfg.vocab_size))
    check("stream_identity", sb == ss, f"{len(sb)} streams")
    check("trace_parity",
          (base.prefill_traces, base.decode_traces)
          == (shard.prefill_traces, shard.decode_traces),
          f"base=({base.prefill_traces},{base.decode_traces}) "
          f"shard=({shard.prefill_traces},{shard.decode_traces})")
    check("page_drain", (not shard.paged)
          or shard.allocator.pages_in_use == 0,
          f"pages_in_use={getattr(shard.allocator, 'pages_in_use', 0)}")

    mcfg = _moe_cfg()
    mparams = init_params(mcfg, jax.random.key(1))
    mb = _serve(_engine(mcfg, mparams, 1, moe_capacity_policy="strict"),
                _workload(3, mcfg.vocab_size, max_new=5))
    ms = _serve(_engine(mcfg, mparams, N_DEV, moe_capacity_policy="strict"),
                _workload(3, mcfg.vocab_size, max_new=5))
    check("moe_ep_stream_identity", mb == ms, f"{len(mb)} streams")

    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: sharded streams bit-identical, trace counts flat, "
          "pages drained, MoE EP exact")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--ticks", type=int, default=96)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: bit-identity + trace parity + page drain")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_sharded.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, ticks=args.ticks, out=args.out)
    print(f"# sharded decode {res['decode_tps'][f'tp{N_DEV}']:.1f} tok/s vs "
          f"1-chip {res['decode_tps']['1chip']:.1f} tok/s; streams "
          f"{'bit-identical' if res['streams_bit_identical'] else 'DIVERGED'}")


if __name__ == "__main__":
    main()
