"""Shared-prefix KV cache benchmark: A/B warm (prefix-cached) vs cold
prefill on a templated multi-turn workload — the traffic shape the survey
identifies as dominant at scale (system prompts / few-shot templates /
multi-turn history shared across requests).

    PYTHONPATH=src python benchmarks/prefix_bench.py [--arch granite-8b]
        [--template-len 384] [--turns 6] [--rounds 3] [--out BENCH_prefix.json]
    PYTHONPATH=src python benchmarks/prefix_bench.py --smoke   # CI gate

Two identical engines serve the SAME prompts: one cold (every admission
pays the full prefill), one with ``prefix_cache=True`` (the template's
pages are aliased from the radix index and only the per-turn suffix is
prefilled). TTFT is the admission wall time on an otherwise-idle engine
(equal batch for both variants), A/B-interleaved across rounds so host
drift hits both sides; BLAS/XLA host threads are pinned and the host
loadavg is recorded (bench_noise).

The bench is also a correctness gate (``--smoke`` fails CI on it):

  * warm-hit TTFT must be >= 5x better than cold at equal batch;
  * decoded token streams must be bit-identical to the no-sharing path;
  * zero pages leaked: after drain + ``clear_prefix_cache`` every
    refcount is 0 and the pool is fully free;
  * zero-recompile: hit admissions reuse one seed trace + one suffix
    trace per bucket — trace counts must not grow with hit count.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import EngineConfig, Request, ServingEngine

RID = iter(range(10 ** 9))


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------


def make_workload(*, n_templates: int, template_len: int, turns: int,
                  suffix_lo: int, suffix_hi: int, vocab: int, seed: int):
    """Templated multi-turn traffic: ``n_templates`` long shared prefixes
    (system prompt + few-shot block), each carrying ``turns`` requests
    with a unique short user suffix. Returns a list of prompts in
    template-interleaved arrival order (the worst case for naive reuse:
    consecutive requests alternate templates)."""
    rng = np.random.default_rng(seed)
    templates = [rng.integers(0, vocab, template_len).astype(np.int32)
                 for _ in range(n_templates)]
    prompts = []
    for turn in range(turns):
        for tpl in templates:
            sfx = rng.integers(0, vocab,
                               int(rng.integers(suffix_lo, suffix_hi + 1))
                               ).astype(np.int32)
            prompts.append(np.concatenate([tpl, sfx]).astype(np.int32))
    return prompts


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def measure_ttfts(eng, prompts):
    """Admission wall time per prompt on an idle engine (equal batch for
    every variant). ``max_new_tokens=1`` finalizes at activation, so each
    admission also vacates its slot — but pages REGISTERED by the prefix
    engine survive in its index, which is exactly the cache warming under
    test. Returns (seconds list, hit-tokens list)."""
    times, hits = [], []
    for p in prompts:
        req = Request(next(RID), p, max_new_tokens=1)
        t0 = time.perf_counter()
        assert eng.try_admit(req, 0.0)
        jax.block_until_ready(eng.cache)
        times.append(time.perf_counter() - t0)
        hits.append(req.prefix_hit_tokens)
        eng.drain(0.0)
    return times, hits


def decode_outputs(eng, prompts, budget: int):
    """Serve every prompt to completion (continuous batching across all
    slots) and return the token streams — the bit-identity probe."""
    reqs = [Request(next(RID), p.copy(), max_new_tokens=budget)
            for p in prompts]
    t = 0.0
    pending = list(reqs)
    while not all(r.done for r in reqs):
        while pending and eng.try_admit(pending[0], t):
            pending.pop(0)
        t += 1.0
        eng.step(t)
    eng.drain(t)
    return [list(r.output) for r in reqs]


def run(report, *, arch: str = "granite-8b", n_templates: int = 2,
        template_len: int = 768, turns: int = 6, suffix_lo: int = 8,
        suffix_hi: int = 24, rounds: int = 3, budget: int = 8,
        max_seq: int = 1024, page_size: int = 16, seed: int = 0,
        out: str = ""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    # pool = working set (slots * max_pages) + an explicit CACHE budget
    # (one chain per template incl. the warmup probe's). Undersizing is
    # graceful — LRU eviction just truncates the oldest chains, shrinking
    # hits — but the headline measures full-template hits, so fund them.
    max_pages = max_seq // page_size
    pool = (2 + n_templates + 1) * max_pages + 1
    mk = dict(slots=2, window=max_seq, max_seq=max_seq,
              page_size=page_size, pool_pages=pool,
              chunk_prefill=0, sync_every=4)
    cold = ServingEngine(cfg, params, EngineConfig(**mk))
    warm = ServingEngine(cfg, params, EngineConfig(prefix_cache=True, **mk))
    assert cold.paged and warm.paged

    prompts = make_workload(
        n_templates=n_templates, template_len=template_len, turns=turns,
        suffix_lo=suffix_lo, suffix_hi=suffix_hi, vocab=cfg.vocab_size,
        seed=seed)

    # -- warm the jit caches on a THROWAWAY template (both engines pay the
    # same compiles; the measured templates stay unregistered until their
    # first measured admission primes them)
    rngp = np.random.default_rng(seed + 991)
    ptpl = rngp.integers(0, cfg.vocab_size, template_len).astype(np.int32)
    probe = [np.concatenate(
        [ptpl, rngp.integers(0, cfg.vocab_size, n).astype(np.int32)])
        for n in (suffix_lo, suffix_hi)]  # both suffix buckets
    for _ in range(2):  # second pass warms the repeat-hit (plen-1) path
        measure_ttfts(cold, probe)
        measure_ttfts(warm, probe)
    traces_after_warmup = warm.prefill_traces

    # -- TTFT A/B rounds (interleaved: drift hits both variants equally)
    cold_t, warm_t, warm_hits = [], [], []
    for _ in range(rounds):
        t, _ = measure_ttfts(cold, prompts)
        cold_t.extend(t)
        t, h = measure_ttfts(warm, prompts)
        warm_t.extend(t)
        warm_hits.extend(h)
    hit_t = [t for t, h in zip(warm_t, warm_hits) if h > 0]
    miss_t = [t for t, h in zip(warm_t, warm_hits) if h == 0]
    cold_ms = float(np.median(cold_t) * 1e3)
    hit_ms = float(np.median(hit_t) * 1e3)
    speedup = cold_ms / hit_ms if hit_ms else 0.0
    trace_growth = warm.prefill_traces - traces_after_warmup

    # -- bit-identity at equal batch: decode the same workload through
    # both engines (the warm one serving from aliased pages)
    out_cold = decode_outputs(cold, prompts, budget)
    out_warm = decode_outputs(warm, prompts, budget)
    identical = out_cold == out_warm

    # -- zero-leak probe: after drain every slot has retired; only the
    # index holds pages, and clearing it must return the pool to empty
    # (all refcounts 0)
    cached = warm.allocator.pages_in_use
    assert cached == warm.prefix_index.cached_pages, (
        cached, warm.prefix_index.cached_pages)
    freed = warm.clear_prefix_cache()
    leaked = warm.allocator.pages_in_use
    live_refs = warm.allocator.total_refs
    cold_leaked = cold.allocator.pages_in_use

    results = {
        "arch": arch, "n_templates": n_templates,
        "template_len": template_len, "turns": turns,
        "suffix_tokens": [suffix_lo, suffix_hi], "rounds": rounds,
        "budget": budget, "max_seq": max_seq, "page_size": page_size,
        "seed": seed,
        **noise_report(),  # loadavg + thread pinning when measured
        "ttft": {
            "cold_p50_ms": cold_ms,
            "warm_hit_p50_ms": hit_ms,
            "warm_miss_p50_ms": float(np.median(miss_t) * 1e3) if miss_t
            else None,
            "cold_p95_ms": float(np.percentile(cold_t, 95) * 1e3),
            "warm_hit_p95_ms": float(np.percentile(hit_t, 95) * 1e3)
            if hit_t else None,
            "warm_speedup": speedup,
            "admissions": len(cold_t),
            "warm_hit_admissions": len(hit_t),
        },
        "hit_tokens_mean": float(np.mean([h for h in warm_hits if h > 0]))
        if any(warm_hits) else 0.0,
        "prefix_hits": warm.metrics.prefix_hits,
        "prefix_hit_tokens": warm.metrics.prefix_hit_tokens,
        "bit_identical_to_cold": identical,
        "suffix_trace_growth_after_warmup": trace_growth,
        "pages": {"cached_after_drain": cached, "freed_by_clear": freed,
                  "leaked_warm": leaked, "leaked_cold": cold_leaked,
                  "live_refs_after_clear": live_refs},
    }
    report("prefix_ttft_cold_p50_ms", round(cold_ms, 2),
           f"{template_len}-token template, full prefill")
    report("prefix_ttft_warm_hit_p50_ms", round(hit_ms, 2),
           f"aliased pages + suffix-only prefill "
           f"(mean hit {results['hit_tokens_mean']:.0f} tokens)")
    report("prefix_ttft_speedup", round(speedup, 2),
           "cold / warm-hit admission wall time, equal batch")
    report("prefix_bit_identical", identical,
           "token streams, cached vs no-sharing")
    report("prefix_pages_leaked", leaked + cold_leaked + live_refs,
           "pages (+live refs) after drain + cache clear")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("prefix_bench_json", out, "full results")
    return results


# ---------------------------------------------------------------------------
# CI smoke gate
# ---------------------------------------------------------------------------


def smoke(*, arch: str = "granite-8b", out: str = "") -> int:
    """Tiny A/B run failing CI on the prefix-cache invariants: the >=5x
    warm-TTFT headline, stream bit-identity, the zero-leak / refcount
    drain, and trace-count stability across hit lengths."""
    res = run(lambda *a: None, arch=arch, n_templates=2, template_len=512,
              turns=3, rounds=2, budget=6, max_seq=1024, out=out)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    check("warm_ttft_5x", res["ttft"]["warm_speedup"] >= 5.0,
          f"{res['ttft']['warm_speedup']:.2f}x "
          f"(cold {res['ttft']['cold_p50_ms']:.2f}ms vs "
          f"hit {res['ttft']['warm_hit_p50_ms']:.2f}ms)")
    check("bit_identical", res["bit_identical_to_cold"],
          "cached vs no-sharing token streams")
    check("zero_leaks",
          res["pages"]["leaked_warm"] == 0
          and res["pages"]["leaked_cold"] == 0
          and res["pages"]["live_refs_after_clear"] == 0,
          res["pages"])
    check("hits_happened", res["prefix_hits"] > 0, res["prefix_hits"])
    check("no_trace_growth", res["suffix_trace_growth_after_warmup"] <= 2,
          f"{res['suffix_trace_growth_after_warmup']} new prefill traces "
          f"across {res['ttft']['warm_hit_admissions']} hit admissions")
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: prefix-cache speedup + identity + leak probes green")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--n-templates", type=int, default=2)
    ap.add_argument("--template-len", type=int, default=768)
    ap.add_argument("--turns", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fail on prefix-cache regressions")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_prefix.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch, out=args.out))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, n_templates=args.n_templates,
              template_len=args.template_len, turns=args.turns,
              rounds=args.rounds, budget=args.budget, max_seq=args.max_seq,
              seed=args.seed, out=args.out)
    print(f"# warm-prefix TTFT speedup {res['ttft']['warm_speedup']:.1f}x, "
          f"bit-identical={res['bit_identical_to_cold']}, "
          f"leaks={res['pages']['leaked_warm']}")


if __name__ == "__main__":
    main()
