"""Cluster-frontend benchmark: A/B the routing policies (round-robin,
least-loaded, power-of-two-choices, predicted-completion) over N live
``ServingEngine`` replicas under a Poisson, mixed-prompt-length workload.

    PYTHONPATH=src python benchmarks/cluster_bench.py [--replicas 2]
        [--requests 48] [--rate 0.6] [--out BENCH_cluster.json]
    PYTHONPATH=src python benchmarks/cluster_bench.py --smoke   # CI gate

Time is VIRTUAL: the drive loop advances ``now`` by one cost-model decode
tick (``estimate_decode(cfg, slots, window).latency_s``) per cluster step,
so TTFT/JCT measure *queueing structure* (how many cluster ticks a request
waited for a slot behind the policy's placement decisions), not CPU
wall-clock noise — the same determinism trick as the MISD simulator, but
over real engines doing real token work. Calibrating the virtual clock to
the cost model keeps the routing predictions and the observed latencies on
one scale, so the closed-loop residual correction is exercised for real
(latencies are REPORTED in ticks). Every policy replays the identical
workload on the SAME engine objects (reset between rounds, jit caches kept
warm), so the A/B isolates the routing decision.

``--smoke`` is the CI gate: a tiny 2-replica run asserting the cluster
preserves the engine's zero-recompile invariants (compile-count probes per
replica), the routing invariants (every replica sees traffic under
round-robin; predicted-completion routing is no worse than round-robin on
p99 TTFT and SLO goodput — and strictly better on at least one), that
token streams are bit-identical to single-engine serving, and that no
replica leaks pages across the run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import numpy as np

from repro.configs import get_config
from repro.core.costmodel import estimate_decode
from repro.core.mimd.router import POLICIES
from repro.models import init_params
from repro.serving import EngineConfig, ClusterFrontend, Request, ServingEngine


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------


def make_workload(n: int, *, rate: float, vocab: int, seed: int,
                  tick_s: float = 1.0, short_frac: float = 0.7,
                  models=("",)):
    """Poisson arrivals; bimodal prompt/budget mix (the survey's
    short-interactive vs long-context tension): short prompts with tight
    TTFT SLOs, long chunk-prefilled prompts with loose ones. ``rate`` and
    the SLOs are in TICKS (one cost-model decode step); ``tick_s``
    converts to the virtual-clock seconds the engines see. ``models``
    tags requests round-robin across pools (multi-model clusters)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n)) * tick_s
    reqs = []
    for i in range(n):
        if rng.random() < short_frac:
            plen = int(rng.integers(8, 25))
            budget = int(rng.integers(4, 9))
            slo = 6.0
        else:
            plen = int(rng.integers(48, 97))
            budget = int(rng.integers(24, 41))
            slo = 16.0
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=budget,
            arrival_time=float(arrivals[i]),
            ttft_slo_s=slo * tick_s,
            tpot_slo_s=2.0 * tick_s,
            model=models[i % len(models)],
        ))
    return reqs


# ---------------------------------------------------------------------------
# engine reuse across policy rounds
# ---------------------------------------------------------------------------


def build_engines(cfg, params, *, replicas: int, slots: int, window: int,
                  max_seq: int, sync_every: int, tick_s: float):
    # sla_s rides the virtual clock: the admission accumulator's flush
    # deadline must be ~a tick, not wall-clock milliseconds, or saturated
    # engines would batch admissions for hundreds of virtual ticks
    return [ServingEngine(cfg, params, EngineConfig(
                slots=slots, window=window, max_seq=max_seq,
                sync_every=sync_every, sla_s=4.0 * tick_s))
            for _ in range(replicas)]


def reset_engine(eng: ServingEngine):
    """Next policy round starts clean on the SAME engine object, keeping
    its jit caches (the A/B then never pays a recompile after round one)."""
    eng.reset()


# ---------------------------------------------------------------------------
# virtual-time drive
# ---------------------------------------------------------------------------


def drive(server, reqs, *, dt: float = 1.0, max_steps: int = 200_000):
    """Open-loop replay in virtual time: submit arrivals as the clock
    passes them, step the server once per dt. Works for a ClusterFrontend
    or a bare ServingEngine (the single-engine reference)."""
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    i, now, done = 0, 0.0, 0
    for _ in range(max_steps):
        while i < len(pending) and pending[i].arrival_time <= now:
            server.submit(pending[i], now)
            i += 1
        done += len(server.step(now))
        if done >= len(reqs):
            break
        now += dt
    else:
        raise RuntimeError(f"workload did not drain in {max_steps} steps "
                           f"({done}/{len(reqs)} finished)")
    server.drain(now)
    return now


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def run_policy(policy, engines, reqs, *, seed: int, tick_s: float = 1.0,
               pools=("",)):
    for eng in engines:
        reset_engine(eng)
    if len(pools) > 1:
        grouped = {m: [e for j, e in enumerate(engines)
                       if j % len(pools) == pools.index(m)] for m in pools}
        cluster = ClusterFrontend(grouped, policy=policy, seed=seed)
    else:
        cluster = ClusterFrontend(engines, policy=policy, seed=seed)
    makespan = drive(cluster, reqs, dt=tick_s) / tick_s
    m = cluster.merged_metrics()
    ttfts = np.asarray([r.ttft for r in reqs]) / tick_s  # -> ticks
    jcts = np.asarray([r.finish_time - r.arrival_time
                       for r in reqs]) / tick_s
    assert (ttfts >= 0).all() and m.completed == len(reqs)
    return {
        "ttft_p50": float(np.percentile(ttfts, 50)),
        "ttft_p99": float(np.percentile(ttfts, 99)),
        "jct_p50": float(np.percentile(jcts, 50)),
        "jct_p99": float(np.percentile(jcts, 99)),
        "goodput": m.goodput,
        "slo_met": m.slo_met,
        "slo_tracked": m.slo_tracked,
        "ttft_slo_misses": m.ttft_slo_misses,
        "tpot_slo_misses": m.tpot_slo_misses,
        "makespan": makespan,
        "throughput_tps": m.total_tokens / makespan if makespan else 0.0,
        "per_engine": {
            inst.name: {"routed": inst.routed,
                        "utilization": round(inst.utilization, 3),
                        "residual": round(inst.corrector.correction, 4)}
            for inst in cluster.instances
        },
        "outputs": {r.rid: list(r.output) for r in reqs},
        "pages_in_use": [e.allocator.pages_in_use if e.paged else 0
                         for e in engines],
        "prefill_traces": [e.prefill_traces for e in engines],
        "decode_traces": [e.decode_traces for e in engines],
    }


def single_engine_reference(eng, reqs, *, tick_s: float = 1.0):
    """The bit-identical oracle: the same requests through ONE engine.
    Greedy decoding is batching- and placement-invariant, so every cluster
    policy must reproduce these token streams exactly."""
    reset_engine(eng)
    drive(eng, reqs, dt=tick_s)
    return {r.rid: list(r.output) for r in reqs}


def run(report, *, arch="granite-8b", replicas=2, slots=2, window=128,
        max_seq=192, sync_every=4, requests=48, rate=0.6, seed=0,
        pools=1, out=""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    # virtual clock: 1 tick = one cost-model batched decode step, so the
    # engines' telemetry (backlog seconds) and the observed queueing
    # delays share a scale and the closed-loop corrector sees real signal
    tick_s = estimate_decode(cfg, slots, window).latency_s
    engines = build_engines(cfg, params, replicas=replicas, slots=slots,
                            window=window, max_seq=max_seq,
                            sync_every=sync_every, tick_s=tick_s)
    model_tags = tuple(f"m{i}" for i in range(pools)) if pools > 1 else ("",)
    if pools > 1:
        assert replicas >= pools, "need at least one replica per pool"

    results = {"arch": arch, "replicas": replicas, "slots": slots,
               "window": window, "max_seq": max_seq,
               "sync_every": sync_every, "requests": requests,
               "rate": rate, "seed": seed, "pools": pools,
               "tick_s": tick_s,
               **noise_report(),  # loadavg + thread pinning when measured
               "note": "virtual-time drive: one step per cost-model decode "
                       "tick; latencies reported in ticks, not CPU wall "
                       "clock",
               # typed, versioned replica telemetry (the wire shape a
               # remote frontend would consume; schema_version included)
               "replica_reports": [e.load_report().to_dict()
                                   for e in engines],
               "policies": {}}

    # bit-identical oracle (single pool only: one engine sees every prompt)
    reference = None
    if pools == 1:
        ref_reqs = make_workload(requests, rate=rate, vocab=cfg.vocab_size,
                                 seed=seed, tick_s=tick_s, models=model_tags)
        reference = single_engine_reference(engines[0], ref_reqs,
                                            tick_s=tick_s)

    for policy in POLICIES:
        reqs = make_workload(requests, rate=rate, vocab=cfg.vocab_size,
                             seed=seed, tick_s=tick_s, models=model_tags)
        res = run_policy(policy, engines, reqs, seed=seed, tick_s=tick_s,
                         pools=model_tags)
        res["bit_identical_to_single_engine"] = (
            res.pop("outputs") == reference if reference is not None
            else None)
        results["policies"][policy] = res
        report(f"cluster_ttft_p99_{policy}", round(res["ttft_p99"], 2),
               f"p50={res['ttft_p50']:.2f} goodput={res['goodput']:.3f} "
               f"jct_p99={res['jct_p99']:.2f}")

    rr = results["policies"]["round-robin"]
    pred = results["policies"]["predicted"]
    results["predicted_vs_round_robin"] = {
        "ttft_p99_ratio": (pred["ttft_p99"] / rr["ttft_p99"]
                           if rr["ttft_p99"] else 1.0),
        "goodput_delta": pred["goodput"] - rr["goodput"],
    }
    report("cluster_pred_vs_rr_ttft_p99_ratio",
           round(results["predicted_vs_round_robin"]["ttft_p99_ratio"], 3),
           "predicted-completion / round-robin (lower is better)")
    report("cluster_pred_vs_rr_goodput_delta",
           round(results["predicted_vs_round_robin"]["goodput_delta"], 3),
           "SLO goodput gain of predicted over round-robin")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("cluster_bench_json", out, "full results")
    return results


# ---------------------------------------------------------------------------
# CI smoke gate
# ---------------------------------------------------------------------------


def smoke(*, arch="granite-8b") -> int:
    """Tiny 2-replica run asserting the invariants a cluster PR can break
    while every per-engine test stays green."""
    res = run(lambda *a: None, arch=arch, replicas=2, slots=2, window=128,
              max_seq=192, sync_every=4, requests=24, rate=0.6, seed=0)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    for policy, r in res["policies"].items():
        check(f"{policy}_bit_identical", r["bit_identical_to_single_engine"],
              "token streams vs single-engine oracle")
        check(f"{policy}_no_page_leak", r["pages_in_use"] == [0, 0],
              f"pages_in_use={r['pages_in_use']}")
        check(f"{policy}_decode_traces", max(r["decode_traces"]) <= 2,
              f"{r['decode_traces']} (tick + fused scan per replica)")
        check(f"{policy}_prefill_traces", max(r["prefill_traces"]) <= 4,
              f"{r['prefill_traces']} (one per bucket per replica)")
    rr = res["policies"]["round-robin"]
    pred = res["policies"]["predicted"]
    check("rr_hits_every_replica",
          all(e["routed"] > 0 for e in rr["per_engine"].values()),
          {k: v["routed"] for k, v in rr["per_engine"].items()})
    check("predicted_ttft_p99_no_worse",
          pred["ttft_p99"] <= rr["ttft_p99"],
          f"pred={pred['ttft_p99']:.2f} rr={rr['ttft_p99']:.2f}")
    check("predicted_goodput_no_worse",
          pred["goodput"] >= rr["goodput"],
          f"pred={pred['goodput']:.3f} rr={rr['goodput']:.3f}")
    check("predicted_strictly_beats_rr_somewhere",
          (pred["ttft_p99"] < rr["ttft_p99"]
           or pred["goodput"] > rr["goodput"]),
          f"ttft_p99 {pred['ttft_p99']:.2f} vs {rr['ttft_p99']:.2f}, "
          f"goodput {pred['goodput']:.3f} vs {rr['goodput']:.3f}")
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: cluster routing + compile-count + stream-identity probes "
          "green")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--max-seq", type=int, default=192)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=0.6,
                    help="Poisson arrivals per virtual second")
    ap.add_argument("--pools", type=int, default=1,
                    help="model pools; engines and requests split across "
                         "them round-robin (multi-model cluster)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fail on routing/compile regressions")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_cluster.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, replicas=args.replicas,
              slots=args.slots, window=args.window, max_seq=args.max_seq,
              sync_every=args.sync_every, requests=args.requests,
              rate=args.rate, seed=args.seed, pools=args.pools,
              out=args.out)
    cmp = res["predicted_vs_round_robin"]
    print(f"# predicted vs round-robin: p99 TTFT x{cmp['ttft_p99_ratio']:.2f}"
          f", goodput {cmp['goodput_delta']:+.3f}")


if __name__ == "__main__":
    main()
