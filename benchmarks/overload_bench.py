"""Overload / multi-tenant robustness benchmark: drive a replicated
cluster past its knee with a low-tier tenant flood and prove the
overload stack (SLO tiers + weighted-fair DRR queueing + degradation
ladder) protects the paying tier where a flat-EDF frontend does not.

    PYTHONPATH=src python benchmarks/overload_bench.py
        [--arch granite-8b] [--out BENCH_overload.json]
    PYTHONPATH=src python benchmarks/overload_bench.py --smoke

Three rounds over the SAME seeded workload (fresh engines each):

  baseline    — steady gold+silver traffic only (no flood), fair
                frontend: the unloaded goodput reference.
  unprotected — flat-EDF frontend (tenant tags stripped, no ladder),
                flood ON: shows the failure mode the stack exists for
                (reported, not gated — EDF happens to be a decent
                scheduler; the contrast column, not the proof).
  protected   — tenants + OverloadDetector + paced DRR dispatch +
                token-bucket admission, flood ON: the gated round.

Acceptance gates (smoke and full):

  retention      gold (protected-tier) goodput under the flood >= 0.9x
                 its unloaded baseline.
  no_starvation  the DRR queue's observed worst grants-to-service
                 (``max_wait_rounds``) stays within its provable
                 ``starvation_bound`` — zero starved tenants.
  bit_identical  every FINISHED stream equals the single-engine
                 unloaded reference for that request; browned-out
                 streams are exact PREFIXES of the reference.
  typed_rejects  every rejection carries a finite retry_after_s > 0.
  ladder         the detector actually walked the ladder (transitions
                 recorded; shed or brownout or reject happened).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import numpy as np

from repro.configs import get_config
from repro.core.costmodel import estimate_decode, estimate_prefill
from repro.models import init_params
from repro.serving import (
    ClusterFrontend,
    EngineConfig,
    OverloadDetector,
    Request,
    ServingEngine,
    TenantClass,
    request_cost,
)

TTFT_SLO = 10.0  # virtual-seconds first-token SLO the gold tier declares


def tenant_classes():
    """gold is the protected (top) tier; bulk is first on the ladder."""
    return {
        "gold": TenantClass("gold", tier=2, weight=4.0),
        "silver": TenantClass("silver", tier=1, weight=2.0,
                              brownout_frac=0.5),
        "bulk": TenantClass("bulk", tier=0, weight=1.0,
                            rate_tokens_s=256.0, burst_tokens=2048.0),
    }


def make_workload(*, vocab, seed, gold=12, silver=8, bulk=48,
                  flood_t0=10.0, flood_rate=2.0):
    """Steady gold/silver arrivals plus a bulk burst from ``flood_t0``
    at ``flood_rate`` requests per virtual second — several times the
    cluster's token drain rate."""
    rng = np.random.default_rng(seed)
    reqs = []

    def mk(rid, tenant, t, plen, budget, slo):
        return Request(
            rid=rid,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=budget, arrival_time=float(t),
            tenant=tenant, ttft_slo_s=slo)

    rid = 0
    for i in range(gold):
        reqs.append(mk(rid, "gold", 2.0 + 6.0 * i,
                       int(rng.integers(8, 17)),
                       int(rng.integers(8, 13)), TTFT_SLO))
        rid += 1
    for i in range(silver):
        reqs.append(mk(rid, "silver", 4.0 + 9.0 * i,
                       int(rng.integers(8, 17)),
                       int(rng.integers(8, 13)), 2.0 * TTFT_SLO))
        rid += 1
    for i in range(bulk):
        reqs.append(mk(rid, "bulk", flood_t0 + i / flood_rate,
                       int(rng.integers(12, 25)),
                       int(rng.integers(10, 17)), 0.0))
        rid += 1
    return reqs


def offered_over_capacity(cfg, reqs, *, replicas, flood_t0, flood_rate):
    """Offered token load during the flood window vs the cluster's
    cost-model drain rate — the 'Nx capacity' headline."""
    bulk = [r for r in reqs if r.tenant == "bulk"]
    toks = sum(request_cost(r) for r in bulk)
    window_s = len(bulk) / flood_rate
    dec = estimate_decode(cfg, 1, 128).latency_s
    pre = estimate_prefill(cfg, 1, 16).latency_s
    mean_cost = np.mean([request_cost(r) for r in bulk])
    svc_s = pre + dec * (mean_cost - 16)  # per-request modeled service
    cap_rps = replicas / svc_s  # requests/s the pool can model-drain
    # offered requests per VIRTUAL second vs what one virtual second of
    # stepping drains (1 batched tick per replica per second here)
    drain_tokens_per_s = replicas * 2.0  # slots tokens per tick
    offered_tokens_per_s = toks / window_s
    return {
        "flood_requests": len(bulk),
        "offered_tokens_per_s": float(offered_tokens_per_s),
        "drain_tokens_per_s": float(drain_tokens_per_s),
        "ratio": float(offered_tokens_per_s / drain_tokens_per_s),
        "modeled_service_s_per_request": float(svc_s),
        "modeled_capacity_rps": float(cap_rps),
    }


def build_cluster(cfg, params, *, replicas, protected, backlog_high_s,
                  seed=0):
    engines = [ServingEngine(cfg, params, EngineConfig(
        slots=2, window=128, max_seq=192, sync_every=4))
        for _ in range(replicas)]
    if not protected:
        return ClusterFrontend(engines, policy="predicted", seed=seed), None
    det = OverloadDetector(ttft_slo_s=TTFT_SLO,
                           backlog_high_s=backlog_high_s,
                           period_s=2.0, patience=2, relax_patience=6,
                           min_window=4)
    fe = ClusterFrontend(engines, policy="predicted", seed=seed,
                         tenants=tenant_classes(), overload=det,
                         fair_quantum=64.0)
    return fe, det


def drive(fe, reqs, *, dt=1.0, max_steps=4000):
    pending = sorted(reqs, key=lambda r: (r.arrival_time, r.rid))
    resolved = {}
    i, now = 0, 0.0
    for _ in range(max_steps):
        while i < len(pending) and pending[i].arrival_time <= now:
            fe.submit(pending[i], now)
            i += 1
        for req in fe.step(now):
            resolved[req.rid] = req
        if i >= len(pending) and len(resolved) >= len(pending):
            break
        now += dt
    for req in fe.drain(now):
        resolved.setdefault(req.rid, req)
    return resolved, now


def reference_streams(cfg, params, reqs, *, max_steps=6000):
    """Unloaded single-engine greedy reference for every request (same
    rid/prompt/budget, no tenancy): the bit-identity ground truth —
    streams must not depend on the overload machinery's routing, pacing,
    or ladder decisions."""
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=128, max_seq=192, sync_every=4))
    clones = [Request(rid=r.rid, prompt=r.prompt.copy(),
                      max_new_tokens=r.max_new_tokens) for r in reqs]
    out = {}
    now = 0.0
    queue = list(clones)
    while queue or not eng.idle:
        while queue and eng.submit(queue[0], now):
            queue.pop(0)
        for req in eng.step(now):
            out[req.rid] = list(map(int, req.output))
        now += 1.0
        if now > max_steps:
            raise RuntimeError("reference run did not converge")
    for req in eng.drain(now):
        out[req.rid] = list(map(int, req.output))
    return out


def goodput_by_tenant(resolved, reqs):
    """met-SLO fraction per tenant over its SLO-tracked submissions
    (unfinished/shed/rejected tracked requests count as misses — the
    client-visible definition)."""
    out = {}
    for tenant in sorted({r.tenant for r in reqs}):
        tracked = [r for r in reqs
                   if r.tenant == tenant and r.ttft_slo_s > 0]
        if not tracked:
            continue
        met = sum(1 for r in tracked
                  if (res := resolved.get(r.rid)) is not None
                  and res.meets_slo() is True)
        out[tenant] = {"tracked": len(tracked), "met": met,
                       "goodput": met / len(tracked)}
    return out


def audit_streams(resolved, ref):
    """Every FINISHED stream must equal the reference; a browned-out
    stream must be an exact PREFIX of it."""
    mismatches, prefixes, full = [], 0, 0
    for rid, req in resolved.items():
        if req.state.value != "finished":
            continue
        got = list(map(int, req.output))
        want = ref[rid]
        if req.browned_out_tokens:
            if got != want[:len(got)]:
                mismatches.append(rid)
            else:
                prefixes += 1
        elif got != want:
            mismatches.append(rid)
        else:
            full += 1
    return {"finished": prefixes + full + len(mismatches),
            "full_matches": full, "prefix_matches": prefixes,
            "mismatched_rids": mismatches}


def audit_rejections(resolved):
    rejects = [r for r in resolved.values()
               if r.state.value == "failed"
               and r.fail_reason.startswith("rejected")]
    sheds = [r for r in resolved.values()
             if r.fail_reason.startswith("shed: overload ladder")]
    bad = [r.rid for r in rejects + sheds
           if not (r.retry_after_s > 0 and math.isfinite(r.retry_after_s))]
    return {"rejected": len(rejects), "ladder_shed": len(sheds),
            "missing_retry_after_rids": bad}


def run(report, *, arch="granite-8b", replicas=2, seed=0,
        gold=12, silver=8, bulk=48, out=""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    flood_t0, flood_rate = 10.0, 2.0
    mk = lambda: make_workload(vocab=cfg.vocab_size, seed=seed, gold=gold,
                               silver=silver, bulk=bulk, flood_t0=flood_t0,
                               flood_rate=flood_rate)
    # overload threshold: ~4 mean requests of modeled backlog per replica
    probe = mk()
    dec = estimate_decode(cfg, 1, 128).latency_s
    pre = estimate_prefill(cfg, 1, 16).latency_s
    mean_cost = float(np.mean([request_cost(r) for r in probe]))
    backlog_high_s = 4.0 * (pre + dec * mean_cost)
    results = {"arch": arch, "replicas": replicas, "seed": seed,
               "backlog_high_s": backlog_high_s, **noise_report()}
    results["load"] = offered_over_capacity(
        cfg, probe, replicas=replicas, flood_t0=flood_t0,
        flood_rate=flood_rate)
    report("overload_flood_over_capacity",
           round(results["load"]["ratio"], 2),
           f"{results['load']['flood_requests']} bulk requests")

    ref = reference_streams(cfg, params, probe)

    # -- round 1: unloaded baseline (fair stack on, no flood) -------------
    steady = [r for r in mk() if r.tenant != "bulk"]
    fe, _ = build_cluster(cfg, params, replicas=replicas, protected=True,
                          backlog_high_s=backlog_high_s, seed=seed)
    resolved, _ = drive(fe, steady)
    results["baseline"] = {"goodput": goodput_by_tenant(resolved, steady),
                           "streams": audit_streams(resolved, ref)}

    # -- round 2: flat EDF, flood on (the contrast column) ----------------
    flat = mk()
    for r in flat:
        r.tenant = ""  # untagged: the exact pre-fair flat-EDF frontend
    fe, _ = build_cluster(cfg, params, replicas=replicas, protected=False,
                          backlog_high_s=backlog_high_s, seed=seed)
    resolved, _ = drive(fe, flat)
    by_rid_tenant = {r.rid: r.tenant for r in probe}
    tagged = [Request(rid=r.rid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens,
                      arrival_time=r.arrival_time,
                      tenant=by_rid_tenant[r.rid],
                      ttft_slo_s=r.ttft_slo_s) for r in flat]
    results["unprotected"] = {
        "goodput": goodput_by_tenant(resolved, tagged)}

    # -- round 3: full overload stack, flood on (the gated round) ---------
    flood = mk()
    fe, det = build_cluster(cfg, params, replicas=replicas, protected=True,
                            backlog_high_s=backlog_high_s, seed=seed)
    resolved, end_t = drive(fe, flood)
    merged = fe.merged_metrics()
    max_cost = max(request_cost(r) for r in probe)
    results["protected"] = {
        "goodput": goodput_by_tenant(resolved, flood),
        "streams": audit_streams(resolved, ref),
        "rejections": audit_rejections(resolved),
        "ladder_transitions": det.transitions,
        "peak_level": max([lvl for _, lvl in det.transitions] or [0]),
        "shed": merged.shed, "browned_out": merged.browned_out,
        "rejected": merged.rejected,
        "max_wait_rounds": fe._queue.max_wait_rounds,
        "starvation_bound": fe._queue.starvation_bound(max_cost),
        "tenant_counters": {
            name: {f: getattr(tm, f) for f in type(tm)._COUNTERS}
            for name, tm in sorted(merged.tenants.items())},
        "end_t": end_t,
    }
    p, b = results["protected"], results["baseline"]
    gold_base = b["goodput"]["gold"]["goodput"]
    gold_flood = p["goodput"]["gold"]["goodput"]
    p["gold_retention"] = (gold_flood / gold_base) if gold_base else 0.0
    report("overload_gold_goodput_baseline", round(gold_base, 4), "")
    report("overload_gold_goodput_flood", round(gold_flood, 4),
           f"retention {p['gold_retention']:.3f} (gate >= 0.9)")
    u = results["unprotected"]["goodput"].get("gold", {})
    report("overload_gold_goodput_flat_edf",
           round(u.get("goodput", 0.0), 4), "contrast, ungated")
    report("overload_ladder",
           "->".join(str(lvl) for _, lvl in det.transitions) or "none",
           f"shed={merged.shed} browned_out={merged.browned_out} "
           f"rejected={merged.rejected}")
    report("overload_drr_wait_rounds", p["max_wait_rounds"],
           f"bound {p['starvation_bound']}")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2, default=str)
        report("overload_bench_json", out, "full results")
    return results


def smoke(*, arch="granite-8b") -> int:
    res = run(lambda *a: None, arch=arch, gold=10, silver=6, bulk=36)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    p = res["protected"]
    check("flood_exceeds_capacity", res["load"]["ratio"] >= 3.0,
          f"offered/drain {res['load']['ratio']:.2f}x (want >= 3x)")
    check("gold_retention", p["gold_retention"] >= 0.9,
          f"{p['gold_retention']:.3f} (gate >= 0.9)")
    check("ladder_engaged",
          len(p["ladder_transitions"]) > 0
          and (p["shed"] + p["browned_out"] + p["rejected"]) > 0,
          f"transitions={p['ladder_transitions']} shed={p['shed']} "
          f"browned={p['browned_out']} rejected={p['rejected']}")
    check("no_starvation",
          p["max_wait_rounds"] <= p["starvation_bound"],
          f"max_wait_rounds {p['max_wait_rounds']} <= "
          f"bound {p['starvation_bound']}")
    for round_name in ("baseline", "protected"):
        s = res[round_name]["streams"]
        check(f"bit_identical_{round_name}",
              s["mismatched_rids"] == [] and s["finished"] > 0,
              f"{s['full_matches']} full + {s['prefix_matches']} prefix "
              f"of {s['finished']}")
    rj = p["rejections"]
    check("typed_rejections", rj["missing_retry_after_rids"] == [],
          f"{rj['rejected']} rejects + {rj['ladder_shed']} sheds, "
          f"{len(rj['missing_retry_after_rids'])} missing retry_after")
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: overload gates green — retention, fairness, "
          "bit-identity, typed retry-after")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--gold", type=int, default=12)
    ap.add_argument("--silver", type=int, default=8)
    ap.add_argument("--bulk", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: retention/fairness/identity/retry-after")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_overload.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, replicas=args.replicas,
              gold=args.gold, silver=args.silver, bulk=args.bulk,
              seed=args.seed, out=args.out)
    p = res["protected"]
    print(f"# gold retention {p['gold_retention']:.3f} under "
          f"{res['load']['ratio']:.1f}x flood; ladder "
          f"{p['ladder_transitions']}; drr wait {p['max_wait_rounds']}"
          f"/{p['starvation_bound']}")


if __name__ == "__main__":
    main()
