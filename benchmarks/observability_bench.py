"""Observability benchmark: proves the new tracing/metrics layer is
*accurate* (histogram percentiles match raw-sample percentiles, traces
account for every request's lifecycle) and *cheap* (decode tok/s with
tracing on stays within a few percent of tracing off).

    PYTHONPATH=src python benchmarks/observability_bench.py
        [--arch granite-8b] [--out BENCH_observability.json]
    PYTHONPATH=src python benchmarks/observability_bench.py --smoke

Sections:

  parity    — one seeded serving run recorded twice: per-request raw
              latency lists (ground truth) vs the engine's bounded
              ServeMetrics histograms. p50/p90/p99 must agree within one
              bucket width (the histogram's design guarantee).
  accounting— a small chaos run (kill + churn) exported as Chrome-trace
              JSON; every request thread in the document must carry the
              full lifecycle (queued -> prefill -> decode), including at
              least one failover_retry and one preempt/restore.
  overhead  — interleaved A/B rounds (tracing off / on) of steady-state
              fused-window decode on otherwise identical engines; the
              median tok/s ratio is the headline number. Acceptance:
              >= 0.97 in the full bench; the smoke gate is 0.90 to stay
              robust on noisy CI runners.
  identity  — the same seeded sampled workload with tracing on vs off
              must produce bit-identical token streams (tracing is pure
              host bookkeeping).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    EngineConfig,
    Request,
    SamplingParams,
    ServingEngine,
)


def make_workload(n, *, vocab, seed, budget=(8, 17), plen=(8, 33),
                  rate=0.8):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(
        rid=i,
        prompt=rng.integers(0, vocab, int(rng.integers(*plen)))
                  .astype(np.int32),
        max_new_tokens=int(rng.integers(*budget)),
        arrival_time=float(arrivals[i]),
        sampling=SamplingParams(temperature=0.7, top_k=20, top_p=0.95,
                                seed=7000 + i),
    ) for i in range(n)]


def drive(eng, reqs, *, dt=1.0, max_steps=100_000):
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    resolved = {}
    i, now = 0, 0.0
    for _ in range(max_steps):
        while i < len(pending) and pending[i].arrival_time <= now:
            eng.submit(pending[i], now)
            i += 1
        for req in eng.step(now):
            resolved[req.rid] = req
        if len(resolved) >= len(reqs):
            break
        now += dt
    for req in eng.drain(now):
        resolved[req.rid] = req
    return resolved


# ---------------------------------------------------------------------------
# parity: histogram percentiles vs raw-sample percentiles
# ---------------------------------------------------------------------------


def percentile_parity(cfg, params, *, requests, seed):
    """Drive one workload, compare ServeMetrics histogram percentiles
    against np.percentile over the raw per-request samples. The histogram
    guarantee is 'within the containing bucket', so the gate is bucket
    distance <= 1 between the two answers."""
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=128, max_seq=192, sync_every=4, tracing=True))
    resolved = drive(eng, make_workload(requests, vocab=cfg.vocab_size,
                                        seed=seed))
    done = [r for r in resolved.values() if r.finish_time >= 0]
    raw = {
        "ttft": [r.ttft for r in done if r.ttft >= 0],
        "tpot": [r.tpot for r in done if r.tpot > 0],
        "jct": [r.finish_time - r.arrival_time for r in done],
    }
    hists = {"ttft": eng.metrics.ttfts, "tpot": eng.metrics.tpots,
             "jct": eng.metrics.jcts}
    out = {}
    for name, samples in raw.items():
        h = hists[name]
        rows = {"n_raw": len(samples), "n_hist": h.count, "quantiles": {}}
        for q in (50, 90, 99):
            want = float(np.percentile(samples, q)) if samples else 0.0
            got = h.percentile(q)
            dist = abs(h.bucket_index(got) - h.bucket_index(want))
            rows["quantiles"][f"p{q}"] = {
                "raw": want, "hist": got, "bucket_distance": dist}
        out[name] = rows
    out["max_bucket_distance"] = max(
        row["bucket_distance"]
        for m in raw for row in out[m]["quantiles"].values())
    out["counts_match"] = all(out[m]["n_raw"] == out[m]["n_hist"]
                              for m in raw)
    return out


# ---------------------------------------------------------------------------
# accounting: exported chaos trace covers every request lifecycle
# ---------------------------------------------------------------------------


def trace_accounting(*, arch, requests, trace_path):
    """Run the chaos harness (kill + hang + churn) with trace export and
    audit the *artifact*: parse the Chrome-trace JSON back and require
    each request thread to show the queued -> prefill -> decode
    lifecycle, plus the fault markers the scenario guarantees."""
    from chaos_bench import run as chaos_run

    res = chaos_run(lambda *a: None, arch=arch, replicas=4, slots=2,
                    window=128, max_seq=192, sync_every=4,
                    requests=requests, rate=0.8, seed=0,
                    rounds=("kill", "hang"), churn=True, out="",
                    trace_out=trace_path)
    with open(trace_path) as f:
        doc = json.load(f)
    threads = {}  # (pid, tid) -> set of span names
    for ev in doc["traceEvents"]:
        if ev["ph"] in ("X", "i"):
            threads.setdefault((ev["pid"], ev["tid"]), set()).add(ev["name"])
    lifecycle = {"queued", "prefill", "decode"}
    missing = [k for k, kinds in threads.items()
               if not lifecycle <= kinds]
    all_kinds = set().union(*threads.values()) if threads else set()
    return {
        "events": len(doc["traceEvents"]),
        "request_threads": len(threads),
        "threads_missing_lifecycle": len(missing),
        "has_failover_retry": "failover_retry" in all_kinds,
        "has_preempt_restore": {"preempt", "restore"} <= all_kinds,
        "span_problems": res["trace"]["span_problems"],
        "doc_problems": res["trace"].get("doc_problems", []),
    }


# ---------------------------------------------------------------------------
# overhead: tracing on vs off, interleaved A/B decode rounds
# ---------------------------------------------------------------------------


def _prime(eng, slots, plen, vocab, budget, *, seed, tracing):
    eng.drain(0.0)
    for i, r in enumerate(eng.active):
        if r is not None:
            eng.release_slot(i)
    rng = np.random.default_rng(seed)
    for i in range(slots):
        req = Request(rid=i,
                      prompt=rng.integers(0, vocab, plen).astype(np.int32),
                      max_new_tokens=budget)
        assert eng.try_admit(req, now=0.0)
    for _ in range(2):
        eng.step(0.0)
    jax.block_until_ready(eng.cache)


def _measure(eng, slots, ticks):
    done = 0
    t0 = time.perf_counter()
    while done < ticks:
        c0 = eng.metrics.decode_ticks
        eng.step(0.0)
        n = eng.metrics.decode_ticks - c0
        if n == 0 and not any(eng.decoding):
            break
        done += n
    eng.drain(0.0)
    jax.block_until_ready(eng.cache)
    return done * slots / (time.perf_counter() - t0)


def overhead(cfg, params, *, slots=4, window=256, ticks=64, rounds=5,
             sync_every=16):
    """Median decode tok/s ratio, tracing on / tracing off, from
    interleaved rounds on two engines that differ only in the tracing
    flag (so drift in machine load hits both)."""
    prompt_len = 32
    budget = window - prompt_len
    assert budget >= 3 * sync_every + ticks
    engines = {
        False: ServingEngine(cfg, params, EngineConfig(
            slots=slots, window=window, sync_every=sync_every)),
        True: ServingEngine(cfg, params, EngineConfig(
            slots=slots, window=window, sync_every=sync_every,
            tracing=True)),
    }
    tps = {False: [], True: []}
    for r in range(rounds):
        for tracing in (False, True):
            eng = engines[tracing]
            _prime(eng, slots, prompt_len, cfg.vocab_size, budget,
                   seed=r, tracing=tracing)
            tps[tracing].append(_measure(eng, slots, ticks))
    off = float(np.median(tps[False]))
    on = float(np.median(tps[True]))
    return {
        "decode_tps_tracing_off": off,
        "decode_tps_tracing_on": on,
        "ratio": on / off if off else 0.0,
        "rounds_off": tps[False],
        "rounds_on": tps[True],
        "meets_0p97": (on / off >= 0.97) if off else False,
    }


# ---------------------------------------------------------------------------
# identity: tracing cannot change a single token
# ---------------------------------------------------------------------------


def bit_identity(cfg, params, *, requests, seed):
    outs = {}
    for tracing in (False, True):
        eng = ServingEngine(cfg, params, EngineConfig(
            slots=2, window=128, max_seq=192, sync_every=4,
            tracing=tracing))
        resolved = drive(eng, make_workload(requests, vocab=cfg.vocab_size,
                                            seed=seed))
        outs[tracing] = {rid: list(map(int, r.output))
                         for rid, r in resolved.items()}
    return {"identical": outs[False] == outs[True],
            "requests": len(outs[False])}


# ---------------------------------------------------------------------------
# full bench / smoke
# ---------------------------------------------------------------------------


def run(report, *, arch="granite-8b", requests=32, rounds=5, ticks=64,
        seed=0, out="", trace_out=""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    results = {"arch": arch, "requests": requests, "rounds": rounds,
               "ticks": ticks, "seed": seed, **noise_report()}

    results["parity"] = percentile_parity(cfg, params, requests=requests,
                                          seed=seed)
    report("obs_parity_max_bucket_distance",
           results["parity"]["max_bucket_distance"],
           "histogram vs raw percentiles (gate <= 1)")

    trace_path = trace_out or os.path.join(
        os.path.dirname(__file__), "..", "TRACE_chaos.json")
    results["accounting"] = trace_accounting(
        arch=arch, requests=max(16, requests // 2), trace_path=trace_path)
    a = results["accounting"]
    report("obs_trace_threads", a["request_threads"],
           f"missing_lifecycle={a['threads_missing_lifecycle']} "
           f"failover={a['has_failover_retry']} "
           f"preempt/restore={a['has_preempt_restore']}")

    results["identity"] = bit_identity(cfg, params, requests=requests,
                                       seed=seed)
    report("obs_bit_identical", results["identity"]["identical"],
           "streams tracing on vs off")

    results["overhead"] = overhead(cfg, params, ticks=ticks, rounds=rounds)
    o = results["overhead"]
    report("obs_tracing_overhead_ratio", round(o["ratio"], 4),
           f"on={o['decode_tps_tracing_on']:.1f} "
           f"off={o['decode_tps_tracing_off']:.1f} tok/s "
           f"(acceptance >= 0.97: {o['meets_0p97']})")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("observability_bench_json", out, "full results")
    return results


def smoke(*, arch="granite-8b") -> int:
    """CI gate: parity within one bucket, full lifecycle accounting in
    the exported trace, bit-identical streams, and tracing overhead
    bounded at 0.90 (the acceptance number 0.97 is re-measured by the
    full bench on a quiet machine — CI runners are too noisy to gate
    that tightly)."""
    res = run(lambda *a: None, arch=arch, requests=24, rounds=3, ticks=48)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    p = res["parity"]
    check("parity_within_one_bucket", p["max_bucket_distance"] <= 1,
          f"max bucket distance {p['max_bucket_distance']}")
    check("parity_counts_match", p["counts_match"],
          "histogram count == raw sample count")
    a = res["accounting"]
    check("trace_valid", a["doc_problems"] == [] and a["span_problems"] == [],
          f"doc={a['doc_problems'][:2]} span={a['span_problems'][:2]}")
    check("trace_full_lifecycle",
          a["request_threads"] > 0 and a["threads_missing_lifecycle"] == 0,
          f"{a['threads_missing_lifecycle']} of {a['request_threads']} "
          f"threads missing queued/prefill/decode")
    check("trace_failover", a["has_failover_retry"], "failover_retry span")
    check("trace_preempt_restore", a["has_preempt_restore"],
          "preempt+restore spans")
    check("bit_identical", res["identity"]["identical"],
          "streams tracing on vs off")
    o = res["overhead"]
    check("overhead_bounded", o["ratio"] >= 0.90,
          f"ratio {o['ratio']:.4f} (smoke gate 0.90, acceptance 0.97)")
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: observability gates green — parity, accounting, "
          "identity, bounded overhead")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: parity/accounting/identity/overhead")
    ap.add_argument("--trace-out", default="",
                    help="where the accounting section writes its "
                         "Chrome-trace JSON (default TRACE_chaos.json)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_observability.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, requests=args.requests,
              rounds=args.rounds, ticks=args.ticks, seed=args.seed,
              out=args.out, trace_out=args.trace_out)
    o = res["overhead"]
    print(f"# tracing overhead: {o['ratio']:.4f}x decode tok/s "
          f"(acceptance >= 0.97: {o['meets_0p97']}); parity max bucket "
          f"distance {res['parity']['max_bucket_distance']}")


if __name__ == "__main__":
    main()
