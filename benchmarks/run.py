"""Benchmark harness: one function per survey table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,...]

Prints ``name,value,derived`` CSV rows; each module reproduces one of the
survey's quantitative artifacts over our own serving stack (DESIGN.md §8).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def report(name, value, derived=""):
    print(f"{name},{value},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,table1,fig7,roofline,micro,serving")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def on(key):
        return want is None or key in want

    print("name,value,derived")
    if on("fig3"):
        from benchmarks import fig3_colocation

        fig3_colocation.run(report)
    if on("fig4"):
        from benchmarks import fig4_power

        fig4_power.run(report)
    if on("table1"):
        from benchmarks import table1_schedulers

        table1_schedulers.run(report)
    if on("fig7"):
        from benchmarks import fig7_dlrm

        fig7_dlrm.run(report)
    if on("roofline"):
        from benchmarks import roofline

        roofline.run(report)
    if on("micro"):
        from benchmarks import microbench

        microbench.run(report)
    if on("serving"):
        from benchmarks import serving_bench

        serving_bench.run(report)


if __name__ == "__main__":
    main()
