"""Quantized-serving A/B benchmark: int8 KV-cache pages (fused-dequant
paged decode) vs the lossless f32 pool on the SAME workload.

    PYTHONPATH=src python benchmarks/quant_bench.py [--arch granite-8b]
        [--requests 8] [--budget 16] [--rounds 3] [--out BENCH_quant.json]
    PYTHONPATH=src python benchmarks/quant_bench.py --smoke   # CI gate

What it measures / gates (--smoke fails CI on these):

  * decode throughput: tok/s over full continuous-batching decode, A/B
    interleaved across rounds — the int8 path (inline VMEM dequant next
    to the scalar-prefetched page table) must hold >= 0.9x of f32;
  * capacity: ``plan_admission`` slots at an EQUAL KV HBM budget — int8
    pages (1 byte/elem + one fp32 scale per vector) must buy >= 1.8x
    the concurrent slots of the f32 pool;
  * stream divergence under greedy AND seeded-sampled decode: token
    edit distance + first-divergence position per request vs the f32
    engine. Prefill attends over exact pre-quantization K/V, so token 1
    is ALWAYS bit-identical (gated); later tokens may drift (reported);
  * kernel error-vs-bound: the fused-dequant kernel's deviation from
    exact f32 attention stays inside the sort-free closed-form bound
    from kernels/ref.py, including an exact-score-tie case.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_noise import noise_report, pin_host_threads

pin_host_threads()  # must precede the first jax import

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.costmodel import kv_bytes_per_token
from repro.core.misd.batching import plan_admission
from repro.kernels import ops, ref
from repro.models import init_params
from repro.models.blocks import dequantize_kv, quantize_kv
from repro.serving import (
    EngineConfig,
    PrecisionConfig,
    Request,
    SamplingParams,
    ServingEngine,
)

RID = iter(range(10 ** 9))


# ---------------------------------------------------------------------------
# stream divergence stats
# ---------------------------------------------------------------------------


def edit_distance(a, b) -> int:
    """Plain Levenshtein over token ids (streams are short)."""
    prev = list(range(len(b) + 1))
    for i, x in enumerate(a, 1):
        cur = [i]
        for j, y in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1,
                           prev[j - 1] + (x != y)))
        prev = cur
    return prev[-1]


def first_divergence(a, b) -> int:
    """Index of the first differing token; -1 if the streams agree."""
    for i, (x, y) in enumerate(zip(a, b)):
        if x != y:
            return i
    return -1 if len(a) == len(b) else min(len(a), len(b))


def divergence_stats(f32_streams, i8_streams, budget: int) -> dict:
    eds = [edit_distance(a, b) for a, b in zip(f32_streams, i8_streams)]
    fds = [first_divergence(a, b) for a, b in zip(f32_streams, i8_streams)]
    diverged = [f for f in fds if f >= 0]
    return {
        "requests": len(eds),
        "identical_streams": sum(f < 0 for f in fds),
        "edit_distance_mean": float(np.mean(eds)),
        "edit_distance_max": int(max(eds)),
        "edit_distance_budget_frac": float(np.mean(eds)) / budget,
        # -1 entries (bit-identical) excluded from the position stats
        "first_divergence_min": int(min(diverged)) if diverged else -1,
        "first_divergence_mean": float(np.mean(diverged)) if diverged
        else -1.0,
    }


# ---------------------------------------------------------------------------
# engine A/B
# ---------------------------------------------------------------------------


def _mk_engine(cfg, params, *, int8: bool, slots: int, window: int):
    pr = PrecisionConfig(kv_cache_dtype="int8" if int8 else "")
    return ServingEngine(cfg, params, EngineConfig(
        slots=slots, window=window, max_seq=window, paged=True,
        chunk_prefill=0, sync_every=4, precision=pr))


def serve_all(eng, prompts, budget: int, sampled: bool):
    """Continuous-batching run to completion. Returns (streams,
    decode-wall-seconds): the clock starts after every admission's
    prefill has retired, so it prices the decode ticks the int8 kernel
    actually changes."""
    reqs = []
    for p in prompts:
        samp = (SamplingParams(temperature=0.7, top_k=20, top_p=0.95,
                               seed=1000 + len(reqs)) if sampled
                else SamplingParams())
        reqs.append(Request(next(RID), p.copy(), max_new_tokens=budget,
                            sampling=samp))
    pending = list(reqs)
    t = 0.0
    while pending and eng.try_admit(pending[0], t):
        pending.pop(0)
    jax.block_until_ready(eng.cache)
    t0 = time.perf_counter()
    while not all(r.done for r in reqs):
        while pending and eng.try_admit(pending[0], t):
            pending.pop(0)
        t += 1.0
        eng.step(t)
    jax.block_until_ready(eng.cache)
    wall = time.perf_counter() - t0
    eng.drain(t)
    return [list(r.output) for r in reqs], wall


# ---------------------------------------------------------------------------
# kernel error-vs-bound probe
# ---------------------------------------------------------------------------


def kernel_bound_probe(seeds=(1, 7, 23), d=64) -> dict:
    """Max observed output error / closed-form bound across random draws
    (must stay <= 1), plus the exact-tie case (identical keys -> the
    kernel must agree with the sort-free oracle to f32 tolerance)."""
    b, h, kv, ps, n_pages = 2, 4, 2, 8, 4
    w = ps * n_pages
    worst = 0.0
    for seed in seeds:
        key = jax.random.key(seed)
        kq_, kk_, kv_ = jax.random.split(key, 3)
        q = jax.random.normal(kq_, (b, 1, h, d), jnp.float32)
        kc = jax.random.normal(kk_, (b, w, kv, d), jnp.float32)
        vc = jax.random.normal(kv_, (b, w, kv, d), jnp.float32)
        k_pool = kc.reshape(b * n_pages, ps, kv, d)
        v_pool = vc.reshape(b * n_pages, ps, kv, d)
        table = jnp.arange(b * n_pages, dtype=jnp.int32).reshape(b, n_pages)
        pos = jnp.asarray([w // 3, w], jnp.int32)
        kq, ks = quantize_kv(k_pool, ps)
        vq, vs = quantize_kv(v_pool, ps)
        exact = ref.ref_paged_decode_attention(q, k_pool, v_pool, table,
                                               pos)
        quant = ref.ref_paged_decode_attention_int8(q, kq, vq, ks, vs,
                                                    table, pos)
        err = float(jnp.max(jnp.abs(quant - exact)))
        bound = float(ref.int8_attention_output_bound(
            q, ks, vs, dequantize_kv(vq, vs, jnp.float32)))
        worst = max(worst, err / bound)
    # exact-tie case: all keys identical -> uniform weights either way
    q = jax.random.normal(jax.random.key(99), (1, 1, h, d), jnp.float32)
    kq, ks = quantize_kv(jnp.full((6, ps, kv, d), 0.5, jnp.float32))
    vq, vs = quantize_kv(
        jax.random.normal(jax.random.key(98), (6, ps, kv, d), jnp.float32))
    table = jnp.asarray([[3, 5]], jnp.int32)
    pos = jnp.asarray([ps + 3], jnp.int32)
    out = ops.paged_decode_attention_int8(q, kq, vq, ks, vs, table, pos)
    want = ref.ref_paged_decode_attention_int8(q, kq, vq, ks, vs, table,
                                               pos)
    tie_err = float(jnp.max(jnp.abs(out - want)))
    return {"max_err_over_bound": worst, "within_bound": worst <= 1.0,
            "tie_kernel_vs_oracle_abs": tie_err,
            "tie_ok": tie_err <= 2e-5}


# ---------------------------------------------------------------------------
# bench body
# ---------------------------------------------------------------------------


def run(report, *, arch: str = "granite-8b", requests: int = 8,
        budget: int = 16, prompt_len: int = 48, window: int = 256,
        slots: int = 4, rounds: int = 3, seed: int = 0, out: str = ""):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(requests)]

    # -- capacity: identical HBM budget, per-pool dtype priced in
    per_tok_f32 = kv_bytes_per_token(cfg)
    per_tok_i8 = kv_bytes_per_token(cfg, "int8")
    budget_bytes = per_tok_f32 * window * 8  # 8 f32 slots' worth
    kw = dict(context=window, sla_s=1e9, max_slots=4096,
              kv_hbm_budget_bytes=budget_bytes)
    slots_f32 = plan_admission(cfg, **kw).slots
    slots_i8 = plan_admission(cfg, **kw, kv_cache_dtype="int8").slots
    slots_ratio = slots_i8 / slots_f32

    # -- decode throughput + divergence, A/B interleaved per round
    f32_tp, i8_tp = [], []
    streams = {}
    for mode, sampled in (("greedy", False), ("sampled", True)):
        for variant, int8 in (("f32", False), ("int8", True)):
            eng = _mk_engine(cfg, params, int8=int8, slots=slots,
                             window=window)
            serve_all(eng, prompts[:2], budget, sampled)  # warm jit
            walls = []
            for _ in range(rounds):
                outs, wall = serve_all(eng, prompts, budget, sampled)
                walls.append(wall)
            streams[(mode, variant)] = outs
            tokens = requests * budget
            (f32_tp if not int8 else i8_tp).append(
                tokens / float(np.median(walls)))
    tp_f32 = float(np.mean(f32_tp))
    tp_i8 = float(np.mean(i8_tp))
    tp_ratio = tp_i8 / tp_f32

    div = {mode: divergence_stats(streams[(mode, "f32")],
                                  streams[(mode, "int8")], budget)
           for mode in ("greedy", "sampled")}
    bounds = kernel_bound_probe()

    results = {
        "arch": arch, "requests": requests, "budget": budget,
        "prompt_len": prompt_len, "window": window, "slots": slots,
        "rounds": rounds, "seed": seed,
        **noise_report(),
        "capacity": {
            "kv_bytes_per_token_f32": per_tok_f32,
            "kv_bytes_per_token_int8": per_tok_i8,
            "bytes_ratio": per_tok_f32 / per_tok_i8,
            "kv_hbm_budget_bytes": budget_bytes,
            "slots_f32": slots_f32, "slots_int8": slots_i8,
            "slots_ratio": slots_ratio,
        },
        "throughput": {"decode_tok_s_f32": tp_f32,
                       "decode_tok_s_int8": tp_i8,
                       "ratio_int8_over_f32": tp_ratio},
        "divergence": div,
        "kernel_bounds": bounds,
    }
    report("quant_slots_ratio", round(slots_ratio, 2),
           f"{slots_i8} int8 vs {slots_f32} f32 slots, equal HBM budget")
    report("quant_decode_tok_s_ratio", round(tp_ratio, 3),
           f"{tp_i8:.1f} vs {tp_f32:.1f} tok/s")
    for mode in ("greedy", "sampled"):
        report(f"quant_divergence_{mode}",
               round(div[mode]["edit_distance_budget_frac"], 3),
               f"mean edit distance / budget; first divergence >= "
               f"{div[mode]['first_divergence_min']}")
    report("quant_kernel_err_over_bound",
           round(bounds["max_err_over_bound"], 4),
           "must stay <= 1 (sort-free closed-form bound)")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        report("quant_bench_json", out, "full results")
    return results


# ---------------------------------------------------------------------------
# CI smoke gate
# ---------------------------------------------------------------------------


def smoke(*, arch: str = "granite-8b", out: str = "") -> int:
    res = run(lambda *a: None, arch=arch, requests=4, budget=8,
              prompt_len=32, window=128, slots=2, rounds=2, out=out)
    failures = []

    def check(name, ok, got):
        print(f"smoke:{name}: {'ok' if ok else 'FAIL'} ({got})")
        if not ok:
            failures.append(name)

    cap, tp, div = res["capacity"], res["throughput"], res["divergence"]
    check("slots_1p8x", cap["slots_ratio"] >= 1.8,
          f"{cap['slots_ratio']:.2f}x ({cap['slots_int8']} vs "
          f"{cap['slots_f32']} slots)")
    check("decode_tok_s_0p9x", tp["ratio_int8_over_f32"] >= 0.9,
          f"{tp['ratio_int8_over_f32']:.3f}x "
          f"({tp['decode_tok_s_int8']:.1f} vs "
          f"{tp['decode_tok_s_f32']:.1f} tok/s)")
    for mode in ("greedy", "sampled"):
        d = div[mode]
        # exact prefill => token 1 can never diverge; drift afterwards
        # must stay bounded (not a full-stream rewrite)
        check(f"first_token_exact_{mode}",
              d["first_divergence_min"] != 0, d["first_divergence_min"])
        check(f"divergence_bounded_{mode}",
              d["edit_distance_budget_frac"] <= 0.9,
              f"{d['edit_distance_budget_frac']:.3f} of budget")
    check("kernel_within_bound", res["kernel_bounds"]["within_bound"],
          res["kernel_bounds"]["max_err_over_bound"])
    check("kernel_tie_exact", res["kernel_bounds"]["tie_ok"],
          res["kernel_bounds"]["tie_kernel_vs_oracle_abs"])
    if failures:
        print(f"smoke: FAILED ({', '.join(failures)})")
        return 1
    print("smoke: quantized capacity + throughput + divergence + "
          "bound probes green")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--window", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fail on quantized-serving "
                         "regressions")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_quant.json"))
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(arch=args.arch, out=args.out))

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}")

    print("name,value,derived")
    res = run(report, arch=args.arch, requests=args.requests,
              budget=args.budget, prompt_len=args.prompt_len,
              window=args.window, slots=args.slots, rounds=args.rounds,
              seed=args.seed, out=args.out)
    tp = res["throughput"]["ratio_int8_over_f32"]
    print(f"# int8 pages: {res['capacity']['slots_ratio']:.1f}x slots at "
          f"equal HBM, {tp:.2f}x decode tok/s, greedy divergence "
          f"{res['divergence']['greedy']['edit_distance_budget_frac']:.2f} "
          f"of budget")


if __name__ == "__main__":
    main()
