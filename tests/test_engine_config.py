"""EngineConfig / DeviceTopology / typed LoadReport: the redesigned
construction + telemetry API. These run in every matrix cell (no extra
devices needed) — the sharded execution paths live in test_sharded.py."""
import dataclasses

import jax
import numpy as np
import pytest
from conftest import make_engine

from repro.configs import get_config
from repro.models import init_params
from repro.models.moe import drop_free_group
from repro.serving import (
    DeviceTopology,
    EngineConfig,
    LoadReport,
    Request,
    RequestRejected,
    SCHEMA_VERSION,
    ServingEngine,
)


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    return cfg, init_params(cfg, jax.random.key(0))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


# ---------------------------------------------------------------------------
# EngineConfig value object
# ---------------------------------------------------------------------------


def test_engine_config_frozen_hashable_value():
    a = EngineConfig(slots=2, window=64)
    b = EngineConfig(slots=2, window=64)
    assert a == b and hash(a) == hash(b)
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.slots = 3
    assert a.replace(slots=3).slots == 3 and a.slots == 2


def test_topology_defaults_and_validation():
    t = DeviceTopology()
    assert t.n_chips == 1 and not t.sharded
    assert t.mesh_axes == (("data", 1), ("model", 1))
    assert DeviceTopology(tp=8).n_chips == 8
    with pytest.raises(ValueError, match="axes must be >= 1"):
        DeviceTopology(tp=0)


def test_engine_config_rejects_bad_policy():
    with pytest.raises(ValueError, match="moe_capacity_policy"):
        EngineConfig(moe_capacity_policy="bogus")


def test_legacy_shim_is_gone():
    """The one-PR from_legacy_kwargs shim (PR 7) is fully removed — all
    construction goes through EngineConfig directly."""
    assert not hasattr(EngineConfig, "from_legacy_kwargs")


def test_validate_names_xla_flags_fix():
    """An unrealizable topology must fail at validate() time with the
    XLA_FLAGS fix in the message, not at first trace."""
    need = jax.local_device_count() * 8  # always more than the host has
    with pytest.raises(ValueError) as ei:
        EngineConfig(topology=DeviceTopology(tp=need)).validate()
    msg = str(ei.value)
    assert f"--xla_force_host_platform_device_count={need}" in msg
    # a realizable topology validates to itself (chainable)
    c = EngineConfig(slots=1)
    assert c.validate() is c


def test_legacy_kwargs_raise_with_migration_recipe(granite):
    """ServingEngine(cfg, params, slots=...) keyword construction raises
    TypeError naming the EngineConfig migration and the offending
    keywords — even alongside an explicit config."""
    cfg, params = granite
    with pytest.raises(TypeError, match="EngineConfig") as ei:
        ServingEngine(cfg, params, slots=2, window=64)
    assert "slots" in str(ei.value) and "modeled_chips" in str(ei.value)
    with pytest.raises(TypeError, match="EngineConfig"):
        ServingEngine(cfg, params, EngineConfig(slots=2, window=64),
                      slots=2)
    # no DeprecationWarning remains anywhere on the construction path
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64))
    assert eng.slots == 2 and eng.window == 64


def test_resolved_moe_policy_defaults():
    moe = get_config("grok-1-314b").reduced()
    dense = get_config("granite-8b").reduced()
    c = EngineConfig()
    assert c.resolved_moe_policy(moe) == "drop"  # 1-chip legacy default
    sharded = EngineConfig(topology=DeviceTopology(tp=8))
    assert sharded.resolved_moe_policy(moe) == "strict"
    assert sharded.resolved_moe_policy(dense) == "drop"
    pinned = EngineConfig(moe_capacity_policy="backpressure")
    assert pinned.resolved_moe_policy(moe) == "backpressure"


# ---------------------------------------------------------------------------
# typed LoadReport wire shape
# ---------------------------------------------------------------------------


def test_load_report_round_trip(granite):
    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64)
    assert eng.try_admit(Request(rid=0, prompt=_prompt(8),
                                 max_new_tokens=4), 0.0)
    rep = eng.load_report()
    assert rep.schema_version == SCHEMA_VERSION
    d = rep.to_dict()
    assert isinstance(d["mesh_axes"], list)  # JSON-safe: no tuples
    assert LoadReport.from_dict(d) == rep
    import json
    assert LoadReport.from_dict(json.loads(json.dumps(d))) == rep


def test_load_report_v1_compat_and_future_rejection():
    v1 = {"slots": 4, "free_slots": 4, "queued_requests": 0,
          "queued_prefill_tokens": 0, "decode_tokens_remaining": 0,
          "free_pages": -1, "total_pages": 0, "backlog_s": 0.0,
          "tick_est_s": 0.01, "queued_prefill_s": 0.0}
    rep = LoadReport.from_dict(v1)  # no schema_version field = v1
    assert rep.schema_version == SCHEMA_VERSION  # stamped on upgrade
    assert rep.n_chips == 1 and rep.mesh_axes == (("data", 1), ("model", 1))
    assert rep.moe_capacity_policy == ""
    with pytest.raises(ValueError, match="newer than this reader"):
        LoadReport.from_dict({**v1, "schema_version": SCHEMA_VERSION + 1})


def test_load_report_n_chips_follows_mesh(granite):
    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64)
    rep = eng.load_report()
    assert rep.n_chips == eng.topology.n_chips
    assert rep.mesh_axes == eng.topology.mesh_axes


# ---------------------------------------------------------------------------
# MoE capacity backpressure (typed admission rejection; 1-chip)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tight_moe():
    """A capacity factor low enough that only tiny token groups are
    provably drop-free (k * factor < E)."""
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              moe_capacity_factor=1.0)
    return cfg, init_params(cfg, jax.random.key(1))


def test_backpressure_clamps_slots_and_rejects_typed(tight_moe):
    cfg, params = tight_moe
    gmax = drop_free_group(cfg)
    assert gmax < 16  # the fixture really is tight
    eng = make_engine(cfg, params, slots=8, window=64, chunk_prefill=0,
                      moe_capacity_policy="backpressure")
    assert eng.slots <= gmax  # decode group provably drop-free
    big = Request(rid=0, prompt=_prompt(32), max_new_tokens=2)
    with pytest.raises(RequestRejected, match="drop-free"):
        eng.try_admit(big, 0.0)
    # submit() surfaces the same thing as a typed FAILED outcome
    big2 = Request(rid=1, prompt=_prompt(32), max_new_tokens=2)
    assert eng.submit(big2, 0.0) is False
    assert "drop-free" in big2.fail_reason
    assert eng.metrics.rejected == 1
    rep = eng.load_report()
    assert rep.moe_capacity_policy == "backpressure"
    assert rep.moe_drop_free_group == gmax


def test_strict_policy_serves_any_prompt(tight_moe):
    """strict sizes capacity to the group: the same prompt backpressure
    rejects decodes fine, and the stream completes."""
    cfg, params = tight_moe
    eng = make_engine(cfg, params, slots=2, window=64, chunk_prefill=0,
                      moe_capacity_policy="strict")
    req = Request(rid=0, prompt=_prompt(32), max_new_tokens=4)
    assert eng.try_admit(req, 0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    assert len(req.output) == 4
    assert eng.load_report().moe_capacity_policy == "strict"


def test_dense_arch_ignores_capacity_policy(granite):
    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64,
                      moe_capacity_policy="backpressure")
    assert eng.moe_capacity_policy == ""  # dense: no MoE capacity to police
    assert eng.load_report().moe_drop_free_group == 0


# ---------------------------------------------------------------------------
# LoadReport v3: observability fields
# ---------------------------------------------------------------------------


def test_load_report_v2_compat_defaults_observability_fields():
    """A v2 report (pre-observability) reads cleanly: the v3 fields
    default to empty, nothing is mis-parsed."""
    v2 = {"slots": 4, "free_slots": 4, "queued_requests": 0,
          "queued_prefill_tokens": 0, "decode_tokens_remaining": 0,
          "free_pages": -1, "total_pages": 0, "backlog_s": 0.0,
          "tick_est_s": 0.01, "queued_prefill_s": 0.0,
          "schema_version": 2,
          "mesh_axes": [["data", 1], ["model", 8]],
          "axis_collective_s": [["model", 0.002]],
          "moe_capacity_policy": "strict"}
    rep = LoadReport.from_dict(v2)
    assert rep.mesh_axes == (("data", 1), ("model", 8))
    assert rep.histograms == ()
    assert rep.span_totals == ()
    assert rep.compile_events == ()


def test_load_report_v3_histograms_round_trip(granite):
    """A traced engine that completed a request ships non-empty
    histograms/span_totals/compile_events, and they survive
    dict -> JSON -> dict exactly."""
    import json

    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64, tracing=True)
    req = Request(rid=0, prompt=_prompt(8), max_new_tokens=4)
    eng.submit(req, 0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    eng.drain(t)
    rep = eng.load_report()
    names = [name for name, _wire in rep.histograms]
    assert "jct_s" in names and "latency_s" in names
    assert any(kind == "decode" for kind, _c, _s in rep.span_totals)
    assert rep.compile_events  # jit traces counted per cache key
    rt = LoadReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert rt == rep

    # the wire histograms rebuild into working Histogram objects
    from repro.serving import Histogram
    hists = dict(rep.histograms)
    h = Histogram.from_wire(hists["jct_s"])
    assert h.count == 1 and h.percentile(50) > 0


def test_histogram_merge_associative_property():
    """hypothesis: merging per-replica histograms is associative and
    order-independent — counts exactly, sums to float tolerance (addition
    order differs)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.serving import latency_histogram

    samples = st.lists(
        st.floats(1e-6, 1e5, allow_nan=False, allow_infinity=False),
        max_size=40)

    @given(samples, samples, samples)
    @settings(max_examples=50, deadline=None)
    def prop(a, b, c):
        def hist(vs):
            h = latency_histogram()
            h.extend(vs)
            return h

        left = hist(a).merge(hist(b)).merge(hist(c))
        right = hist(a).merge(hist(b).merge(hist(c)))
        pooled = hist(a + b + c)
        for other in (right, pooled):
            assert left.counts == other.counts
            assert left.count == other.count
            assert left.vmin == other.vmin and left.vmax == other.vmax
            assert left.sum == pytest.approx(other.sum, rel=1e-12, abs=1e-12)

    prop()
