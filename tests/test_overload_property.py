"""Property guarantees for the overload stack.

Runs hypothesis-driven when the (optional) dep is installed — CI's
requirements pin it — and falls back to a fixed seeded sweep otherwise,
so the properties execute either way instead of skipping:

1. DRR never starves a backlogged tenant: for any tenant mix, weights,
   quantum, and request shapes, no queued tenant waits more grant
   rounds than the provable bound ``ceil(max_cost / (quantum *
   min_weight)) + 1`` — including when a flood arrives mid-drain.
2. Brownout-clamped streams are bit-identical prefixes: trimming a
   request's decode budget (what the ladder's BROWNOUT rung does to
   sub-protected tiers) serves exactly the first ``cap`` tokens of the
   untrimmed stream — degraded service, never *different* service.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    EngineConfig,
    ServingEngine,
    TenantClass,
    WeightedFairQueue,
    request_cost,
)

from conftest import make_request

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised in CI only
    HAVE_HYPOTHESIS = False


def seeded_property(*, sweep, examples):
    """``@given(seed=...)`` under hypothesis; a fixed ``seed`` sweep via
    parametrize without it. Either way the test body draws everything
    from ``np.random.default_rng(seed)``."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=examples, deadline=None)(
                given(seed=st.integers(0, 2**20))(fn))
        return pytest.mark.parametrize("seed", range(sweep))(fn)
    return deco


# -- property 1: bounded DRR wait --------------------------------------------


@seeded_property(sweep=30, examples=25)
def test_drr_never_starves_backlogged_tenant(seed):
    rng = np.random.default_rng(seed)
    tenants = {
        f"t{t}": TenantClass(f"t{t}", tier=int(rng.integers(0, 3)),
                             weight=float(rng.uniform(0.5, 8.0)))
        for t in range(int(rng.integers(2, 7)))
    }
    q = WeightedFairQueue(quantum=float(rng.uniform(8.0, 512.0)),
                          weight_of=lambda n: tenants[n].weight)

    def burst(rid0, names):
        reqs = []
        for name in names:
            for _ in range(int(rng.integers(1, 20))):
                r = make_request(
                    rid0 + len(reqs),
                    np.zeros(int(rng.integers(1, 64)), np.int32),
                    int(rng.integers(1, 64)), tenant=name,
                    arrival_time=float(rng.uniform(0.0, 5.0)),
                    ttft_slo_s=float(rng.choice([0.0, 10.0, 30.0])))
                q.push(r)
                reqs.append(r)
        return reqs

    reqs = burst(0, list(tenants))
    # drain halfway, then a flood from one tenant arrives mid-drain — the
    # backlogged others must still be served within the bound
    for _ in range(len(q) // 2):
        assert q.pop() is not None
    reqs += burst(10_000, [str(rng.choice(list(tenants)))])
    # the provable bound at the smallest weight any tenant ever held
    # (starvation_bound() itself only sees *currently backlogged* ones)
    max_cost = max(request_cost(r) for r in reqs)
    min_w = min(tc.weight for tc in tenants.values())
    bound = int(np.ceil(max_cost / (q.quantum * min_w))) + 1
    assert q.starvation_bound(max_cost) <= bound  # backlogged subset only
    while len(q):
        assert q.pop() is not None
    assert q.max_wait_rounds <= bound


# -- property 2: brownout streams are bit-identical prefixes -----------------


@pytest.fixture(scope="module")
def warm(granite):
    """One warm engine reused across examples (reset keeps jit caches)."""
    cfg, params = granite
    return cfg, ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=4))


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    return cfg, init_params(cfg, jax.random.key(0))


def _serve(cfg, eng, shapes):
    """Serve one request per (plen, budget, pseed); return the streams."""
    eng.reset()
    reqs = []
    for rid, (plen, budget, pseed) in enumerate(shapes):
        prng = np.random.default_rng(pseed)
        r = make_request(rid, prng.integers(0, cfg.vocab_size,
                                            plen).astype(np.int32), budget)
        eng.submit(r, 0.0)
        reqs.append(r)
    now = 0.0
    while any(r.finish_time < 0 for r in reqs):
        now += 1.0
        eng.step(now)
        assert now < 500
    return [list(r.output) for r in reqs]


@seeded_property(sweep=4, examples=5)
def test_brownout_stream_is_bit_identical_prefix(warm, seed):
    cfg, eng = warm
    rng = np.random.default_rng(seed)
    shapes = [(int(rng.integers(4, 25)), int(rng.integers(4, 13)),
               int(rng.integers(0, 2**16)))
              for _ in range(int(rng.integers(2, 5)))]
    full = _serve(cfg, eng, shapes)
    frac = float(rng.uniform(0.25, 0.9))
    caps = [max(1, int(budget * frac)) for _, budget, _ in shapes]
    clamped = _serve(cfg, eng, [(p, cap, s) for (p, _, s), cap
                                in zip(shapes, caps)])
    for out, ref, cap in zip(clamped, full, caps):
        assert out == ref[:cap]
