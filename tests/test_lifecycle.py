"""Request lifecycle: the state machine, cancel/deadline-abort/shed,
typed rejections, and preemption with bit-identical restore."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    EngineConfig,
    PREEMPT_POLICIES,
    RequestRejected,
    RequestState,
    SamplingParams,
    ServingEngine,
)

# rid-stable sampled seeds under REPRO_ENGINE_SAMPLING=sampled: the
# lifecycle machinery is exercised under stochastic decode as well
from conftest import make_request as Request


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _drive(eng, reqs, *, t0=0.0, max_steps=500):
    done, t = [], t0
    while len(done) < len(reqs):
        t += 1.0
        done += eng.step(t)
        assert t - t0 < max_steps, f"{len(done)}/{len(reqs)} resolved"
    return done, t


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------


def test_state_machine_progression(granite):
    """QUEUED -> PREFILL -> DECODE -> FINISHED, observable at each stage
    (chunked prefill makes the PREFILL stage span multiple ticks)."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, sync_every=1,
                        chunk_prefill=8))
    req = Request(0, _prompt(32), max_new_tokens=3)
    assert req.state is RequestState.QUEUED and not req.state.terminal
    assert eng.submit(req, 0.0)
    assert req.state is RequestState.PREFILL
    t = 0.0
    while req.state is RequestState.PREFILL:
        t += 1.0
        eng.step(t)
        assert t < 50
    assert req.state is RequestState.DECODE
    while not req.done:
        t += 1.0
        eng.step(t)
        assert t < 50
    assert req.state is RequestState.FINISHED and req.state.terminal
    assert len(req.output) == 3 and req.fail_reason == ""


def test_cancel_frees_slot_and_pages(granite):
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, sync_every=1,
                        chunk_prefill=0))
    req = Request(0, _prompt(12), max_new_tokens=40)
    other = Request(1, _prompt(10, seed=1), max_new_tokens=4)
    assert eng.try_admit(req, 0.0)
    eng.submit(other, 0.0)  # queued behind the doomed request
    eng.step(1.0)
    assert 0 < len(req.output) < 40
    req.cancel()
    out = eng.step(2.0)
    assert req in out
    assert req.state is RequestState.CANCELLED
    assert "cancel" in req.fail_reason
    assert eng.metrics.cancelled == 1
    # the freed slot admits the queued request, which runs to completion
    done, _ = _drive(eng, [other], t0=2.0)
    assert other in done and len(other.output) == 4
    assert eng.n_active == 0 and eng.allocator.pages_in_use == 0


def test_timeout_aborts_mid_decode(granite):
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, sync_every=1,
                        chunk_prefill=0))
    req = Request(0, _prompt(12), max_new_tokens=200, timeout_s=3.0)
    assert eng.try_admit(req, 0.0)
    for t in (1.0, 2.0, 3.0):  # within deadline: keeps decoding
        eng.step(t)
    assert req.state is RequestState.DECODE
    out = eng.step(4.5)  # now > arrival + timeout_s
    assert req in out and req.state is RequestState.TIMED_OUT
    assert "timed out" in req.fail_reason
    assert eng.metrics.timed_out == 1
    assert 0 < len(req.output) < 200  # partial stream, then the abort
    assert eng.n_active == 0 and eng.allocator.pages_in_use == 0


def test_shed_overdue_queued_request_under_overload(granite):
    """With shed_overdue on, a QUEUED request whose TTFT deadline already
    passed is dropped before burning prefill budget; the occupant is
    untouched. Off by default (late requests still finish)."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, sync_every=1,
                        chunk_prefill=0, shed_overdue=True))
    hog = Request(0, _prompt(12), max_new_tokens=30)
    late = Request(1, _prompt(10, seed=1), max_new_tokens=4, ttft_slo_s=2.0)
    assert eng.try_admit(hog, 0.0)
    eng.submit(late, 0.0)
    out = []
    for t in (1.0, 2.0, 3.0):
        out += eng.step(t)
    assert late in out and late.state is RequestState.TIMED_OUT
    assert "shed" in late.fail_reason
    assert eng.metrics.shed == 1 and eng.metrics.timed_out == 0
    assert late.prefill_done < 0  # never prefillled: no budget burned
    done, _ = _drive(eng, [hog], t0=3.0)
    assert hog in done and len(hog.output) == 30


def test_typed_rejection_is_a_valueerror_subclass():
    """Backward compat: callers catching ValueError keep working."""
    assert issubclass(RequestRejected, ValueError)


# ---------------------------------------------------------------------------
# preemption + bit-identical restore
# ---------------------------------------------------------------------------


def test_preemption_requires_paged(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="preemption requires"):
        ServingEngine(cfg, params, EngineConfig(slots=1, paged=False, preemption=True))
    with pytest.raises(ValueError, match="preempt_policy"):
        ServingEngine(cfg, params, EngineConfig(slots=1, preemption=True,
                      preempt_policy="coin-flip"))
    assert set(PREEMPT_POLICIES) == {"latest-deadline", "most-remaining"}


@pytest.mark.parametrize("prefix_cache", [False, True],
                         ids=["paged", "prefix_cache"])
def test_preempt_restore_bit_identical(granite, prefix_cache):
    """A request preempted mid-decode by a higher-priority arrival resumes
    with a stream bit-identical to an undisturbed run — the restore path
    is suffix-only prefill over the cached generated prefix when the
    prefix cache is on, full recompute of the folded prompt otherwise;
    position-keyed seeded sampling makes both exact."""
    cfg, params = granite
    kw = dict(slots=1, window=64, max_seq=64, sync_every=1, chunk_prefill=0)
    samp = SamplingParams(temperature=0.7, top_k=20, top_p=0.95, seed=77)

    ref_eng = ServingEngine(cfg, params, EngineConfig(**kw))
    ref = Request(0, _prompt(20), max_new_tokens=10, sampling=samp)
    assert ref_eng.try_admit(ref, 0.0)
    _drive(ref_eng, [ref])

    eng = ServingEngine(cfg, params, EngineConfig(**kw, preemption=True,
                        prefix_cache=prefix_cache))
    victim = Request(0, _prompt(20), max_new_tokens=10, sampling=samp,
                     ttft_slo_s=100.0)
    assert eng.try_admit(victim, 0.0)
    for t in (1.0, 2.0, 3.0):
        eng.step(t)
    assert len(victim.output) >= 2  # mid-decode when the preemptor lands
    hot = Request(1, _prompt(10, seed=9), max_new_tokens=3, priority=1,
                  ttft_slo_s=1.0,
                  sampling=SamplingParams(temperature=0.7, top_k=20,
                                          top_p=0.95, seed=78))
    eng.submit(hot, 3.0)
    done, _ = _drive(eng, [victim, hot], t0=3.0)
    assert victim in done and hot in done
    assert victim.preemptions >= 1
    assert eng.metrics.preempted >= 1 and eng.metrics.preempt_restores >= 1
    # the hot request jumped the line: it finished while the victim waited
    assert hot.finish_time <= victim.finish_time
    # THE contract: the disturbed stream equals the undisturbed one
    assert list(victim.output) == list(ref.output)
    assert victim.state is RequestState.FINISHED
    if prefix_cache:
        # restore aliased the registered generated prefix (>= 1 full page)
        assert eng.metrics.prefix_hits >= 1
    # no leaked pages or refcount drift after the churn
    eng.clear_prefix_cache()
    assert eng.allocator.pages_in_use == 0
    assert eng.allocator.total_refs == 0


def test_preemption_never_evicts_equal_urgency(granite):
    """Strict-urgency eligibility: an identical-urgency arrival cannot
    evict a running request (no thrash: two equal requests would
    otherwise trade the slot forever)."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, sync_every=1,
                        chunk_prefill=0, preemption=True))
    a = Request(0, _prompt(12), max_new_tokens=20, ttft_slo_s=5.0)
    b = Request(1, _prompt(12, seed=1), max_new_tokens=20, ttft_slo_s=5.0)
    assert eng.try_admit(a, 0.0)
    eng.submit(b, 0.0)
    for t in range(1, 6):
        eng.step(float(t))
    assert eng.metrics.preempted == 0
    assert a.preemptions == 0 and not a.done  # still running undisturbed
