"""Tooling correctness: the dry-run's HLO collective parser, the
affine-probe extrapolation, data-pipeline determinism, batching runtime."""
import numpy as np

from repro.launch import dryrun  # safe: only sets XLA_FLAGS in its process
from repro.core.misd.batching import BatchAccumulator
from repro.training.data import TokenPipeline


def test_collective_parser_counts_and_multiplies():
    hlo = """
  %ag = bf16[4,1024]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[8,256]{1,0} all-reduce(%y), to_apply=%sum
  %a2a.1 = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%a, %b)
  %cp = u8[16]{0} collective-permute(%z)
  %ags = bf16[4,4]{1,0} all-gather-start(%w)
"""
    got = dryrun.collective_bytes(hlo)
    assert got["all-gather"] == 4 * 1024 * 2 + 4 * 4 * 2  # incl. -start
    assert got["all-reduce"] == 8 * 256 * 4 * 2.0  # ring multiplier
    assert got["all-to-all"] == 2 * (2 * 2 * 4)
    assert got["collective-permute"] == 16


def test_affine_probe_extrapolation_exact():
    """cost = a*r + b is recovered exactly from two probes."""
    a, b = 3.5e12, 1.1e11
    r1, r2, target = 2, 4, 40
    v1, v2 = a * r1 + b, a * r2 + b
    slope = (v2 - v1) / (r2 - r1)
    assert abs((v2 + slope * (target - r2)) - (a * target + b)) < 1e-3


def test_token_pipeline_deterministic_and_seekable():
    p1 = TokenPipeline(1000, 32, 4, seed=7)
    p2 = TokenPipeline(1000, 32, 4, seed=7)
    it1, it2 = p1.batches(), p2.batches()
    for _ in range(3):
        b1, b2 = next(it1), next(it2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # seek: step 2 reproduced from a fresh iterator
    fresh = next(TokenPipeline(1000, 32, 4, seed=7).batches(start_step=2))
    np.testing.assert_array_equal(fresh["tokens"], b1["tokens"])


def test_batch_accumulator_deadline_and_target():
    acc = BatchAccumulator(target_batch=3, deadline_s=1.0)
    assert acc.add("a", now=0.0) is None
    assert acc.add("b", now=0.1) is None
    assert acc.poll(now=0.5) is None  # under deadline, under target
    got = acc.add("c", now=0.2)
    assert got == ["a", "b", "c"]  # target reached
    assert acc.add("d", now=5.0) is None
    assert acc.poll(now=6.1) == ["d"]  # deadline flush
