"""Paged KV cache: allocator invariants, paged-vs-rolling decode
equivalence, prompts beyond the old window cap, admission backpressure,
and page reuse under churn."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_params
from repro.serving import (
    EngineConfig,
    PageAllocator,
    RequestState,
    SamplingParams,
    ServingEngine,
)

# Requests ride the CI config matrix: under REPRO_ENGINE_SAMPLING=sampled
# every request in this suite samples with a rid-stable seed
# (conftest.make_request shares Request's positional signature), so the
# paging invariants are exercised under stochastic decode as well.
from conftest import make_request as Request


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _run(cfg, params, reqs, **kw):
    eng = ServingEngine(cfg, params, EngineConfig(**kw))
    for r in reqs:
        assert eng.try_admit(r, 0.0)
    t = 0.0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    return eng


# ---------------------------------------------------------------------------
# PageAllocator
# ---------------------------------------------------------------------------


def test_allocator_alloc_free_reuse():
    a = PageAllocator(9, 16)  # 8 usable + trash
    assert a.capacity == 8 and a.free_pages == 8
    p1 = a.alloc(0, 3)
    assert p1 is not None and len(p1) == 3
    assert a.TRASH_PAGE not in p1  # page 0 is never granted
    assert a.pages_in_use == 3
    freed = a.free_slot(0)
    assert sorted(freed) == sorted(p1)
    assert a.free_pages == 8
    # LIFO: the pages just freed come back first
    p2 = a.alloc(1, 3)
    assert set(p2) == set(p1)


def test_allocator_all_or_nothing():
    a = PageAllocator(5, 16)  # 4 usable
    assert a.alloc(0, 3) is not None
    assert a.alloc(1, 2) is None  # only 1 left: no partial grant
    assert a.free_pages == 1  # the failed alloc consumed nothing
    assert a.alloc(1, 1) is not None


def test_allocator_fragmentation_under_churn():
    """Random admit/finish churn must conserve pages exactly: fixed-size
    pages mean the free list never fragments — any N free pages satisfy
    any N-page request regardless of the alloc/free history."""
    rng = np.random.default_rng(0)
    a = PageAllocator(33, 16)  # 32 usable
    live = {}
    for it in range(500):
        if live and (len(live) > 6 or rng.random() < 0.45):
            slot = int(rng.choice(list(live)))
            a.free_slot(slot)
            del live[slot]
        else:
            slot = it
            n = int(rng.integers(1, 5))
            pages = a.alloc(slot, n)
            if pages is None:
                assert a.free_pages < n  # refusal only when truly short
                continue
            live[slot] = pages
        # invariants: disjoint ownership, exact conservation, no trash
        owned = [p for ps in live.values() for p in ps]
        assert len(owned) == len(set(owned))
        assert 0 not in owned
        assert a.free_pages + len(owned) == a.capacity
    for slot in list(live):
        a.free_slot(slot)
    assert a.free_pages == a.capacity


def test_allocator_pages_for():
    a = PageAllocator(4, 16)
    assert a.pages_for(1) == 1 and a.pages_for(16) == 1
    assert a.pages_for(17) == 2 and a.pages_for(160) == 10


def test_allocator_lifo_exact_reuse_order():
    """LIFO is exact, not just set-equal: across interleaved frees the
    most recently freed page is always granted first (cache-friendly
    reuse; also makes allocation traces reproducible in tests)."""
    a = PageAllocator(9, 16)  # 8 usable
    assert a.alloc(0, 2) == [1, 2]  # free list pops lowest-first when fresh
    assert a.alloc(1, 2) == [3, 4]
    a.free_slot(0)  # free list top: 1, 2 (newest first... reversed -> 1 on top)
    a.free_slot(1)  # top now: 3, 4 order below slot 0's pages
    # slot 1's pages were freed last, so they come back first, in the
    # order originally granted
    assert a.alloc(2, 3) == [3, 4, 1]
    assert a.alloc(3, 1) == [2]


def test_allocator_exhaustion_boundary_at_admission():
    """Admission-time exhaustion is a clean refusal exactly at the
    capacity boundary — never a partial grant, never an exception (the
    mid-stream OutOfPagesError guard is a different, louder path —
    test_out_of_pages_mid_decode_raises)."""
    a = PageAllocator(5, 16)  # 4 usable
    assert a.can_alloc(4) and not a.can_alloc(5)
    assert a.alloc(0, 5) is None  # one past capacity: refused whole
    assert a.free_pages == 4  # the refusal consumed nothing
    assert len(a.alloc(0, 4)) == 4  # exactly at capacity: granted
    assert a.free_pages == 0 and not a.can_alloc(1)
    assert a.alloc(1, 1) is None
    a.free_slot(0)
    assert a.free_pages == 4  # full recovery after release


# ---------------------------------------------------------------------------
# paged vs rolling decode equivalence
# ---------------------------------------------------------------------------


def test_paged_engine_matches_rolling(granite):
    """Acceptance: for prompts that fit the old window, the paged engine's
    token streams are identical to the rolling-window engine's."""
    cfg, params = granite
    out = {}
    for paged in (True, False):
        reqs = [Request(0, _prompt(13, seed=1), max_new_tokens=9),
                Request(1, _prompt(30, seed=2), max_new_tokens=7),
                Request(2, _prompt(21, seed=3), max_new_tokens=11)]
        eng = _run(cfg, params, reqs, slots=3, window=64, sync_every=4,
                   paged=paged)
        assert eng.paged is paged
        out[paged] = [r.output for r in reqs]
    assert out[True] == out[False]


def test_paged_chunked_prefill_matches_rolling(granite):
    """Chunked-prefill admissions through the paged linear buffer decode
    identically to the rolling engine's chunked path."""
    cfg, params = granite
    out = {}
    for paged in (True, False):
        req = Request(0, _prompt(40, seed=4), max_new_tokens=6)
        _run(cfg, params, [req], slots=2, window=128, chunk_prefill=16,
             paged=paged)
        out[paged] = req.output
    assert out[True] == out[False]


def test_paged_lifts_prompt_cap(granite):
    """Acceptance: prompts longer than the rolling window serve correctly
    when max_seq raises the page-table width — first token must match the
    exact full-prompt forward."""
    cfg, params = granite
    window, plen = 64, 100  # prompt exceeds the old per-slot window
    prompt = _prompt(plen, seed=5)
    # pinned greedy: the assertions below are argmax-vs-exact-forward
    # math, and the rolling reference uses a different rid (seed)
    req = Request(0, prompt, max_new_tokens=5, sampling=SamplingParams())
    eng = _run(cfg, params, [req], slots=2, window=window, max_seq=256,
               sync_every=4)
    assert eng.paged and len(req.output) == 5
    logits, _, _ = forward(cfg, params,
                           {"tokens": jnp.asarray(prompt[None, :], jnp.int32)},
                           mode="prefill", cache=None)
    assert req.output[0] == int(jnp.argmax(logits[0, -1]))
    # and the whole stream matches a wide rolling engine (no paging)
    ref = Request(1, prompt, max_new_tokens=5, sampling=SamplingParams())
    _run(cfg, params, [ref], slots=2, window=256, paged=False)
    assert req.output == ref.output


def test_explicit_paged_on_nonpageable_arch_raises():
    """paged=True must not silently downgrade to rolling windows (callers
    sizing max_seq would get lossy ring-wrapped context instead)."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = init_params(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="non-pageable"):
        ServingEngine(cfg, params, EngineConfig(slots=1, paged=True))
    eng = ServingEngine(cfg, params, EngineConfig(slots=1))  # auto-fallback stays fine
    assert not eng.paged


def test_paged_rejects_prompt_beyond_max_seq(granite):
    """An unservable prompt is rejected at submit/try_admit time and never
    reaches the backlog (where its failure would poison every later tick).
    ``try_admit`` raises the typed ``RequestRejected`` (a ValueError) for
    direct callers; ``submit`` converts it to a FAILED outcome so one bad
    request cannot crash a serving loop."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=32, max_seq=64))
    with pytest.raises(ValueError, match="max_seq"):
        eng.try_admit(Request(0, _prompt(65), max_new_tokens=2), 0.0)
    # saturate the slot, then submit the poison request: it must resolve
    # as a rejection, leaving the queue clean and the engine steppable
    ok = Request(1, _prompt(10, seed=1), max_new_tokens=4)
    assert eng.try_admit(ok, 0.0)
    poison = Request(2, _prompt(65, seed=2), max_new_tokens=2)
    assert eng.submit(poison, 0.0) is False
    assert poison.state is RequestState.FAILED
    assert "max_seq" in poison.fail_reason
    assert eng.metrics.rejected == 1
    assert not eng.backlog and not eng.admission.pending
    t = 0.0
    done = []
    while not ok.done:
        t += 1.0
        done += eng.step(t)
    assert len(ok.output) == 4
    assert poison in done  # the rejection surfaced through the step stream


def test_budget_cap_is_surfaced(granite):
    """When the page table truncates a request's token budget, the request
    says so instead of silently ending early."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, chunk_prefill=0))
    req = Request(0, _prompt(20), max_new_tokens=1000)  # 64-token cap
    assert eng.try_admit(req, 0.0)
    assert req.budget_capped and req.max_new_tokens == 64 - 20
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    assert len(req.output) == 44
    # a request within budget is not flagged
    ok = Request(1, _prompt(20, seed=1), max_new_tokens=4)
    assert eng.try_admit(ok, t)
    assert not ok.budget_capped


# ---------------------------------------------------------------------------
# single-trace probes
# ---------------------------------------------------------------------------


def test_paged_single_trace_probes(granite):
    """Acceptance: the paged engine keeps one decode trace per step shape
    (tick + fused scan) and one prefill trace per bucket."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=4, window=128, chunk_prefill=0,
                        sync_every=4))
    assert eng.paged
    reqs = [Request(i, _prompt(p, seed=i), max_new_tokens=12)
            for i, p in enumerate((9, 12, 15, 16))]
    for r in reqs:
        assert eng.try_admit(r, 0.0)
    assert eng.prefill_traces == 1  # one bucket -> one trace
    t = 0.0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    assert eng.decode_traces <= 2  # single tick + fused scan, once each
    assert eng.try_admit(Request(9, _prompt(17, seed=9), 4), t)
    assert eng.prefill_traces == 2  # a new bucket costs exactly one trace


# ---------------------------------------------------------------------------
# backpressure and page reuse
# ---------------------------------------------------------------------------


def test_out_of_pages_backpressure(granite):
    """A pool too small for a second prompt rejects the admission (request
    stays queued) and accepts it once the first request's pages free up."""
    cfg, params = granite
    # 5 usable pages of 16 tokens; each 33-token prompt buckets to 64
    # tokens = 4 pages, so the second admission cannot be covered.
    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64, pool_pages=6,
                        sync_every=1, chunk_prefill=0))
    assert eng.paged
    a = Request(0, _prompt(33, seed=1), max_new_tokens=4)
    b = Request(1, _prompt(33, seed=2), max_new_tokens=4)
    assert eng.try_admit(a, 0.0)
    assert not eng.try_admit(b, 0.0)  # 1 free page < the 4 needed
    eng.submit(b, 0.0)  # queues instead of dropping
    t = 0.0
    while not (a.done and b.done):
        t += 1.0
        eng.step(t)
    assert len(a.output) == 4 and len(b.output) == 4
    assert eng.allocator.pages_in_use == 0  # all pages returned


def test_token_budget_reserved_at_admission(granite):
    """Admission reserves the request's whole token budget, so a pool too
    small for prompt + decode tail backpressures UP FRONT instead of
    exhausting mid-stream."""
    cfg, params = granite
    # 2 usable pages: the 32-token bucket fits (2 pages) but the 20-token
    # decode tail needs a 3rd -> admission must refuse, not crash later.
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, pool_pages=3,
                        sync_every=1, chunk_prefill=0))
    assert not eng.try_admit(Request(0, _prompt(30), max_new_tokens=20), 0.0)
    assert eng.allocator.pages_in_use == 0
    # a request whose budget fits the reservation serves to completion
    ok = Request(1, _prompt(30, seed=1), max_new_tokens=3)
    assert eng.try_admit(ok, 0.0)
    t = 0.0
    while not ok.done:
        t += 1.0
        eng.step(t)
    assert len(ok.output) == 3


def test_out_of_pages_mid_decode_fails_only_that_request(granite):
    """The mid-decode exhaustion guard — reachable only when the
    admission-time reservation is bypassed (here: the token budget is
    raised after admission) — stays LOUD (the failure names the sizing
    knobs) but is contained: it fails THAT request, frees its slot and
    pages, and the engine keeps serving everyone else."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64, pool_pages=6,
                        sync_every=1, chunk_prefill=0))
    bad = Request(0, _prompt(30), max_new_tokens=2)  # reserves 2 pages
    ok = Request(1, _prompt(30, seed=1), max_new_tokens=8)
    assert eng.try_admit(bad, 0.0)
    assert eng.try_admit(ok, 0.0)
    bad.max_new_tokens = 90  # bypass the reservation: grow past the pool
    done = []
    for t in range(200):
        done += eng.step(float(t))
        if ok.done and bad in done:
            break
    assert bad.state is RequestState.FAILED
    assert "OutOfPagesError" in bad.fail_reason
    assert "pool_pages" in bad.fail_reason
    assert eng.metrics.failed == 1
    # the innocent bystander finished its full budget on a live engine
    assert ok.done and len(ok.output) == 8
    # the failed request's slot and pages came back to the pool
    assert eng.n_active == 0 and eng.allocator.pages_in_use == 0


def test_kv_budget_admits_more_paged_slots():
    """The admission plan converts paged HBM savings into slots: under the
    same KV budget, paying only the expected resident length per slot
    (paged) admits more concurrency than reserving a full window
    (rolling)."""
    from repro.core.costmodel import kv_bytes_per_token
    from repro.core.misd.batching import plan_admission

    cfg = get_config("granite-8b")
    budget = kv_bytes_per_token(cfg) * 4096 * 4  # 4 full windows of KV
    rolling = plan_admission(cfg, context=4096, sla_s=10.0,
                             kv_hbm_budget_bytes=budget, mean_context=4096)
    paged = plan_admission(cfg, context=4096, sla_s=10.0,
                           kv_hbm_budget_bytes=budget, mean_context=512)
    assert rolling.slots == 4  # budget-bound
    assert paged.slots == min(32, plan_admission(
        cfg, context=4096, sla_s=10.0).slots)  # 8x more until SLA-bound


def test_done_at_activation_releases_slot(granite):
    """A request whose budget is met by the prefill token alone (max_new=1,
    or a prompt filling max_seq) must finalize at activation — not zombie
    in its slot holding pages forever."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, chunk_prefill=0))
    req = Request(0, _prompt(10), max_new_tokens=1)
    assert eng.try_admit(req, 0.0)
    assert req.done and req.finish_time >= 0
    assert eng.n_active == 0 and eng.allocator.pages_in_use == 0
    assert eng.drain(1.0) == [req]
    # a follow-up request reuses the slot and pages immediately
    nxt = Request(1, _prompt(12, seed=2), max_new_tokens=3)
    assert eng.try_admit(nxt, 1.0)


def test_chunked_jobs_share_one_chunk_trace(granite):
    """Chunked prompts of different padded lengths must reuse ONE compiled
    chunk step (the shared max_seq-wide job buffer), not retrace the full
    model per prompt length."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64, max_seq=256,
                        chunk_prefill=16))
    t = 0.0
    for i, plen in enumerate((40, 72)):  # different padded lengths
        req = Request(i, _prompt(plen, seed=i), max_new_tokens=3)
        assert eng.try_admit(req, t)
        while not req.done:
            t += 1.0
            eng.step(t)
    assert eng._prefill_chunk._cache_size() == 1


def test_page_reuse_under_engine_churn(granite):
    """Sequential waves of requests through a bounded pool: every wave's
    pages are reclaimed, so the pool never monotonically fills."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64, sync_every=2,
                        chunk_prefill=0))
    t = 0.0
    for wave in range(3):
        reqs = [Request(10 * wave + i, _prompt(20 + i, seed=wave * 7 + i),
                        max_new_tokens=5) for i in range(2)]
        for r in reqs:
            assert eng.try_admit(r, t)
        while not all(r.done for r in reqs):
            t += 1.0
            eng.step(t)
        assert eng.allocator.pages_in_use == 0
    assert eng.metrics.completed == 6
