"""Observability layer: histograms/registry, span tracing across engine
and cluster paths (admission, preemption, failover), compile/profile
hooks, and the zero-behavior-change guarantees (bit-identical streams,
mean-preserving router correction)."""
import json
import time

import jax
import numpy as np
import pytest
from conftest import make_engine

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    ClusterFrontend,
    Counter,
    EngineConfig,
    FaultyEngine,
    Histogram,
    MetricsRegistry,
    Request,
    RequestState,
    SamplingParams,
    ServeMetrics,
    ServingEngine,
    Trace,
    chrome_trace,
    latency_histogram,
    request_traces,
    validate_chrome_trace,
)


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    return cfg, init_params(cfg, jax.random.key(0))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _samp(seed):
    return SamplingParams(temperature=0.7, top_k=20, top_p=0.95, seed=seed)


def _run(eng, reqs, *, max_steps=500):
    resolved, t = {}, 0.0
    for r in reqs:
        eng.submit(r, t)
    while len(resolved) < len(reqs) and max_steps:
        t += 1.0
        for r in eng.step(t):
            resolved[r.rid] = r
        max_steps -= 1
    for r in eng.drain(t):
        resolved[r.rid] = r
    return resolved, t


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------


def test_histogram_percentiles_within_one_bucket():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=2.0, size=500)
    h = latency_histogram()
    h.extend(vals)
    assert h.count == 500 and len(h) == 500
    assert h.mean == pytest.approx(float(np.mean(vals)))  # exact sum
    for q in (0, 10, 50, 90, 99, 100):
        want = float(np.percentile(vals, q))
        got = h.percentile(q)
        assert abs(h.bucket_index(got) - h.bucket_index(want)) <= 1, q
    assert h.percentile(0) == float(np.min(vals))
    assert h.percentile(100) == float(np.max(vals))


def test_histogram_merge_is_exact_and_checks_bounds():
    a, b = latency_histogram(), latency_histogram()
    va = [0.001, 0.5, 3.0]
    vb = [0.02, 7.0]
    a.extend(va)
    b.extend(vb)
    pooled = latency_histogram()
    pooled.extend(va + vb)
    merged = a.copy().merge(b)
    # counts/extremes are exactly the pooled histogram's; the sum matches
    # to addition-order float tolerance
    assert merged.counts == pooled.counts
    assert merged.count == pooled.count
    assert (merged.vmin, merged.vmax) == (pooled.vmin, pooled.vmax)
    assert merged.sum == pytest.approx(pooled.sum, rel=1e-15)
    with pytest.raises(ValueError, match="different bounds"):
        a.merge(Histogram([1.0, 2.0]))


def test_histogram_wire_round_trip_and_empty_json_safety():
    h = latency_histogram()
    h.extend([0.004, 0.004, 12.0])
    rt = Histogram.from_wire(
        json.loads(json.dumps(list(h.to_wire()), default=list)))
    assert rt == h and rt.preset == "latency_s"
    # empty histograms must not leak inf into JSON
    wire = latency_histogram().to_wire()
    assert wire[4] == 0.0 and wire[5] == 0.0
    assert "Infinity" not in json.dumps(list(wire), default=list)
    assert Histogram.from_wire(wire).count == 0


def test_histogram_list_compat_shims():
    """ServeMetrics call sites kept their list idioms: .append and
    truthiness."""
    h = latency_histogram()
    assert not h
    h.append(0.25)
    assert h and len(h) == 1


def test_counter_and_registry_exposition():
    reg = MetricsRegistry()
    reg.counter("requests_total", help="all requests").inc(3)
    reg.gauge("qps").set(1.5)
    h = reg.histogram("lat_seconds")
    h.observe(0.02)
    with pytest.raises(ValueError, match="only go up"):
        reg.get("requests_total").inc(-1)
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("requests_total")
    text = reg.exposition()
    assert "# HELP requests_total all requests" in text
    assert "# TYPE requests_total counter" in text
    assert "requests_total 3" in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    snap = reg.snapshot()
    assert snap["requests_total"] == 3
    assert snap["lat_seconds"]["count"] == 1
    json.dumps(snap)  # JSON-safe throughout
    assert isinstance(Counter(), Counter)


def test_serve_metrics_is_bounded_and_merges_exactly():
    a, b = ServeMetrics(), ServeMetrics()
    for i in range(100):
        a.latencies.append(0.01 * (i + 1))
        b.latencies.append(0.02 * (i + 1))
        a.ttfts.append(0.001)
        b.tpots.append(0.005)
    pooled = ServeMetrics()
    pooled.merge(a)
    pooled.merge(b)
    assert pooled.latencies.count == 200
    want = float(np.percentile([0.01 * (i + 1) for i in range(100)]
                               + [0.02 * (i + 1) for i in range(100)], 99))
    got = pooled.p(99)
    assert abs(pooled.latencies.bucket_index(got)
               - pooled.latencies.bucket_index(want)) <= 1
    assert pooled.ttft_p(50) == 0.001
    assert pooled.tpot_p(50) == 0.005
    # memory is O(buckets): the histogram never stores samples
    assert len(pooled.latencies.counts) == len(pooled.latencies.bounds) + 1


# ---------------------------------------------------------------------------
# util.timeit samples
# ---------------------------------------------------------------------------


def test_timeit_returns_mean_with_samples():
    from repro.util import timeit

    t = timeit(lambda: time.sleep(0.001), iters=5, warmup=1)
    assert isinstance(t, float)
    assert len(t.samples) == 5
    assert float(t) == pytest.approx(sum(t.samples) / 5)
    assert min(t.samples) <= t.median <= max(t.samples)
    assert t * 1e6 > 0  # the microbench idiom still works


# ---------------------------------------------------------------------------
# Trace primitives
# ---------------------------------------------------------------------------


def test_trace_lifecycle_and_validation():
    t = Trace(rid=7)
    t.begin("queued", 1.0)
    t.end("queued", 2.0)
    t.begin("decode", 2.0, slot=0)
    assert t.is_open("decode")
    assert t.validate() != []  # open span on a terminal trace
    t.end("decode", 5.0, tokens=3)
    assert t.validate() == []
    assert t.totals()["decode"] == (1, 3.0)
    # lenient end: no open span of that kind is a no-op, not an error
    assert t.end("prefill", 6.0) is None
    bad = Trace(rid=8)
    bad.add("a", 3.0, 2.0)
    bad.add("b", 1.0, 1.5)
    probs = bad.validate()
    assert any("negative" in p for p in probs)
    assert any("before" in p for p in probs)


def test_chrome_trace_export_structure():
    t = Trace(rid=4)
    t.add("queued", 0.0, 1.0)
    t.event("dispatch", 1.0, replica="e0")
    doc = chrome_trace([("e0", t)])
    assert validate_chrome_trace(doc) == []
    phases = {ev["ph"] for ev in doc["traceEvents"]}
    assert {"M", "X", "i"} <= phases
    span = next(ev for ev in doc["traceEvents"] if ev["ph"] == "X")
    assert span["ts"] == 0.0 and span["dur"] == 1e6  # seconds -> us
    assert validate_chrome_trace({"traceEvents": []}) != []
    assert validate_chrome_trace(
        {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1}]}) != []


# ---------------------------------------------------------------------------
# engine span integrity
# ---------------------------------------------------------------------------


def test_engine_stamps_full_lifecycle(granite):
    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64, max_seq=128,
                      sync_every=4, tracing=True)
    reqs = [Request(rid=i, prompt=_prompt(8 + i, seed=i), max_new_tokens=4,
                    sampling=_samp(100 + i) if i % 2 else None)
            for i in range(5)]
    resolved, _ = _run(eng, reqs)
    assert len(resolved) == 5
    for r in resolved.values():
        assert r.trace is not None
        assert r.trace.validate() == [], (r.rid, r.trace.validate())
        kinds = set(r.trace.kinds())
        assert {"queued", "prefill", "decode"} <= kinds, (r.rid, kinds)
        if r.sampling is not None:
            assert "sample" in kinds
    # terminal traces folded into the engine rollup
    assert eng.tracer.collected == 5
    assert eng.tracer.span_totals["decode"][0] == 5
    # per-step wall accounting only exists when tracing is on
    assert eng._tick_wall.count > 0


def test_tracing_off_means_no_trace_objects(granite):
    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64, sync_every=4)
    reqs = [Request(rid=i, prompt=_prompt(8), max_new_tokens=3)
            for i in range(3)]
    resolved, _ = _run(eng, reqs)
    assert all(r.trace is None for r in resolved.values())
    assert eng._tick_wall.count == 0
    assert eng.tracer.collected == 0


def test_streams_bit_identical_tracing_on_vs_off(granite):
    cfg, params = granite
    outs = {}
    for tracing in (False, True):
        eng = ServingEngine(cfg, params, EngineConfig(
            slots=2, window=64, max_seq=128, sync_every=4, tracing=tracing))
        reqs = [Request(rid=i, prompt=_prompt(9 + i, seed=i),
                        max_new_tokens=5, sampling=_samp(300 + i))
                for i in range(4)]
        resolved, _ = _run(eng, reqs)
        outs[tracing] = {rid: list(map(int, r.output))
                         for rid, r in resolved.items()}
    assert outs[False] == outs[True]


def test_preempt_restore_spans(granite):
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=1, prefix_cache=True,
        tracing=True))
    victim = Request(rid=0, prompt=_prompt(12), max_new_tokens=8,
                     sampling=_samp(42))
    eng.submit(victim, 0.0)
    for t in (1.0, 2.0, 3.0):
        eng.step(t)
    assert eng.preempt(0, 3.0) is victim
    assert victim.state is RequestState.PREEMPTED
    eng.submit(victim, 4.0)  # requeue for restore
    t = 4.0
    while not victim.done:
        t += 1.0
        eng.step(t)
    eng.drain(t)
    kinds = victim.trace.kinds()
    assert {"preempt", "restore", "queued", "prefill", "decode"} <= set(kinds)
    assert victim.trace.validate() == []
    # two decode spans: pre-eviction and post-restore
    decodes = [sp for sp in victim.trace.spans if sp.kind == "decode"]
    assert len(decodes) == 2 and all(not sp.open for sp in decodes)


def test_failover_spans_survive_replica_death(granite):
    cfg, params = granite
    proxies = [FaultyEngine(ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=1, tracing=True)))
        for _ in range(2)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         health_timeout_s=50.0, max_retries=3,
                         retry_backoff_s=1.0, tracing=True)
    reqs = [Request(rid=i, prompt=_prompt(10 + i, seed=i), max_new_tokens=6,
                    sampling=_samp(500 + i)) for i in range(6)]
    resolved, t = {}, 0.0
    for r in reqs:
        fe.submit(r, 0.0)
    while len(resolved) < len(reqs) and t < 200.0:
        t += 1.0
        if t == 2.0:
            proxies[0].inject("kill")
        for r in fe.step(t):
            resolved[r.rid] = r
    assert len(resolved) == len(reqs)
    assert all(r.state is RequestState.FINISHED for r in resolved.values())
    retried = [r for r in resolved.values()
               if "failover_retry" in r.trace.kinds()]
    assert retried, "the dead replica held work; someone must have failed over"
    for r in resolved.values():
        assert r.trace.validate() == [], (r.rid, r.trace.validate())
        assert "dispatch" in r.trace.kinds()
    # the frontend-created traces flow through lanes by serving replica
    lanes = {lane for lane, _t in request_traces(resolved.values())}
    assert lanes and all(lane.startswith("pool/") for lane in lanes)


# ---------------------------------------------------------------------------
# compile accounting + profiler hook + registries
# ---------------------------------------------------------------------------


def test_compile_events_flat_across_second_workload(granite):
    cfg, params = granite
    eng = make_engine(cfg, params, slots=2, window=64, max_seq=128,
                      sync_every=4, tracing=True)
    _run(eng, [Request(rid=i, prompt=_prompt(9 + i, seed=i),
                       max_new_tokens=4) for i in range(3)])
    assert eng.compile_events
    assert sum(eng.compile_events.values()) >= eng.decode_traces
    warm = dict(eng.compile_events)
    eng.reset()
    assert eng.compile_events == warm  # reset keeps warm jit caches
    _run(eng, [Request(rid=10 + i, prompt=_prompt(9 + i, seed=i),
                       max_new_tokens=4) for i in range(3)])
    assert eng.compile_events == warm, "second workload must not retrace"
    rep = eng.load_report()
    assert dict((k, v) for k, v in rep.compile_events) == warm


def test_profiler_hook_gated_by_config(granite, monkeypatch, tmp_path):
    cfg, params = granite
    calls = []
    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop", None)))
    bare = make_engine(cfg, params, slots=2, window=64)
    assert bare.start_profile() is False  # no profile_dir: disarmed
    eng = make_engine(cfg, params, slots=2, window=64,
                      profile_dir=str(tmp_path))
    assert eng.start_profile() is True
    assert eng.start_profile() is False  # already profiling
    assert eng.stop_profile() is True
    assert eng.stop_profile() is False
    assert calls == [("start", str(tmp_path)), ("stop", None)]


def test_engine_and_cluster_metrics_registries(granite):
    cfg, params = granite
    engines = [ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=4, tracing=True))
        for _ in range(2)]
    fe = ClusterFrontend(engines, policy="round-robin", seed=0, tracing=True)
    reqs = [Request(rid=i, prompt=_prompt(8 + i, seed=i), max_new_tokens=4)
            for i in range(6)]
    resolved, t = {}, 0.0
    for r in reqs:
        fe.submit(r, 0.0)
    while len(resolved) < len(reqs) and t < 200.0:
        t += 1.0
        for r in fe.step(t):
            resolved[r.rid] = r
    reg = engines[0].metrics_registry()
    assert reg.get("serving_completed_total").value \
        == engines[0].metrics.completed
    assert "serving_prefill_traces_total" in reg
    creg = fe.metrics_registry()
    assert creg.get("cluster_completed_total").value == len(reqs)
    jct = creg.get("cluster_jct_seconds")
    assert jct.count == len(reqs)
    expo = creg.exposition()
    assert "cluster_jct_seconds_bucket" in expo
    snap = creg.snapshot()
    json.dumps(snap)
    assert snap["cluster_completed_total"] == len(reqs)


def test_load_report_histograms_merge_across_replicas(granite):
    """The v3 wire histograms rebuild and merge exactly — the cluster
    percentile path without sample shipping."""
    cfg, params = granite
    merged = latency_histogram()
    total = 0
    for k in range(2):
        eng = make_engine(cfg, params, slots=2, window=64, sync_every=4)
        _run(eng, [Request(rid=10 * k + i, prompt=_prompt(8 + i, seed=i),
                           max_new_tokens=4) for i in range(3)])
        hists = dict(eng.load_report().histograms)
        h = Histogram.from_wire(hists["jct_s"])
        total += h.count
        merged.merge(h)
    assert merged.count == total == 6
    assert merged.percentile(50) > 0


# ---------------------------------------------------------------------------
# interference residual histogram (mean-preserving closed loop)
# ---------------------------------------------------------------------------


def test_interference_correction_equals_running_mean():
    from repro.core.misd.interference import InterferencePredictor

    p = InterferencePredictor()
    rng = np.random.default_rng(3)
    resids = []
    for _ in range(50):
        pred = float(rng.uniform(0.5, 2.0))
        act = float(rng.uniform(0.5, 2.0))
        p.observe(pred, act)
        resids.append(-(act - pred) / pred)
    # bit-equal to the bare accumulator it replaced: same sum, same order
    want = 0.0
    for r in resids:
        want += r
    assert p.correction == want / len(resids)
    assert p._n == 50 and p._resid_sum == want  # compat views
    assert p.residuals.count == 50  # the distribution is now observable
    assert p.residuals.percentile(50) != 0.0 or all(r == 0 for r in resids)
