"""Device-resident stochastic sampling through the fused decode window.

The contract under test (ISSUE 5 acceptance): one decode trace + one
fused-window trace no matter the greedy/stochastic slot mix; seeded
sampled streams bit-identical across engine restarts, slot assignments,
admission paths (bucketed / chunked / prefix-hit suffix), and cache
layouts; greedy as the exact degenerate case (temperature -> 0 converges,
top-k = 1 equals greedy outright)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, process_logits
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine

SP = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=7)


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _serve(eng, reqs, *, t0=0.0):
    for r in reqs:
        eng.submit(r, t0)
    t = t0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t)
    return t


def _streams(cfg, params, rids, *, sampling=None, engine=None, **kw):
    """Serve one request per rid (prompt/seed keyed by rid); returns
    {rid: output}. ``sampling`` may be a callable rid -> SamplingParams."""
    eng = engine or ServingEngine(cfg, params, EngineConfig(**kw))
    if engine is not None:
        eng.reset()
    reqs = []
    for rid in rids:
        sp = sampling(rid) if callable(sampling) else (sampling
                                                       or SamplingParams())
        reqs.append(Request(rid=rid, prompt=_prompt(10 + rid % 3, seed=rid),
                            max_new_tokens=8, sampling=sp))
    _serve(eng, reqs)
    return {r.rid: r.output for r in reqs}, eng


# ---------------------------------------------------------------------------
# single-trace probes with mixed greedy/stochastic batches
# ---------------------------------------------------------------------------


def test_mixed_batch_single_decode_trace(granite):
    """Acceptance probe: greedy and sampled slots share ONE decode trace
    and ONE fused-window trace — the stochastic branch is masked
    composition inside the same jit program, never a retrace."""
    cfg, params = granite
    mix = lambda rid: SP if rid % 2 else SamplingParams()  # noqa: E731
    out, eng = _streams(cfg, params, range(4), sampling=mix,
                        slots=4, window=64, sync_every=4, chunk_prefill=0)
    assert eng.decode_traces <= 2  # single tick + fused scan
    assert eng.prefill_traces <= 2  # one per prompt bucket
    assert eng.metrics.sampled_requests == 2
    # the sampled slots actually diverge from greedy decode
    greedy_out, _ = _streams(cfg, params, [1], slots=4, window=64,
                             sync_every=4, chunk_prefill=0)
    assert out[1] != greedy_out[1]
    # admitting MORE sampled traffic onto the warm engine retraces nothing
    before = eng.decode_traces
    _streams(cfg, params, range(4), sampling=SP, engine=eng)
    assert eng.decode_traces == before


def test_all_greedy_batch_unchanged_by_sampling_state(granite):
    """A fully greedy batch on the sampling-capable engine produces the
    same streams as before the subsystem existed (the greedy lane is
    argmax, not a temperature-1 draw)."""
    cfg, params = granite
    out, eng = _streams(cfg, params, range(3), slots=3, window=64,
                        sync_every=4)
    from repro.serving import generate

    for rid, stream in out.items():
        assert stream == generate(cfg, params, _prompt(10 + rid % 3,
                                                       seed=rid), 8, window=64)


# ---------------------------------------------------------------------------
# seeded-stream reproducibility
# ---------------------------------------------------------------------------


def test_sampled_streams_reproducible_across_restart_and_slot_order(granite):
    """Fixed seed => bit-identical stream on a fresh engine, under a
    different submission order (different slot assignment), and alongside
    a different batch mix."""
    cfg, params = granite
    a, _ = _streams(cfg, params, [0, 1, 2, 3], sampling=SP, slots=4,
                    window=64, sync_every=4)
    b, _ = _streams(cfg, params, [3, 1, 0, 2], sampling=SP, slots=4,
                    window=64, sync_every=4)
    assert a == b
    # same request alone in the batch: stream unchanged
    solo, _ = _streams(cfg, params, [2], sampling=SP, slots=4, window=64,
                       sync_every=4)
    assert solo[2] == a[2]


def test_sampled_streams_reproducible_across_cache_layout_and_fusion(granite):
    """The same seeded request decodes identically under paged vs rolling
    caches and fused vs single-tick windows."""
    cfg, params = granite
    base, _ = _streams(cfg, params, [0, 1], sampling=SP, slots=2,
                       window=64, sync_every=4)
    rolling, _ = _streams(cfg, params, [0, 1], sampling=SP, slots=2,
                          window=64, sync_every=4, paged=False)
    unfused, _ = _streams(cfg, params, [0, 1], sampling=SP, slots=2,
                          window=64, sync_every=1)
    assert base == rolling == unfused


def test_seed_changes_the_stream(granite):
    cfg, params = granite
    a, _ = _streams(cfg, params, [0], sampling=SamplingParams(
        temperature=1.2, seed=1), slots=1, window=64)
    b, _ = _streams(cfg, params, [0], sampling=SamplingParams(
        temperature=1.2, seed=2), slots=1, window=64)
    assert a[0] != b[0]


def test_sampled_stream_survives_every_admission_path(granite):
    """Bucketed single-shot, interleaved chunked prefill, and the
    prefix-cache hit (suffix-offset prefill over aliased pages) must all
    produce the same seeded stream — the first token's noise is keyed by
    (seed, prompt_len) in every path."""
    cfg, params = granite
    prompt = _prompt(40, seed=9)

    def run(**kw):
        eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=128, sync_every=4,
                            **kw))
        r = Request(rid=0, prompt=prompt, max_new_tokens=8, sampling=SP)
        assert eng.try_admit(r, 0.0)
        t = 0.0
        while not r.done:
            t += 1.0
            eng.step(t)
        eng.drain(t)
        return r.output, eng

    single, _ = run(chunk_prefill=0)
    chunked, _ = run(chunk_prefill=16)
    assert chunked == single

    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=128, sync_every=4,
                        prefix_cache=True))
    cold = Request(rid=0, prompt=prompt, max_new_tokens=8, sampling=SP)
    assert eng.try_admit(cold, 0.0)
    t = 0.0
    while not cold.done:
        t += 1.0
        eng.step(t)
    eng.drain(t)
    warm = Request(rid=1, prompt=prompt, max_new_tokens=8, sampling=SP)
    assert eng.try_admit(warm, t)
    while not warm.done:
        t += 1.0
        eng.step(t)
    eng.drain(t)
    assert eng.metrics.prefix_hits == 1
    assert warm.output == cold.output == single


# ---------------------------------------------------------------------------
# logit-processor invariants (hypothesis properties: test_sampling_property)
# ---------------------------------------------------------------------------


def test_logit_processor_masks():
    """top-k keeps exactly k survivors (no value ties in model logits);
    the nucleus always covers mass >= top_p; both off = pure rescale."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((3, 200)) * 2, jnp.float32)
    temp = jnp.full((3,), 0.9, jnp.float32)
    k = jnp.asarray([1, 7, 0], jnp.int32)
    proc = process_logits(logits, temp, k, jnp.ones((3,), jnp.float32))
    alive = np.isfinite(np.asarray(proc)).sum(axis=1)
    assert list(alive) == [1, 7, 200]
    topp = jnp.asarray([0.3, 0.8, 1.0], jnp.float32)
    proc = process_logits(logits, temp, jnp.zeros((3,), jnp.int32), topp)
    p = np.asarray(jax.nn.softmax(logits / 0.9, axis=-1))
    for row, thresh in enumerate((0.3, 0.8)):
        kept = np.isfinite(np.asarray(proc[row]))
        assert p[row, kept].sum() >= thresh  # nucleus reaches the mass
        # minimality: dropping the smallest kept entry dips below it
        smallest = p[row][kept].min()
        assert p[row, kept].sum() - smallest < thresh


def test_hot_path_draw_agrees_with_logit_processor_mask(granite):
    """The inverse-CDF hot path (sample_tokens, prob-space radix) must
    only ever emit tokens inside the logit-space processor's kept set —
    the two mask formulations are order-isomorphic by construction."""
    import jax.numpy as jnp

    from repro.models.layers import sample_tokens

    rng = np.random.default_rng(11)
    b, v = 4, 300
    logits = jnp.asarray(rng.standard_normal((b, v)) * 2, jnp.float32)
    temp = jnp.full((b,), 0.8, jnp.float32)
    k = jnp.asarray([1, 5, 40, 0], jnp.int32)
    tp = jnp.asarray([1.0, 0.9, 0.6, 0.4], jnp.float32)
    allowed = np.isfinite(np.asarray(process_logits(logits, temp, k, tp)))
    samp = {
        "greedy": jnp.zeros((b,), jnp.bool_),
        "temperature": temp, "top_k": k, "top_p": tp,
        "key": jnp.stack([jnp.asarray(jax.random.PRNGKey(i))
                          for i in range(b)]).astype(jnp.uint32),
    }
    for pos0 in range(0, 64, 4):
        pos = jnp.arange(pos0, pos0 + b, dtype=jnp.int32)
        tok = np.asarray(sample_tokens(logits, samp, pos))
        assert all(allowed[i, tok[i]] for i in range(b)), (pos0, tok)


def test_hot_path_mask_exact_on_prob_collapsed_ties():
    """Adversarial tie (found in review): two distinct logits whose
    float32 softmax probabilities are bit-equal. The top-k cut must run
    in logit space — a prob-space cut would keep both and emit a token
    outside the configured top-k set."""
    import jax.numpy as jnp

    from repro.models.layers import sample_tokens

    v = 64
    row = np.zeros(v, np.float32)
    row[0], row[1], row[2] = 5.0, 1.0, 1.0 + 1e-7
    logits = jnp.asarray(row[None])
    assert float(jax.nn.softmax(logits)[0, 1]) == float(
        jax.nn.softmax(logits)[0, 2])  # the collapse this test needs
    samp = {
        "greedy": jnp.zeros((1,), jnp.bool_),
        "temperature": jnp.ones((1,), jnp.float32),
        "top_k": jnp.full((1,), 2, jnp.int32),
        "top_p": jnp.ones((1,), jnp.float32),
        "key": jnp.asarray(jax.random.PRNGKey(0))[None].astype(jnp.uint32),
    }
    allowed = np.isfinite(np.asarray(process_logits(
        logits, samp["temperature"], samp["top_k"], samp["top_p"])))[0]
    assert allowed.sum() == 2 and allowed[0] and allowed[2]
    for p in range(200):
        tok = int(sample_tokens(logits, samp,
                                jnp.asarray([p], jnp.int32))[0])
        assert allowed[tok], (p, tok)
