"""Cluster frontend: SLO-aware routing of live traffic across
ServingEngine replicas — load_report telemetry, EDF ordering, policy
routing, retire/drain, autoscale hooks, closed-loop correction, and the
bit-identical-streams / no-page-leak invariants."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import estimate_backlog_s
from repro.core.misd.interference import InterferencePredictor
from repro.models import init_params
from repro.serving import (
    ClusterFrontend,
    EngineConfig,
    RequestState,
    ServeMetrics,
    ServingEngine,
)

# Requests ride the CI config matrix (rid-stable sampled seeds under
# REPRO_ENGINE_SAMPLING=sampled; conftest.make_request shares Request's
# positional signature), so routing/SLO/stream-identity invariants are
# exercised under stochastic decode as well.
from conftest import make_request as Request


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def pair(granite):
    """Two live replicas shared (and reset) across tests so their jit
    caches stay warm."""
    cfg, params = granite
    engines = [ServingEngine(cfg, params, EngineConfig(slots=2, window=64, max_seq=128,
                             sync_every=4)) for _ in range(2)]
    return cfg, params, engines


def _reset(eng):
    eng.reset()


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _drive(server, reqs, *, t0=0.0, max_steps=5000):
    done, t = 0, t0
    for r in reqs:
        server.submit(r, t)
    while done < len(reqs):
        t += 1.0
        done += len(server.step(t))
        assert t - t0 < max_steps
    server.drain(t)
    return t


# ---------------------------------------------------------------------------
# telemetry + SLO plumbing (no cluster needed)
# ---------------------------------------------------------------------------


def test_load_report_tracks_queue_and_slots(pair):
    _, _, engines = pair
    eng = engines[0]
    _reset(eng)
    rep = eng.load_report()
    assert rep.free_slots == eng.slots and not rep.saturated
    assert rep.backlog_s == 0.0 and rep.queued_requests == 0
    reqs = [Request(i, _prompt(12, seed=i), max_new_tokens=8)
            for i in range(4)]
    for r in reqs:
        eng.submit(r, 0.0)
    rep = eng.load_report()
    assert rep.free_slots == 0 and rep.saturated
    assert rep.queued_requests == 2  # 2 admitted, 2 queued
    assert rep.queued_prefill_tokens == 24
    assert len(rep.active_remaining) == 2 and len(rep.queued_budgets) == 2
    assert rep.decode_tokens_remaining > 0 and rep.backlog_s > 0
    assert rep.tick_est_s > 0
    assert rep.free_pages >= 0 and rep.total_pages > 0
    t = 0.0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t)
    rep = eng.load_report()
    assert rep.free_slots == eng.slots and rep.backlog_s == 0.0


def test_estimate_backlog_monotone(granite):
    cfg, _ = granite
    kw = dict(slots=2, context=128)
    zero = estimate_backlog_s(cfg, queued_prefill_tokens=0,
                              decode_tokens_remaining=0, **kw)
    some = estimate_backlog_s(cfg, queued_prefill_tokens=64,
                              decode_tokens_remaining=32, **kw)
    more = estimate_backlog_s(cfg, queued_prefill_tokens=64,
                              decode_tokens_remaining=320, **kw)
    assert zero == 0.0 and 0 < some < more


def test_slo_fields_and_goodput_metrics():
    req = Request(0, _prompt(8), max_new_tokens=5, arrival_time=2.0,
                  ttft_slo_s=3.0, tpot_slo_s=1.5)
    assert req.ttft_deadline == 5.0
    req.prefill_done = 4.0
    req.output = [1, 2, 3, 4, 5]
    req.finish_time = 8.0
    assert req.ttft == 2.0 and req.tpot == 1.0
    assert req.meets_slo() is True
    late = Request(1, _prompt(8), 5, arrival_time=0.0, ttft_slo_s=1.0)
    late.prefill_done, late.finish_time, late.output = 2.0, 3.0, [1]
    assert late.meets_slo() is False
    untracked = Request(2, _prompt(8), 5)
    assert untracked.meets_slo() is None
    assert untracked.ttft_deadline == float("inf")
    m = ServeMetrics()
    for r in (req, late, untracked):
        m.record_slo(r)
    assert m.slo_tracked == 2 and m.slo_met == 1
    assert m.ttft_slo_misses == 1 and m.tpot_slo_misses == 0
    assert m.goodput == 0.5
    m2 = ServeMetrics()
    m2.record_slo(req)
    m2.merge(m)
    assert m2.slo_tracked == 3 and m2.slo_met == 2
    assert ServeMetrics().goodput == 1.0  # nothing tracked = nothing missed


def test_engine_records_slo_attainment(granite):
    """The engine folds each finished request's SLO verdict into its
    metrics; a generous TTFT SLO passes, an impossible one misses."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, chunk_prefill=0))
    good = Request(0, _prompt(8), max_new_tokens=3, arrival_time=0.0,
                   ttft_slo_s=100.0)
    bad = Request(1, _prompt(8, seed=1), max_new_tokens=3, arrival_time=-50.0,
                  ttft_slo_s=1e-9)
    t = 0.0
    for r in (good, bad):
        eng.submit(r, t)
        while not r.done:
            t += 1.0
            eng.step(t)
    eng.drain(t)
    m = eng.metrics
    assert m.slo_tracked == 2 and m.slo_met == 1 and m.ttft_slo_misses == 1
    assert m.goodput == 0.5


def test_engine_edf_backlog_ordering(granite):
    """With edf_backlog the engine admits the earliest-TTFT-deadline
    request first, regardless of submission order; FIFO stays default."""
    cfg, params = granite

    def run(edf):
        eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64,
                            chunk_prefill=0, edf_backlog=edf))
        blocker = Request(9, _prompt(8, seed=9), max_new_tokens=2)
        eng.submit(blocker, 0.0)  # occupies the only slot
        loose = Request(0, _prompt(8, seed=1), max_new_tokens=2,
                        arrival_time=0.0, ttft_slo_s=100.0)
        tight = Request(1, _prompt(8, seed=2), max_new_tokens=2,
                        arrival_time=0.0, ttft_slo_s=1.0)
        eng.submit(loose, 0.0)
        eng.submit(tight, 0.0)
        t = 0.0
        while not (loose.done and tight.done):
            t += 1.0
            eng.step(t)
        eng.drain(t)
        return loose, tight

    loose, tight = run(edf=True)
    assert tight.prefill_done < loose.prefill_done  # EDF: tight jumps ahead
    loose, tight = run(edf=False)
    assert loose.prefill_done < tight.prefill_done  # FIFO preserved


def test_interference_latency_loop():
    """observe_latency shifts corrected_latency toward reality; out-of-band
    observations (mismatched regimes) are rejected, in-band outliers are
    clamped."""
    p = InterferencePredictor()
    assert p.corrected_latency(1.0) == pytest.approx(1.0)
    for _ in range(50):
        p.observe_latency(1.0, 2.0)  # consistently 2x slower than predicted
    assert p.corrected_latency(1.0) == pytest.approx(2.0, rel=0.05)
    q = InterferencePredictor()
    q.observe_latency(1.0, 1e-6)  # out of band: ignored entirely
    q.observe_latency(1.0, 1e6)
    assert q.correction == 0.0
    q.observe_latency(1.0, 20.0)  # in band, clamped to 4x
    assert q.corrected_latency(1.0) <= 4.5


# ---------------------------------------------------------------------------
# cluster routing over live engines
# ---------------------------------------------------------------------------


def test_cluster_streams_bit_identical_to_single_engine(pair):
    """Acceptance: token streams from the cluster frontend match
    single-engine serving for the same requests, for every policy."""
    cfg, params, engines = pair

    def mk_reqs():
        return [Request(i, _prompt(10 + 7 * i, seed=i), max_new_tokens=5,
                        arrival_time=0.0, ttft_slo_s=50.0)
                for i in range(5)]

    _reset(engines[0])
    ref = mk_reqs()
    _drive(engines[0], ref)
    ref_out = {r.rid: r.output for r in ref}
    for policy in ("round-robin", "predicted"):
        for eng in engines:
            _reset(eng)
        fe = ClusterFrontend(engines, policy=policy, seed=0)
        reqs = mk_reqs()
        _drive(fe, reqs)
        assert {r.rid: r.output for r in reqs} == ref_out, policy
        assert all(r.routed_to for r in reqs)


def test_cluster_releases_pages_on_every_engine(pair):
    """Satellite: slot release under the cluster frontend never leaks
    pages — after a full run every replica's allocator is empty and the
    allocators never shared a page (per-engine pools are disjoint by
    construction; the leak mode is a request finishing on engine A while
    its pages were reserved on B)."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines, policy="least-loaded", seed=0)
    reqs = [Request(i, _prompt(12 + 5 * i, seed=i), max_new_tokens=4)
            for i in range(8)]
    _drive(fe, reqs)
    for eng in engines:
        assert eng.paged and eng.allocator.pages_in_use == 0
        assert eng.allocator.free_pages == eng.allocator.capacity
    # every request was admitted (and its pages charged) on the engine it
    # was routed to — not on any other replica
    names = {i.name for i in fe.instances}
    assert {r.routed_to for r in reqs} <= names


def test_cluster_retire_drains_without_new_routes(pair):
    """A retired replica finishes its in-flight work but receives no new
    routes, and drops out of the cluster once idle."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines, policy="round-robin", seed=0)
    first = [Request(i, _prompt(10, seed=i), max_new_tokens=6)
             for i in range(2)]
    for r in first:
        fe.submit(r, 0.0)
    fe.step(1.0)  # one request on each replica
    victim_name = first[0].routed_to
    victim = fe.retire(victim_name)
    assert victim is not None and victim.draining
    assert len(fe.instances) == 1 and fe.pool() and len(fe.pool()) == 1
    late = [Request(10 + i, _prompt(9, seed=10 + i), max_new_tokens=3)
            for i in range(3)]
    t = 1.0
    for r in late:
        fe.submit(r, t)
    while not all(r.done for r in first + late):
        t += 1.0
        fe.step(t)
    fe.drain(t)
    assert all(r.routed_to != victim_name for r in late)
    assert all(len(r.output) == r.max_new_tokens for r in first + late)
    fe.step(t + 1.0)  # reap: the drained victim leaves the cluster
    assert fe.draining == []
    assert victim.engine.allocator.pages_in_use == 0


def test_cluster_retire_requeues_unstarted_backlog(pair):
    """Satellite fix: retiring a replica used to strand its queued-but-
    unstarted backlog behind the drain (they'd finish, but only on the
    retiree, defeating the retire). Now `retire` pulls that backlog back
    through the frontend and re-routes it to live replicas; the retiree
    only finishes what it had actually started."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines[:1], policy="round-robin", seed=0)
    reqs = [Request(i, _prompt(10 + i, seed=i), max_new_tokens=4)
            for i in range(6)]
    for r in reqs:
        fe.submit(r, 0.0)
    fe.step(1.0)  # slots=2: two started, four queued on the lone replica
    victim_name = fe.instances[0].name
    started = [r for r in reqs if r.prefill_done >= 0]
    assert 0 < len(started) <= 2
    fe.add_engine(engines[1])
    victim = fe.retire(victim_name)
    assert victim is not None and victim.draining
    # the retiree's queue was taken over, not left to drain
    assert len(victim.engine.backlog) == 0
    assert len(victim.engine.admission.pending) == 0
    t = 1.0
    while not all(r.done for r in reqs):
        t += 1.0
        fe.step(t)
        assert t < 200
    fe.drain(t)
    assert all(len(r.output) == 4 for r in reqs)
    unstarted = [r for r in reqs if r not in started]
    assert all(r.routed_to != victim_name for r in unstarted)
    assert fe.merged_metrics().completed == 6
    for eng in engines[:2]:
        assert eng.allocator.pages_in_use == 0


def test_cluster_autoscale_hooks(pair):
    """Queue pressure grows the pool via the spawn callback; an idle pool
    shrinks by retiring (and draining) the least-loaded replica."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines[:1], policy="predicted", seed=0)
    assert len(fe.instances) == 1
    # queue pressure: saturate the lone replica, then autoscale out
    reqs = [Request(i, _prompt(16, seed=i), max_new_tokens=12)
            for i in range(6)]
    for r in reqs:
        fe.submit(r, 0.0)
    fe.step(1.0)
    assert fe.instances[0].queue_s > 0  # sync() mirrored real telemetry
    grown = fe.autoscale(1.0, spawn=lambda: engines[1], high_s=1e-9)
    assert grown is not None and len(fe.instances) == 2
    t = 1.0
    while not all(r.done for r in reqs):
        t += 1.0
        fe.step(t)
    fe.drain(t)
    # idle now: pressure ~ 0 -> shrink retires one replica
    shrunk = fe.autoscale(t, low_s=1.0)
    assert shrunk is not None and len(fe.instances) == 1
    fe.step(t + 1.0)
    assert fe.draining == []  # already idle, reaped immediately


def test_cluster_emptied_pool_holds_queue_until_replica_returns(pair):
    """Retiring the last replica of a pool must not crash the step or
    drop queued requests: they wait at the frontend and dispatch as soon
    as a replica registers again."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines[:1], policy="predicted", seed=0)
    req = Request(0, _prompt(10), max_new_tokens=3, arrival_time=0.0)
    fe.submit(req, 0.0)
    fe.retire(fe.instances[0].name)  # pool now empty, request still queued
    fe.step(1.0)  # must hold, not crash/lose
    assert not req.routed_to and fe._queue
    fe.add_engine(engines[1])
    t = 1.0
    while not req.done:
        t += 1.0
        fe.step(t)
    fe.drain(t)
    assert req.routed_to and len(req.output) == 3


def test_cluster_multi_model_pools(pair):
    """Requests tagged with a model only ever land in that model's pool;
    an untagged request with no default pool is rejected loudly."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend({"chat": engines[:1], "code": engines[1:]},
                         policy="predicted", seed=0)
    chat = [Request(i, _prompt(10, seed=i), max_new_tokens=3, model="chat")
            for i in range(2)]
    code = [Request(10 + i, _prompt(14, seed=9 + i), max_new_tokens=3,
                    model="code") for i in range(2)]
    _drive(fe, chat + code)
    assert {r.routed_to for r in chat} == {"chat/e0"}
    assert {r.routed_to for r in code} == {"code/e1"}
    # an unroutable model tag is a typed rejection, not a frontend crash:
    # the request resolves FAILED through the next step and is counted
    stray = Request(99, _prompt(8), 2, model="missing")
    assert fe.submit(stray, 0.0) is False
    assert stray.state is RequestState.FAILED
    assert "no engine pool" in stray.fail_reason
    assert stray in fe.step(0.0)
    assert fe.merged_metrics().rejected == 1


def test_cluster_edf_frontend_dispatch_order(pair):
    """Within one tick, the tightest TTFT deadline is routed (and thus
    admitted) first even when submitted last."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines[:1], policy="round-robin", seed=0)
    loose = Request(0, _prompt(8, seed=1), max_new_tokens=2,
                    arrival_time=0.0, ttft_slo_s=90.0)
    tight = Request(1, _prompt(8, seed=2), max_new_tokens=2,
                    arrival_time=0.0, ttft_slo_s=1.0)
    mid = Request(2, _prompt(8, seed=3), max_new_tokens=2,
                  arrival_time=0.0, ttft_slo_s=30.0)
    for r in (loose, mid, tight):  # deliberately worst-case order
        fe.submit(r, 0.0)
    t = 0.0
    while not all(r.done for r in (loose, mid, tight)):
        t += 1.0
        fe.step(t)
    fe.drain(t)
    assert tight.prefill_done <= mid.prefill_done <= loose.prefill_done


def test_heterogeneous_pool_routes_more_to_bigger_replica(granite):
    """Satellite: per-replica n_chips flows through EngineInstance into
    predicted-completion routing — a 4-chip replica's cost-model service
    is cheaper, so it should absorb clearly more of the traffic than its
    1-chip sibling (and the pool still drains correctly)."""
    cfg, params = granite
    engines = [ServingEngine(cfg, params, EngineConfig(slots=2, window=64, max_seq=128,
                             sync_every=4, modeled_chips=c)) for c in (1, 4)]
    fe = ClusterFrontend(engines, policy="predicted", seed=0)
    small, big = fe.instances
    assert small.device.speed == 1.0 and big.device.speed == 4.0
    reqs = [Request(i, _prompt(12 + (i % 5), seed=i), max_new_tokens=6)
            for i in range(12)]
    # trickle arrivals so routing reacts to load, not just an empty tie
    t, done, pending = 0.0, 0, list(reqs)
    while done < len(reqs):
        if pending:
            fe.submit(pending.pop(0), t)
        t += 1.0
        done += len(fe.step(t))
        assert t < 5000
    fe.drain(t)
    assert small.routed + big.routed == len(reqs)
    assert big.routed > small.routed  # more chips -> more traffic
    for eng in engines:
        assert eng.allocator.pages_in_use == 0


def test_prefix_affinity_routes_template_to_warm_replica(granite):
    """Satellite/tentpole: with prefix caching on, predicted-completion
    routing includes the affinity term — requests sharing a template land
    on the replica that already holds its pages (and actually hit)."""
    cfg, params = granite
    engines = [ServingEngine(cfg, params, EngineConfig(slots=2, window=64, max_seq=128,
                             sync_every=4, prefix_cache=True))
               for _ in range(2)]
    fe = ClusterFrontend(engines, policy="predicted", seed=0)
    tpl = _prompt(48, seed=40)
    # warm the SECOND replica directly: on an idle cluster the routing
    # tie-break alone would pick e0, so landing on e1 proves the
    # affinity term (not registration order) steered the choice
    primer = Request(0, tpl.copy(), max_new_tokens=1)
    assert engines[1].try_admit(primer, 0.0)
    engines[1].drain(0.0)
    home = fe.instances[1].name
    followups = [Request(1 + i,
                         np.concatenate([tpl, _prompt(4 + i, seed=41 + i)]
                                        ).astype(np.int32),
                         max_new_tokens=2) for i in range(4)]
    t = 1000.0
    for r in followups:  # idle cluster each time: affinity is the tiebreak
        t = _drive(fe, [r], t0=t) + 1.0
    assert all(r.routed_to == home for r in followups)
    assert all(r.prefix_hit_tokens == 48 for r in followups)
    # unrelated traffic is NOT pulled toward the warm replica's pages
    stranger = Request(99, _prompt(20, seed=77), max_new_tokens=2)
    probe_inst = next(i for i in fe.instances if i.name == home)
    job = fe._job_for(stranger, t)
    assert probe_inst.prefix_hit_s(job) == 0.0
    for eng in engines:
        assert eng.allocator.pages_in_use == eng.prefix_index.cached_pages


def test_cluster_closed_loop_observes(pair):
    """Serving traffic populates each instance's corrector with residual
    observations (predicted vs observed TTFT/JCT)."""
    _, _, engines = pair
    for eng in engines:
        _reset(eng)
    fe = ClusterFrontend(engines, policy="predicted", seed=0)
    # drive on the cost-model tick scale so observed waits land in the
    # corrector's accepted band (wall-clock-consistent virtual time)
    dt = engines[0].load_report().tick_est_s
    reqs = [Request(i, _prompt(10 + i, seed=i), max_new_tokens=6,
                    arrival_time=0.0, ttft_slo_s=1000 * dt)
            for i in range(6)]
    done, t = 0, 0.0
    for r in reqs:
        fe.submit(r, t)
    while done < len(reqs):
        t += dt
        done += len(fe.step(t))
    fe.drain(t)
    assert sum(inst.corrector._n for inst in fe.instances) > 0
    m = fe.merged_metrics()
    assert m.completed == len(reqs) and m.slo_tracked == len(reqs)
    util = fe.utilization()
    assert set(util) == {i.name for i in fe.instances}
    assert all(0.0 <= u <= 1.0 for u in util.values())


def test_cluster_sampled_streams_stable_under_routing(pair):
    """ISSUE 5 acceptance: a seeded sampled request produces the SAME
    token stream no matter which replica the policy lands it on — noise
    is keyed by (seed, position), never by placement. Streams also match
    single-engine serving, and per-replica compile counts stay at the
    single-trace budget with mixed greedy/sampled traffic."""
    from repro.serving import SamplingParams

    cfg, params, engines = pair

    def mk_reqs():
        return [Request(i, _prompt(10 + 3 * i, seed=i), max_new_tokens=5,
                        arrival_time=0.0,
                        sampling=(SamplingParams(temperature=0.9, top_k=30,
                                                 top_p=0.95, seed=40 + i)
                                  if i % 2 else SamplingParams()))
                for i in range(6)]

    _reset(engines[0])
    ref = mk_reqs()
    _drive(engines[0], ref)
    ref_out = {r.rid: r.output for r in ref}
    placements = set()
    for policy in ("round-robin", "p2c", "predicted"):
        for eng in engines:
            _reset(eng)
        fe = ClusterFrontend(engines, policy=policy, seed=1)
        reqs = mk_reqs()
        _drive(fe, reqs)
        assert {r.rid: r.output for r in reqs} == ref_out, policy
        placements.add(tuple(r.routed_to for r in reqs))
        for eng in engines:
            assert eng.decode_traces <= 2, policy
    assert len(placements) > 1  # the policies really did place differently
    m = fe.merged_metrics()
    assert m.sampled_requests == 3  # cluster rollup counts sampled traffic
