import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config


def make_batch(cfg, b, s, *, labels=False, key=0):
    """Batch matching cfg's modality at (b, s)."""
    rng = np.random.default_rng(key)
    if cfg.modality == "audio":
        batch = {"frames": jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)}
        if labels:
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        return batch
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.rope_variant == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (3, b, s))
    if labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.fixture(params=ASSIGNED_ARCHS)
def arch_cfg(request):
    return get_config(request.param)
