import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# CI config-matrix knobs (ISSUE 5 + ISSUE 7 satellites): the same tier-1
# suite runs under {paged, rolling, prefix_cache} x {greedy, sampled} x
# {1-chip, tp8} engine configurations so a regression confined to one
# configuration cannot hide behind the default. Tests that build engines
# through ``make_engine`` / requests through ``make_request`` pick the
# matrix cell up from the environment; explicit kwargs always win, so tests
# pinning a specific configuration (e.g. the paged-vs-rolling A/Bs) are
# unaffected by the knob.
#
# REPRO_ENGINE_TOPOLOGY=tp8 runs every make_engine engine as ONE 8-way
# tensor/expert-parallel replica. The XLA host-device flag must be in place
# before jax initializes its backend, which is why it is injected HERE —
# conftest imports before any test module touches jax.
# ---------------------------------------------------------------------------

ENGINE_CACHE = os.environ.get("REPRO_ENGINE_CACHE", "")  # ""|paged|rolling|prefix_cache
ENGINE_SAMPLING = os.environ.get("REPRO_ENGINE_SAMPLING", "")  # ""|greedy|sampled
ENGINE_TOPOLOGY = os.environ.get("REPRO_ENGINE_TOPOLOGY", "")  # ""|tp8
# ""|int8 — run every (pageable-arch) make_engine engine with int8 KV-cache
# pages (ISSUE 10). Only injected when the test pins neither cache layout
# nor precision: quantized KV requires the paged cache, and tests that A/B
# paged-vs-rolling or assert engine-vs-f32-oracle exactness pin their
# config explicitly and stay lossless.
ENGINE_PRECISION = os.environ.get("REPRO_ENGINE_PRECISION", "")

if ENGINE_TOPOLOGY == "tp8":
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config


def engine_overrides(cfg) -> dict:
    """ServingEngine kwargs for the active matrix cell. Non-pageable archs
    keep their rolling fallback in every cell (paged/prefix demands would
    be construction errors, not coverage)."""
    from repro.models import paged_ok

    kw = {}
    if ENGINE_CACHE == "rolling":
        kw["paged"] = False
    elif ENGINE_CACHE == "paged" and paged_ok(cfg):
        kw["paged"] = True
    elif ENGINE_CACHE == "prefix_cache" and paged_ok(cfg):
        kw["prefix_cache"] = True
    if ENGINE_TOPOLOGY == "tp8":
        from repro.serving import DeviceTopology

        kw["topology"] = DeviceTopology(tp=8)
        # pin the legacy capacity behavior: the suite's engine-vs-forward
        # oracle comparisons must see the exact same MoE capacity dims
        # (the sharded-MoE "strict" default would change reduction tiling)
        kw["moe_capacity_policy"] = "drop"
    return kw


def matrix_sampling(rid: int = 0):
    """Per-request SamplingParams for the active matrix cell. The sampled
    cell exercises the stochastic decode path with a request-stable seed,
    so every determinism assertion (same config => identical streams)
    still holds."""
    from repro.serving import SamplingParams

    if ENGINE_SAMPLING == "sampled":
        return SamplingParams(temperature=0.7, top_k=20, top_p=0.95,
                              seed=1000 + rid)
    return SamplingParams()


def make_engine(cfg, params, **kw):
    """ServingEngine honoring the matrix cell; explicit kwargs win. Built
    through ``EngineConfig`` (the only construction path since the legacy
    shim was removed), so the whole suite exercises it."""
    from repro.models import paged_ok
    from repro.serving import EngineConfig, PrecisionConfig, ServingEngine

    merged = {**engine_overrides(cfg), **kw}
    if (ENGINE_PRECISION == "int8" and paged_ok(cfg)
            and not {"paged", "prefix_cache", "precision"} & merged.keys()):
        merged["precision"] = PrecisionConfig(kv_cache_dtype="int8")
    return ServingEngine(cfg, params, EngineConfig(**merged))


def make_request(rid, prompt, max_new_tokens, **kw):
    """Request honoring the matrix cell's sampling; explicit kwargs win."""
    from repro.serving import Request

    kw.setdefault("sampling", matrix_sampling(rid))
    return Request(rid, prompt, max_new_tokens, **kw)


def make_batch(cfg, b, s, *, labels=False, key=0):
    """Batch matching cfg's modality at (b, s)."""
    rng = np.random.default_rng(key)
    if cfg.modality == "audio":
        batch = {"frames": jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)}
        if labels:
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        return batch
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.rope_variant == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (3, b, s))
    if labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.fixture(params=ASSIGNED_ARCHS)
def arch_cfg(request):
    return get_config(request.param)
