"""Tensor/expert-parallel sharded serving: one replica over an 8-device
mesh must be observably IDENTICAL to the 1-chip engine — bit-identical
streams (the exact GSPMD profile all-gathers activations instead of
psum-reducing partial products), flat trace counts, and the same page
accounting under preemption churn.

These tests need 8 XLA devices. The CI shard8 matrix cell provides them
(REPRO_ENGINE_TOPOLOGY=tp8 makes conftest inject
``--xla_force_host_platform_device_count=8`` before jax initializes);
on a plain host they skip. Engines are built directly from pinned
``EngineConfig``s — each test needs a tp=1 and a tp=8 engine side by
side, so the matrix cell's topology override must not apply."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    DeviceTopology,
    EngineConfig,
    Request,
    SamplingParams,
    ServingEngine,
)

NDEV = 8
pytestmark = pytest.mark.skipif(
    jax.local_device_count() < NDEV,
    reason=f"needs {NDEV} XLA devices (run under "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV} "
           f"or the shard8 CI cell)")


@pytest.fixture(scope="module")
def dense():
    """8 kv heads so the paged pools' kv-head axis splits 8 ways."""
    cfg = dataclasses.replace(get_config("granite-8b").reduced(),
                              num_heads=NDEV, num_kv_heads=NDEV)
    return cfg, init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def moe():
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              num_heads=NDEV, num_kv_heads=NDEV,
                              num_experts=NDEV, moe_expert_parallel=True)
    return cfg, init_params(cfg, jax.random.key(1))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _mixed_workload(n, *, max_new=6):
    """Greedy and seeded-stochastic streams interleaved: identity must
    hold through BOTH the argmax and the gumbel/top-k sampling paths."""
    return [Request(rid=i, prompt=_prompt(8 + 2 * i, seed=i),
                    max_new_tokens=max_new,
                    sampling=(SamplingParams() if i % 2 == 0 else
                              SamplingParams(temperature=0.8, top_k=40,
                                             seed=100 + i)))
            for i in range(n)]


def _serve(eng, reqs, t0=0.0):
    t = t0
    for r in reqs:
        eng.submit(r, t)
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t + 1.0)
    return [tuple(r.output) for r in reqs]


def _pair(cfg, params, **kw):
    mk = lambda tp: ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, chunk_prefill=16,
        topology=DeviceTopology(tp=tp), **kw))
    return mk(1), mk(NDEV)


# ---------------------------------------------------------------------------
# stream bit-identity: the sharded-replica contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [dict(paged=True), dict(prefix_cache=True)],
                         ids=["paged", "prefix_cache"])
def test_sharded_streams_bit_identical(dense, kw):
    cfg, params = dense
    base, shard = _pair(cfg, params, **kw)
    assert shard.mesh is not None and base.mesh is None
    sb = _serve(base, _mixed_workload(4))
    ss = _serve(shard, _mixed_workload(4))
    assert sb == ss  # not close — EQUAL, token for token


def test_sharded_trace_parity(dense):
    """Tensor parallelism must not multiply compiles: the sharded engine
    reuses one prefill and one decode trace exactly like 1-chip."""
    cfg, params = dense
    base, shard = _pair(cfg, params)
    _serve(base, _mixed_workload(4))
    _serve(shard, _mixed_workload(4))
    assert (shard.prefill_traces, shard.decode_traces) \
        == (base.prefill_traces, base.decode_traces)


def test_sharded_moe_expert_parallel_bit_identical(moe):
    """Expert-parallel MoE decode under the strict capacity policy (the
    sharded-MoE default): the expert all-to-all must not perturb a single
    logit. Policy pinned on BOTH engines so capacity dims match."""
    cfg, params = moe
    base, shard = _pair(cfg, params, moe_capacity_policy="strict")
    assert shard.moe_capacity_policy == "strict"
    sb = _serve(base, _mixed_workload(3, max_new=5))
    ss = _serve(shard, _mixed_workload(3, max_new=5))
    assert sb == ss


def test_sharded_moe_strict_is_default(moe):
    cfg, params = moe
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, topology=DeviceTopology(tp=NDEV)))
    assert eng.moe_capacity_policy == "strict"


# ---------------------------------------------------------------------------
# preemption over sharded paged pools
# ---------------------------------------------------------------------------


def test_sharded_preempt_restore_exact_and_pages_drain(dense):
    """Host-side page tables are layout-identical under sharding, so the
    preempt/restore machinery must work unchanged: the restored stream is
    bit-identical to an undisturbed sharded run and no page leaks."""
    cfg, params = dense
    kw = dict(slots=1, window=64, max_seq=64, sync_every=1, chunk_prefill=0,
              topology=DeviceTopology(tp=NDEV))
    samp = SamplingParams(temperature=0.7, top_k=20, top_p=0.95, seed=77)

    ref_eng = ServingEngine(cfg, params, EngineConfig(**kw))
    ref = Request(0, _prompt(20), max_new_tokens=10, sampling=samp)
    assert ref_eng.try_admit(ref, 0.0)
    _serve(ref_eng, [ref], t0=0.0)

    eng = ServingEngine(cfg, params, EngineConfig(**kw, preemption=True))
    victim = Request(0, _prompt(20), max_new_tokens=10, sampling=samp,
                     ttft_slo_s=100.0)
    assert eng.try_admit(victim, 0.0)
    for t in (1.0, 2.0, 3.0):
        eng.step(t)
    assert len(victim.output) >= 2  # mid-decode when the preemptor lands
    hot = Request(1, _prompt(10, seed=9), max_new_tokens=3, priority=1,
                  ttft_slo_s=1.0)
    eng.submit(hot, 3.0)
    t = 3.0
    while not (victim.done and hot.done):
        t += 1.0
        eng.step(t)
    eng.drain(t + 1.0)
    assert victim.preemptions >= 1
    assert list(victim.output) == list(ref.output)
    assert eng.allocator.pages_in_use == 0
    assert eng.allocator.total_refs == 0


# ---------------------------------------------------------------------------
# telemetry: the router's sharding signal
# ---------------------------------------------------------------------------


def test_sharded_load_report_axis_fields(dense):
    cfg, params = dense
    _, shard = _pair(cfg, params)
    rep = shard.load_report()
    assert rep.n_chips == NDEV
    assert dict(rep.mesh_axes) == {"data": 1, "model": NDEV}
    cs = dict(rep.axis_collective_s)
    assert cs["model"] > 0.0 and cs["data"] == 0.0
    util = dict(rep.axis_util)
    assert 0.0 < util["model"] < 1.0
    # the wire shape survives the new fields
    from repro.serving import LoadReport
    assert LoadReport.from_dict(rep.to_dict()) == rep
