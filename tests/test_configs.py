"""Config registry: exact assigned numbers + analytic param counts match
materialized pytrees."""
import jax
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.models import init_params, param_count_tree

EXPECT = {
    # name -> (layers, d_model, heads, kv, d_ff, vocab)
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "granite-8b": (36, 4096, 32, 8, 14336, 49152),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
}

BILLION_EXPECT = {  # model-card park: (total_B, tolerance_frac)
    "starcoder2-15b": (15.5, 0.1),
    "grok-1-314b": (314, 0.05),
    "granite-8b": (8.1, 0.1),
    "chatglm3-6b": (6.2, 0.1),
    "mamba2-1.3b": (1.3, 0.1),
    "recurrentgemma-9b": (9.0, 0.12),
    "phi3-medium-14b": (14.0, 0.1),
    "llama4-maverick-400b-a17b": (400, 0.05),
    "hubert-xlarge": (0.96, 0.1),
    "qwen2-vl-7b": (7.6, 0.1),
}


def test_all_assigned_archs_present():
    assert len(ASSIGNED_ARCHS) == 10
    assert len({get_config(a).arch_type for a in ASSIGNED_ARCHS}) == 6


@pytest.mark.parametrize("name", list(EXPECT))
def test_assigned_numbers(name):
    cfg = get_config(name)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == EXPECT[name]


@pytest.mark.parametrize("name", list(BILLION_EXPECT))
def test_param_count_matches_model_card(name):
    target, tol = BILLION_EXPECT[name]
    got = get_config(name).param_count() / 1e9
    assert abs(got - target) / target < tol, (name, got, target)


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


def test_analytic_count_matches_materialized(arch_cfg):
    """param_count() formula agrees with the real reduced pytree."""
    cfg = arch_cfg.reduced()
    sds = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    got = sum(x.size for x in jax.tree.leaves(sds))
    want = cfg.param_count()
    assert abs(got - want) / want < 0.02, (cfg.name, got, want)


def test_sharding_divisibility():
    """d_ff/d_model/head_dim divisible by the 16-way model axis."""
    for name in ASSIGNED_ARCHS:
        cfg = get_config(name)
        assert cfg.d_model % 16 == 0
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0
        if cfg.has_attention:
            assert cfg.resolved_head_dim % 16 == 0


def test_moe_actives():
    grok = get_config("grok-1-314b")
    l4 = get_config("llama4-maverick-400b-a17b")
    assert grok.active_param_count() < grok.param_count()
    assert abs(l4.active_param_count() / 1e9 - 17) < 3  # "A17B"
