"""Serving invariants: prefill + decode == full forward; rolling-window
caches; continuous-batching slot isolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.serving import EngineConfig
from repro.models import decode_step, forward, init_cache, init_params

DECODERS = [a for a in ASSIGNED_ARCHS if get_config(a).supports_decode]
B, S = 2, 32


def _full_and_decode(cfg, window):
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, B, S + 1)
    full_logits, _, _ = forward(cfg, params, batch, mode="train", remat=False)

    pre_batch = {k: (v[:, :, :S] if k == "positions" else v[:, :S])
                 for k, v in batch.items()}
    cache = init_cache(cfg, B, window)
    pre_logits, _, cache = forward(cfg, params, pre_batch, mode="prefill",
                                   cache=cache)
    dec_batch = {"tokens": batch["tokens"][:, S:S + 1]}
    if cfg.rope_variant == "mrope":
        dec_batch["positions"] = batch["positions"][:, :, S:S + 1]
    dec_logits, cache = decode_step(cfg, params, cache, dec_batch)
    return full_logits, pre_logits, dec_logits, cache


@pytest.mark.parametrize("arch", DECODERS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    full, pre, dec, cache = _full_and_decode(cfg, window=S + 8)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :S]),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, S]),
                               atol=2e-4, rtol=2e-3)
    assert int(cache["pos"][0]) == S + 1


@pytest.mark.parametrize("arch", ["granite-8b", "recurrentgemma-9b"])
def test_multi_step_decode_positions(arch):
    """Positions advance; rolling KV window keeps decoding past W."""
    cfg = get_config(arch).reduced()
    w = 16  # window smaller than total generated length
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, B, w)
    batch = make_batch(cfg, B, 8)
    _, _, cache = forward(cfg, params, batch, mode="prefill", cache=cache)
    tok = batch["tokens"][:, -1:]
    for i in range(20):  # runs well past the window
        logits, cache = decode_step(cfg, params, cache, {"tokens": tok})
        assert not jnp.isnan(logits).any()
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert int(cache["pos"][0]) == 8 + 20


def test_slot_isolation():
    """Continuous batching: an idle slot does not perturb an active one."""
    from repro.serving import Request, ServingEngine

    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    prompt = np.arange(12, dtype=np.int32)

    eng1 = ServingEngine(cfg, params, EngineConfig(slots=1, window=64))
    r1 = Request(0, prompt, max_new_tokens=6)
    eng1.try_admit(r1, 0.0)
    while not r1.done:
        eng1.step(0.0)

    eng2 = ServingEngine(cfg, params, EngineConfig(slots=3, window=64))
    r2 = Request(0, prompt.copy(), max_new_tokens=6)
    other = Request(1, np.arange(5, dtype=np.int32) + 7, max_new_tokens=9)
    eng2.try_admit(r2, 0.0)
    eng2.try_admit(other, 0.0)
    while not r2.done:
        eng2.step(0.0)
    assert r1.output == r2.output  # co-tenant did not change the stream


def test_int8_kv_cache_decode_close():
    """Quantized serving cache (perf lever kv_int8): decode logits within
    ~1% of the bf16-cache path; cache leaves are int8 + scales."""
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, B, S + 1)
    full, _, _ = forward(cfg, params, batch, mode="train", remat=False)
    cache = init_cache(cfg, B, S + 8, kv_dtype="int8")
    kv_leaves = [l for l in jax.tree.leaves(cache) if l.dtype == jnp.int8]
    assert kv_leaves, "int8 cache leaves missing"
    pre_b = {k: v[:, :S] for k, v in batch.items()}
    _, _, cache = forward(cfg, params, pre_b, mode="prefill", cache=cache)
    dec, _ = decode_step(cfg, params, cache,
                         {"tokens": batch["tokens"][:, S:S + 1]})
    scale = float(jnp.abs(full[:, S]).max())
    err = float(jnp.abs(dec[:, 0] - full[:, S]).max())
    assert err / scale < 0.05, (err, scale)
