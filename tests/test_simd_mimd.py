"""SIMD + MIMD quadrants: sharding specs, DLRM distributed embedding,
heterogeneous-memory offload, service router."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.dlrm import CONFIG as DLRM_CFG
from repro.core.misd.scheduler import Device, Job
from repro.core.mimd import Instance, ServiceRouter
from repro.core.simd import (
    dlrm_forward,
    init_dlrm,
    lookup_traffic_bytes,
    plan_offload,
    shard_specs,
    zipf_hit_rate,
)
from repro.core.simd.sharding import (
    batch_pspecs,
    cache_pspecs,
    make_policy,
    param_pspecs,
)
from repro.launch.mesh import make_local_mesh
from repro.models import cache_specs, param_specs


def _mesh11():
    return make_local_mesh()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_structurally_match(arch):
    cfg = get_config(arch)
    mesh = _mesh11()
    pol = dataclasses.replace(make_policy(cfg, mesh), model_size=16,
                              data_size=16)
    sds = param_specs(cfg)
    specs = param_pspecs(cfg, sds, pol)
    # same tree structure, every spec rank-matching and divisible
    jax.tree.map(
        lambda s, x: _check(s, x),
        specs, sds,
        is_leaf=lambda x: isinstance(x, P))


def _check(spec, sds):
    assert len(spec) == len(sds.shape), (spec, sds.shape)
    for dim, entry in zip(sds.shape, spec):
        if entry is None:
            continue
        n = {"model": 16, "data": 16, "pod": 2}[entry] if isinstance(entry, str) else np.prod(
            [{"model": 16, "data": 16, "pod": 2}[a] for a in entry])
        assert dim % n == 0, (spec, sds.shape)


def test_fsdp_engages_only_for_giants():
    mesh = _mesh11()
    big = dataclasses.replace(
        make_policy(get_config("grok-1-314b"), mesh), model_size=16)
    # recompute with true axis sizes
    from repro.core.simd.sharding import make_policy as mp

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    assert mp(get_config("grok-1-314b"), FakeMesh()).fsdp
    assert mp(get_config("llama4-maverick-400b-a17b"), FakeMesh()).fsdp
    assert not mp(get_config("granite-8b"), FakeMesh()).fsdp
    assert not mp(get_config("starcoder2-15b"), FakeMesh()).fsdp


def test_cache_specs_shard_every_kv_leaf():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    cfg = get_config("phi3-medium-14b")
    pol = make_policy(cfg, FakeMesh())
    cs = cache_specs(cfg, 128, 32768)
    specs = cache_pspecs(cfg, cs, pol, FakeMesh())
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    kv = [s for p, s in flat if str(p[-1]) in ("['k']", "['v']") or
          getattr(p[-1], "key", "") in ("k", "v")]
    assert kv and all("model" in [e for e in s if e] for s in kv)


# --- DLRM (survey Fig. 7) ----------------------------------------------------


def _tiny_dlrm():
    return dataclasses.replace(
        DLRM_CFG, num_tables=4, rows_per_table=64, embed_dim=16,
        bottom_mlp=(32, 16), top_mlp=(32, 1))


def test_dlrm_forward_shape_and_grad():
    cfg = _tiny_dlrm()
    params = init_dlrm(cfg, jax.random.key(0))
    b = 8
    batch = {
        "dense": jnp.ones((b, cfg.num_dense_features)),
        "sparse": jnp.zeros((b, cfg.num_tables, cfg.multi_hot), jnp.int32),
    }
    out = dlrm_forward(cfg, params, batch)
    assert out.shape == (b,)
    assert not jnp.isnan(out).any()


def test_dlrm_embedding_dominates():
    """Survey: embedding tables are 80–95%+ of DLRM weights."""
    frac = DLRM_CFG.embedding_params() / DLRM_CFG.param_count()
    assert frac > 0.8


def test_dlrm_lookup_traffic_scales_with_batch():
    assert lookup_traffic_bytes(DLRM_CFG, 64) == 2 * lookup_traffic_bytes(
        DLRM_CFG, 32)


def test_dlrm_shard_specs_cover_tables():
    specs = shard_specs(DLRM_CFG)
    assert specs["tables"] == P(None, "model", None)


# --- heterogeneous memory (survey §4.3.2) ------------------------------------


def test_zipf_hit_rate_monotone():
    hs = [zipf_hit_rate(int(f * 1e6), int(1e6)) for f in (0.01, 0.1, 0.5, 1.0)]
    assert all(a < b or b == 1.0 for a, b in zip(hs, hs[1:]))
    assert hs[-1] == 1.0


def test_offload_near_hbm_with_small_hot_set():
    """[47][49]: a small HBM cache over Zipf accesses ~ on-par with DRAM."""
    rows, row_bytes = 10_000_000, 512
    plan = plan_offload(rows, row_bytes, hbm_budget_bytes=0.2 * rows * row_bytes)
    assert plan.hit_rate > 0.6
    assert plan.slowdown_vs_hbm < 12  # vs 25x raw HBM/PCIe gap
    none = plan_offload(rows, row_bytes, hbm_budget_bytes=0)
    assert none.slowdown_vs_hbm > plan.slowdown_vs_hbm


# --- MIMD router -------------------------------------------------------------


def _router(policy):
    r = ServiceRouter(policy=policy)
    for i in range(4):
        r.register(Instance(f"i{i}", "m", Device(f"d{i}", 4)))
    return r


@pytest.mark.parametrize("policy", ["least-loaded", "p2c", "round-robin"])
def test_router_balances(policy):
    r = _router(policy)
    counts = {}
    for i in range(400):
        inst = r.route(Job(i, "m", (0.5, 0.5), 0.01))
        counts[inst.name] = counts.get(inst.name, 0) + 1
        for pool in r.pools.values():
            for it in pool:
                r.drain(it, 0.01)
    assert len(counts) == 4
    assert max(counts.values()) < 3 * min(counts.values())


def test_router_autoscale_signals():
    r = _router("least-loaded")
    assert r.want_scale("m") in (-1, 0)
    for i in range(200):
        r.route(Job(i, "m", (0.5, 0.5), 0.5))
    assert r.want_scale("m") == 1  # pressure built up
    assert r.route(Job(0, "unknown", (0.5, 0.5), 0.01)) is None


def test_router_deregister_stops_routes():
    """A deregistered instance is marked draining and never routed again;
    pools can now shrink as well as grow."""
    r = _router("round-robin")
    gone = r.deregister("i2")
    assert gone is not None and gone.draining and gone.name == "i2"
    assert len(r.pools["m"]) == 3
    hits = {r.route(Job(i, "m", (0.5, 0.5), 0.01)).name for i in range(30)}
    assert hits == {"i0", "i1", "i3"}
    assert r.deregister("i2") is None  # absent now
    assert r.deregister("i0", model="never-registered") is None  # no KeyError
    # re-registering clears draining and restores routes
    r.register(gone)
    assert not gone.draining
    hits = {r.route(Job(i, "m", (0.5, 0.5), 0.01)).name for i in range(40)}
    assert "i2" in hits


def test_router_p2c_single_instance_pool():
    """p2c must degrade to the only instance instead of crashing when a
    pool has shrunk to one replica."""
    r = _router("p2c")
    for name in ("i1", "i2", "i3"):
        r.deregister(name)
    inst = r.route(Job(0, "m", (0.5, 0.5), 0.01))
    assert inst is not None and inst.name == "i0"


def test_router_p2c_deterministic_under_seed():
    """Same seed -> identical p2c routing sequence even under permanent
    exact ties (the seeded sample order is the tie-break), and ties still
    spread across the pool instead of starving later registrations."""

    def choices(seed):
        r = ServiceRouter(policy="p2c", seed=seed)
        for i in range(4):
            r.register(Instance(f"i{i}", "m", Device(f"d{i}", 4)))
        out = []
        for i in range(80):
            inst = r.route(Job(i, "m", (0.5, 0.5), 0.01))
            out.append(inst.name)
            inst.queue_s = 0.0  # force a permanent exact tie
        return out

    a, b = choices(7), choices(7)
    assert a == b
    assert set(a) == {"i0", "i1", "i2", "i3"}  # ties spread, nobody starves


def test_router_predicted_policy():
    """'predicted' scans the whole pool for the minimum predicted
    completion (p2c with full visibility)."""
    r = ServiceRouter(policy="predicted", seed=0)
    for i in range(4):
        inst = r.register(Instance(f"i{i}", "m", Device(f"d{i}", 4)))
        inst.queue_s = 3.0 - 0.5 * i  # i3 is least loaded
    chosen = r.route(Job(0, "m", (0.5, 0.5), 0.01))
    assert chosen.name == "i3"
