"""Overload control: SLO tiers, token-bucket admission, weighted-fair
DRR queueing, the degradation ladder, the circuit breaker, per-tenant
metrics/LoadReport v4, and the trace-sampling/ring satellites."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.misd.scheduler import Device, Job
from repro.core.mimd.router import Instance, ServiceRouter
from repro.models import init_params
from repro.serving import (
    BROWNOUT,
    REJECT,
    SHED,
    CircuitBreaker,
    ClusterFrontend,
    EngineConfig,
    LoadReport,
    OverloadDetector,
    RequestRejected,
    RequestState,
    ServeMetrics,
    ServingEngine,
    TenantAdmission,
    TenantClass,
    TenantMetrics,
    TokenBucket,
    WeightedFairQueue,
    request_cost,
)
from repro.serving.metrics import latency_histogram

from conftest import make_request as Request


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _req(rid, tenant="", plen=8, budget=4, arrival=0.0, slo=0.0, seed=0):
    return Request(rid, _prompt(plen, seed=seed or rid), budget,
                   arrival_time=arrival, tenant=tenant, ttft_slo_s=slo)


# -- token bucket / admission ------------------------------------------------


def test_token_bucket_admits_then_meters():
    b = TokenBucket(rate=10.0, capacity=20.0)
    assert b.take(20.0, 0.0) == 0.0  # full burst admitted
    wait = b.take(10.0, 0.0)
    assert wait == pytest.approx(1.0)  # refills at 10 tok/s
    assert b.take(10.0, 0.0 + wait) == 0.0  # honored retry horizon


def test_token_bucket_oversized_request_finite_retry():
    b = TokenBucket(rate=10.0, capacity=20.0)
    wait = b.take(50.0, 0.0)  # larger than the bucket can ever hold
    assert 0 < wait < float("inf")


def test_tenant_admission_typed_rejection():
    adm = TenantAdmission({"t": TenantClass("t", rate_tokens_s=10.0,
                                            burst_tokens=16.0)})
    assert adm.admit(_req(0, "t", plen=8, budget=4), 0.0) is None
    with pytest.raises(RequestRejected) as ei:
        adm.admit(_req(1, "t", plen=8, budget=8), 0.0)
    assert ei.value.retry_after_s > 0
    # unknown / unlimited tenants always pass
    adm.admit(_req(2, "other", plen=100, budget=100), 0.0)


# -- weighted-fair queue -----------------------------------------------------


def test_wfq_single_tenant_is_flat_edf():
    """One (untagged) tenant drains in exactly (ttft_deadline, seq)
    order — the pre-DRR frontend contract."""
    q = WeightedFairQueue(edf=True)
    reqs = [_req(i, arrival=0.0, slo=[5.0, 2.0, 9.0, 2.0][i])
            for i in range(4)]
    for r in reqs:
        q.push(r)
    assert [r.rid for r in q.drain()] == [1, 3, 0, 2]
    assert not q and len(q) == 0


def test_wfq_weights_share_token_throughput():
    """Over a long backlog, a weight-2 tenant pops ~2x the token cost of
    a weight-1 tenant (DRR's defining property)."""
    w = {"a": 2.0, "b": 1.0}
    q = WeightedFairQueue(quantum=16.0, weight_of=lambda t: w[t])
    for i in range(40):
        q.push(_req(100 + i, "a", plen=8, budget=8))
        q.push(_req(200 + i, "b", plen=8, budget=8))
    cost = {"a": 0.0, "b": 0.0}
    for _ in range(30):
        r = q.pop()
        cost[r.tenant] += request_cost(r)
    assert cost["a"] / cost["b"] == pytest.approx(2.0, rel=0.35)


def test_wfq_backlogged_tenant_bounded_wait():
    """A flood from one tenant cannot starve another: the victim's head
    is served within the provable grant bound."""
    q = WeightedFairQueue(quantum=8.0)
    for i in range(50):
        q.push(_req(i, "flood", plen=16, budget=16))
    q.push(_req(99, "victim", plen=16, budget=16))
    bound = q.starvation_bound(request_cost(_req(0, plen=16, budget=16)))
    popped = []
    while True:
        r = q.pop()
        popped.append(r.tenant)
        if r.tenant == "victim":
            break
    assert q.max_wait_rounds <= bound
    # and the victim was NOT last: it interleaved within a few pops
    assert len(popped) <= bound * 2


def test_wfq_drained_tenant_forfeits_deficit():
    q = WeightedFairQueue(quantum=1000.0)
    q.push(_req(0, "a"))
    q.pop()  # a granted a huge quantum, then drained
    q.push(_req(1, "a", plen=8, budget=8))
    q.push(_req(2, "b", plen=8, budget=8))
    # a's old credit is gone: b is served within its own grant round
    assert {q.pop().rid, q.pop().rid} == {1, 2}
    assert q.max_wait_rounds <= q.starvation_bound(16.0)


# -- overload detector -------------------------------------------------------


def _report(backlog_s=0.0, ttfts=()):
    h = latency_histogram()
    for v in ttfts:
        h.observe(v)
    return LoadReport(slots=2, free_slots=0, queued_requests=0,
                      queued_prefill_tokens=0, decode_tokens_remaining=0,
                      free_pages=-1, total_pages=0, backlog_s=backlog_s,
                      tick_est_s=0.01, queued_prefill_s=0.0,
                      histograms=(("ttft_s", h.to_wire()),) if ttfts else ())


def test_detector_escalates_with_hysteresis_and_relaxes():
    det = OverloadDetector(ttft_slo_s=1.0, backlog_high_s=2.0,
                           period_s=1.0, patience=2, relax_patience=2)
    t = 0.0
    det.observe(t, [_report(5.0)])  # arms the eval clock
    for _ in range(3):
        t += 1.0
        det.observe(t, [_report(5.0)])
    assert det.level == SHED  # 2 breaches -> one rung, not three
    for _ in range(2):
        t += 1.0
        det.observe(t, [_report(5.0)])
    assert det.level == BROWNOUT
    while det.level < REJECT:
        t += 1.0
        det.observe(t, [_report(5.0)])
    assert det.level == REJECT  # clamped at max_level
    for _ in range(8):
        t += 1.0
        det.observe(t, [_report(0.1)])
    assert det.level < REJECT  # relax walks back down
    assert det.transitions  # every move recorded
    assert det.retry_after_s() >= det.ttft_slo_s


def test_detector_tail_window_accumulates_until_min_window():
    """Sparse completions must not reset the tail window each period:
    the p99 signal fires once enough samples ACCUMULATE."""
    det = OverloadDetector(ttft_slo_s=1.0, backlog_high_s=1e9,
                           period_s=1.0, patience=1, min_window=4)
    t, ttfts = 0.0, []
    det.observe(t, [_report(0.0)])
    for i in range(3):  # one slow TTFT per period: under min_window
        t += 1.0
        ttfts.append(5.0)
        det.observe(t, [_report(0.0, ttfts)])
        assert det.level == 0
    t += 1.0
    ttfts.append(5.0)  # 4th sample: window evaluates, p99 breaches
    det.observe(t, [_report(0.0, ttfts)])
    assert det.level == SHED


def test_detector_counts_frontend_backlog():
    det = OverloadDetector(ttft_slo_s=1.0, backlog_high_s=2.0,
                           period_s=1.0, patience=1)
    det.observe(0.0, [_report(0.1)])
    det.observe(1.0, [_report(0.1)], frontend_backlog_s=10.0)
    assert det.level == SHED  # the paced-dispatch burst waits upstream


def test_histogram_delta_exact_window():
    a = latency_histogram()
    for v in (0.1, 0.2, 0.5):
        a.observe(v)
    b = a.copy()
    for v in (3.0, 4.0):
        b.observe(v)
    win = b.delta(a)
    assert win.count == 2
    assert win.sum == pytest.approx(7.0)
    assert win.percentile(99) >= 2.0  # only the new tail in the window


# -- circuit breaker ---------------------------------------------------------


def test_breaker_open_halfopen_closed_cycle():
    br = CircuitBreaker(cooldown_s=1.0, probe_limit=1, close_after=2)
    assert br.allow("r", 0.0)  # unknown replicas are healthy
    br.trip("r", 0.0)
    assert not br.allow("r", 0.5)  # OPEN during cooldown
    assert br.allow("r", 1.5)  # HALF_OPEN after cooldown
    br.note_dispatch("r", 1.5)
    assert not br.allow("r", 1.6)  # probe limit reached
    br.note_success("r", 2.0)
    assert br.allow("r", 2.1)
    br.note_dispatch("r", 2.1)
    br.note_success("r", 2.5)  # close_after successes
    assert br.state("r", 2.6) == "closed"
    br.note_failure("r", 3.0)  # failure re-trips
    assert not br.allow("r", 3.1)


# -- per-tenant metrics / LoadReport v4 --------------------------------------


def test_tenant_metrics_merge_and_wire_roundtrip():
    a, b = TenantMetrics(), TenantMetrics()
    a.admitted, a.completed, a.total_tokens = 3, 2, 50
    a.ttfts.observe(0.5)
    b.admitted, b.shed, b.browned_out = 2, 1, 1
    b.ttfts.observe(1.5)
    merged = TenantMetrics().merge(a).merge(b)
    assert (merged.admitted, merged.completed, merged.shed) == (5, 2, 1)
    assert merged.ttfts.count == 2
    rt = TenantMetrics.from_wire(merged.to_wire())
    assert rt.to_wire() == merged.to_wire()


def test_serve_metrics_merge_folds_tenants():
    m1, m2 = ServeMetrics(), ServeMetrics()
    m1.tenant("gold").admitted = 2
    m2.tenant("gold").admitted = 3
    m2.tenant("bulk").shed = 4
    m1.merge(m2)
    assert m1.tenant("gold").admitted == 5
    assert m1.tenant("bulk").shed == 4
    reg = m1.registry()
    text = reg.exposition()
    assert 'tenant_admitted_total{tenant="gold"} 5' in text


def test_load_report_v4_roundtrip_and_version_guard():
    m = ServeMetrics()
    tm = m.tenant("gold")
    tm.admitted = 2
    tm.ttfts.observe(0.25)
    rep = LoadReport(slots=2, free_slots=2, queued_requests=0,
                     queued_prefill_tokens=0, decode_tokens_remaining=0,
                     free_pages=-1, total_pages=0, backlog_s=0.0,
                     tick_est_s=0.0, queued_prefill_s=0.0,
                     browned_out=3, tenant_stats=m.tenant_wire())
    rt = LoadReport.from_dict(rep.to_dict())
    assert rt.browned_out == 3
    assert rt.tenant_stats == rep.tenant_stats
    name, counters, wire = rt.tenant_stats[0]
    assert name == "gold"
    assert TenantMetrics.from_wire((counters, wire)).admitted == 2
    # older readers' reports still parse; future ones refuse
    d = rep.to_dict()
    d.pop("tenant_stats"), d.pop("browned_out")
    d["schema_version"] = 3
    assert LoadReport.from_dict(d).tenant_stats == ()
    d["schema_version"] = 99
    with pytest.raises(ValueError):
        LoadReport.from_dict(d)


# -- router satellites -------------------------------------------------------


def test_pressure_weighs_chips_not_replicas():
    r = ServiceRouter()
    r.register(Instance("tp8", "m", Device("d0", speed=8.0), queue_s=8.0))
    assert r.pressure("m") == pytest.approx(1.0)  # 8s over 8 chips
    assert r.want_scale("m", high_s=2.0) == 0  # NOT 8x-too-eager scale-out
    r2 = ServiceRouter()
    r2.register(Instance("one", "m", Device("d1", speed=1.0), queue_s=8.0))
    assert r2.want_scale("m", high_s=2.0) == 1  # same queue, 1 chip: scale


def test_route_eligible_filter():
    r = ServiceRouter(policy="least-loaded")
    a = r.register(Instance("a", "m", Device("da"), queue_s=0.0))
    r.register(Instance("b", "m", Device("db"), queue_s=5.0))
    job = Job(jid=0, model="m", demand=1, service_s=1.0, arrival=0.0)
    assert r.route(job, eligible={"b"}).name == "b"  # filter beats load
    assert r.route(job, eligible=set()) is None
    assert r.route(job) is a  # no filter: normal policy


# -- cluster integration -----------------------------------------------------


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _engines(cfg, params, n=2):
    return [ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=4)) for _ in range(n)]


TENANTS = {
    "gold": TenantClass("gold", tier=1, weight=2.0),
    "bulk": TenantClass("bulk", tier=0, weight=1.0),
}


def _drive(fe, reqs, *, max_steps=600):
    pending = sorted(reqs, key=lambda r: (r.arrival_time, r.rid))
    resolved, i, now = {}, 0, 0.0
    while len(resolved) < len(pending):
        while i < len(pending) and pending[i].arrival_time <= now:
            fe.submit(pending[i], now)
            i += 1
        for r in fe.step(now):
            resolved[r.rid] = r
        now += 1.0
        assert now < max_steps
    return resolved


def test_cluster_ladder_sheds_low_tier_protects_top(granite):
    # backlog_high_s is on the engine cost-model scale (ticks estimate in
    # milliseconds of virtual compute), not the 1s driver cadence — same
    # derivation as benchmarks/overload_bench.py.
    cfg, params = granite
    det = OverloadDetector(ttft_slo_s=8.0, backlog_high_s=0.002,
                           period_s=1.0, patience=1, relax_patience=50)
    fe = ClusterFrontend(_engines(cfg, params), tenants=TENANTS,
                         overload=det, fair_quantum=32.0)
    reqs = ([_req(i, "bulk", plen=12, budget=10, arrival=0.0)
             for i in range(12)]
            + [_req(100 + i, "gold", plen=8, budget=6, arrival=4.0,
                    slo=30.0) for i in range(3)])
    resolved = _drive(fe, reqs)
    golds = [resolved[100 + i] for i in range(3)]
    assert all(g.state is RequestState.FINISHED for g in golds)
    shed = [r for r in resolved.values()
            if r.fail_reason.startswith("shed: overload ladder")]
    assert shed and all(r.tenant == "bulk" for r in shed)
    assert all(r.retry_after_s > 0 for r in shed)
    m = fe.merged_metrics()
    assert m.tenant("bulk").shed == len(shed)
    assert fe._queue.max_wait_rounds <= fe._queue.starvation_bound(
        max(request_cost(r) for r in reqs))


def test_cluster_brownout_trims_and_counts_once(granite):
    cfg, params = granite
    det = OverloadDetector(ttft_slo_s=8.0, backlog_high_s=0.002,
                           period_s=1.0, patience=1, relax_patience=50,
                           max_level=BROWNOUT)
    tenants = {"gold": TenantClass("gold", tier=2),
               "mid": TenantClass("mid", tier=1, brownout_frac=0.5),
               "bulk": TenantClass("bulk", tier=0)}
    fe = ClusterFrontend(_engines(cfg, params), tenants=tenants,
                         overload=det)
    reqs = ([_req(i, "bulk", plen=12, budget=10, arrival=0.0)
             for i in range(10)]
            + [_req(50 + i, "mid", plen=8, budget=8, arrival=5.0)
               for i in range(3)])
    resolved = _drive(fe, reqs)
    browned = [r for r in resolved.values() if r.browned_out_tokens]
    assert browned and all(r.tenant == "mid" for r in browned)
    for r in browned:
        assert r.state is RequestState.FINISHED
        assert len(r.output) <= r.max_new_tokens  # served to trimmed cap
    m = fe.merged_metrics()
    # counted exactly once (at the serving engine), with trim accounting
    assert m.browned_out == len(browned)
    assert m.tenant("mid").browned_out == len(browned)
    assert m.tenant("mid").brownout_trimmed_tokens == sum(
        r.browned_out_tokens for r in browned)


def test_cluster_reject_level_typed_retry_after(granite):
    cfg, params = granite
    det = OverloadDetector(ttft_slo_s=8.0, backlog_high_s=0.002,
                           period_s=1.0, patience=1, relax_patience=50)
    fe = ClusterFrontend(_engines(cfg, params), tenants=TENANTS,
                         overload=det)
    for i in range(14):  # saturate until the ladder tops out
        fe.submit(_req(i, "bulk", plen=12, budget=10), 0.0)
    now = 0.0
    while det.level < REJECT:
        now += 1.0
        fe.step(now)
        assert now < 100
    late = _req(500, "bulk", plen=8, budget=4, arrival=now)
    assert fe.submit(late, now) is False
    assert late.state is RequestState.FAILED
    assert late.fail_reason.startswith("rejected: cluster overloaded")
    assert late.retry_after_s > 0
    gold = _req(501, "gold", plen=8, budget=4, arrival=now)
    assert fe.submit(gold, now) is True  # top tier admitted even here


def test_cluster_tenant_stats_on_wire(granite):
    cfg, params = granite
    fe = ClusterFrontend(_engines(cfg, params), tenants=TENANTS)
    resolved = _drive(fe, [_req(i, "gold", plen=8, budget=4, slo=30.0)
                           for i in range(3)])
    assert all(r.state is RequestState.FINISHED
               for r in resolved.values())
    stats = {}
    for eng in fe.engines:
        for name, counters, wire in eng.load_report().tenant_stats:
            tm = TenantMetrics.from_wire((counters, wire))
            stats.setdefault(name, TenantMetrics()).merge(tm)
    assert stats["gold"].admitted == 3
    assert stats["gold"].completed == 3
    assert stats["gold"].ttfts.count == 3
    assert stats["gold"].slo_tracked == 3


def test_cluster_single_tenant_path_unchanged(granite):
    """Untagged traffic through a tenant-less frontend: no pacing, no
    per-tenant accounting, identical streams to a fresh single engine."""
    cfg, params = granite
    fe = ClusterFrontend(_engines(cfg, params, n=1))
    reqs = [_req(i, plen=8, budget=6) for i in range(4)]
    resolved = _drive(fe, reqs)
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=4))
    solo = {}
    for i in range(4):
        r = _req(i, plen=8, budget=6)
        eng.submit(r, 0.0)
        solo[i] = r
    now = 0.0
    while any(s.finish_time < 0 for s in solo.values()):
        now += 1.0
        eng.step(now)
        assert now < 300
    for i in range(4):
        assert list(resolved[i].output) == list(solo[i].output)
    assert fe.merged_metrics().tenants == {}


# -- trace sampling + ring satellites ----------------------------------------


def test_trace_sampling_every_nth_rid(granite):
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=4, tracing=True,
        trace_sample_n=3))
    reqs = [_req(i, plen=8, budget=4) for i in range(6)]
    for r in reqs:
        eng.submit(r, 0.0)
    now = 0.0
    while any(r.finish_time < 0 for r in reqs):
        now += 1.0
        eng.step(now)
        assert now < 300
    traced = {r.rid for r in reqs if r.trace is not None}
    assert traced == {0, 3}
    assert eng.tracer.collected == 2


def test_trace_ring_bounded(granite):
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, max_seq=128, sync_every=4, tracing=True,
        trace_ring=2))
    reqs = [_req(i, plen=8, budget=4) for i in range(5)]
    for r in reqs:
        eng.submit(r, 0.0)
    now = 0.0
    while any(r.finish_time < 0 for r in reqs):
        now += 1.0
        eng.step(now)
        assert now < 300
    assert eng.tracer.collected == 5
    assert len(eng.tracer.ring) == 2  # bounded retention
    assert {t.rid for t in eng.tracer.ring} <= {r.rid for r in reqs}
    eng.reset()
    assert eng.tracer.ring is not None and len(eng.tracer.ring) == 0


def test_config_validates_trace_knobs():
    with pytest.raises(ValueError):
        EngineConfig(trace_sample_n=0)
    with pytest.raises(ValueError):
        EngineConfig(trace_ring=-1)
