"""Property-based sampling guarantees (hypothesis; optional dep).

Greedy is the exact degenerate case of the sampling subsystem:
temperature -> 0 converges to the greedy stream and top-k = 1 equals it
outright, across paged and rolling caches and any noise seed."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from test_sampling import _streams


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params



@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20), lseed=st.integers(0, 2**20),
       temp=st.floats(1e-7, 1e-5), v=st.integers(16, 300))
def test_temperature_to_zero_converges_to_greedy(seed, lseed, temp, v):
    """As temperature -> 0 the scaled logit gaps dwarf any Gumbel draw:
    the sampled token equals argmax for every seed."""
    import jax.numpy as jnp

    from repro.models.layers import sample_tokens

    rng = np.random.default_rng(lseed)
    logits = jnp.asarray(rng.standard_normal((2, v)), jnp.float32)
    samp = {
        "greedy": jnp.zeros((2,), jnp.bool_),
        "temperature": jnp.full((2,), temp, jnp.float32),
        "top_k": jnp.zeros((2,), jnp.int32),
        "top_p": jnp.ones((2,), jnp.float32),
        "key": jnp.stack([jnp.asarray(jax.random.PRNGKey(seed + i))
                          for i in range(2)]).astype(jnp.uint32),
    }
    pos = jnp.asarray([11, 29], jnp.int32)
    tok = sample_tokens(logits, samp, pos)
    np.testing.assert_array_equal(np.asarray(tok),
                                  np.asarray(jnp.argmax(logits, -1)))


@pytest.fixture(scope="module")
def cache_pair(granite):
    """One warm engine per cache layout (reused via reset across
    hypothesis examples — jit caches stay hot) plus the memoized greedy
    reference streams."""
    cfg, params = granite
    engines = {
        "paged": ServingEngine(cfg, params, EngineConfig(slots=2, window=64,
                               sync_every=4, paged=True)),
        "rolling": ServingEngine(cfg, params, EngineConfig(slots=2, window=64,
                                 sync_every=4, paged=False)),
    }
    greedy = {}
    for name, eng in engines.items():
        greedy[name], _ = _streams(cfg, params, [0, 1], engine=eng)
    return engines, greedy


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_topk_one_equals_greedy_stream(granite, cache_pair, seed):
    """top-k = 1 restricts every draw to the argmax: the whole ENGINE
    stream equals the greedy stream exactly, across paged and rolling
    caches and any noise seed."""
    cfg, params = granite
    engines, greedy = cache_pair
    sp = SamplingParams(temperature=1.3, top_k=1, seed=seed)
    for name, eng in engines.items():
        sampled, _ = _streams(cfg, params, [0, 1], sampling=sp, engine=eng)
        assert sampled == greedy[name]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_tiny_temperature_engine_stream_converges(granite, cache_pair, seed):
    """Engine-level convergence: temperature 1e-6 reproduces the greedy
    stream across paged and rolling caches."""
    cfg, params = granite
    engines, greedy = cache_pair
    sp = SamplingParams(temperature=1e-6, seed=seed)
    for name, eng in engines.items():
        sampled, _ = _streams(cfg, params, [0, 1], sampling=sp, engine=eng)
        assert sampled == greedy[name]
