"""Training substrate: gradient-accumulation equivalence, loss decreases,
checkpoint roundtrip, optimizer schedule."""
import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro.configs import get_config
from repro.models import init_params
from repro.training import (
    TokenPipeline,
    grads_fn,
    init_adamw,
    latest_step,
    restore_into,
    save_checkpoint,
    train_step,
)
from repro.training.optimizer import cosine_schedule


def test_grad_accum_equivalence():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, 4, 32, labels=True)
    l1, _, g1 = grads_fn(cfg, params, batch, accum=1)
    l2, _, g2 = grads_fn(cfg, params, batch, accum=2)
    assert abs(float(l1) - float(l2)) < 1e-4
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)


def test_loss_decreases_on_structured_data():
    cfg = get_config("chatglm3-6b").reduced()
    params = init_params(cfg, jax.random.key(0))
    opt = init_adamw(params)
    pipe = TokenPipeline(cfg.vocab_size, 32, 8, seed=1)
    step = jax.jit(functools.partial(train_step, cfg, peak_lr=1e-3,
                                     total_steps=40))
    losses = []
    for i, batch in enumerate(pipe.batches()):
        if i >= 30:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["ce"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_checkpoint_roundtrip_with_opt_state():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, params)
        assert latest_step(d) == 7
        r = restore_into(d, 7, jax.eval_shape(lambda: params))
        for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cosine_schedule():
    lr0 = float(cosine_schedule(jnp.asarray(0), peak_lr=1.0, warmup=10,
                                total=100))
    lrw = float(cosine_schedule(jnp.asarray(10), peak_lr=1.0, warmup=10,
                                total=100))
    lre = float(cosine_schedule(jnp.asarray(100), peak_lr=1.0, warmup=10,
                                total=100))
    assert lr0 == 0.0 and abs(lrw - 1.0) < 1e-6 and lre < 0.11


def test_vlm_loss_masks_patch_prefix():
    cfg = get_config("qwen2-vl-7b").reduced()
    params = init_params(cfg, jax.random.key(0))
    b, s, p = 2, 24, 8
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s - p)),
                              jnp.int32),
        "patches": jnp.asarray(rng.standard_normal((b, p, cfg.d_model)),
                               jnp.float32),
        "positions": jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                      (3, b, s)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s - p)),
                              jnp.int32),
    }
    from repro.training import loss_fn

    loss, (ce, aux) = loss_fn(cfg, params, batch)
    assert float(loss) > 0 and not np.isnan(float(loss))
