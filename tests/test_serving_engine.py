"""Zero-copy continuous-batching engine: donation round-trips, bucketed
prefill, chunked prefill, deferred host sync, and admission isolation.

Engine-level tests build engines/requests through the conftest
``make_engine`` / ``make_request`` helpers, so the CI config matrix
({paged, rolling, prefix_cache} x {greedy, sampled}) replays them under
every configuration; raw-step tests (exact logits math) stay pinned."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_engine, make_request

from repro.configs import get_config
from repro.models import forward, init_cache, init_params
from repro.serving.engine import (
    bucketed_prefill_step,
    cache_insert,
    prefill_chunk_step,
    prefill_step,
    prompt_bucket,
)


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _run(cfg, params, reqs, **kw):
    eng = make_engine(cfg, params, **kw)
    for r in reqs:
        assert eng.try_admit(r, 0.0)
    t = 0.0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    return eng


# ---------------------------------------------------------------------------
# cache_insert under donation
# ---------------------------------------------------------------------------


def test_cache_insert_donated_roundtrip(granite):
    """The jit'd, donated slot-scatter must place a B=1 cache exactly and
    leave other slots untouched — across repeated donated calls (the donated
    buffer is consumed and rebound every call)."""
    cfg, params = granite
    slots, w, plen = 3, 64, 10
    batched = init_cache(cfg, slots, w)
    batch = {"tokens": jnp.asarray(_prompt(plen)[None, :], jnp.int32)}
    cache1 = init_cache(cfg, 1, w)
    _, _, cache1 = forward(cfg, params, batch, mode="prefill", cache=cache1)

    ins = jax.jit(lambda big, small, slot: cache_insert(big, small, slot, slots),
                  donate_argnums=(0,))
    for slot in (1, 2):  # one trace serves every slot index
        batched = ins(batched, cache1, np.int32(slot))
    k_big = batched["body"][0]["k"]  # (n_repeat, slots, w, kv, hd)
    k_one = cache1["body"][0]["k"]
    np.testing.assert_array_equal(np.asarray(k_big[:, 1]), np.asarray(k_one[:, 0]))
    np.testing.assert_array_equal(np.asarray(k_big[:, 2]), np.asarray(k_one[:, 0]))
    assert not np.asarray(k_big[:, 0]).any()  # untouched slot stays zero
    assert int(batched["pos"][1]) == plen and int(batched["pos"][0]) == 0


def test_cache_insert_slot_axis_disambiguation(granite):
    """Stacked body leaves have an n_repeat axis that can equal the slot
    count by value; the scatter must still pick the slot axis (the axis
    where the B=1 leaf has extent 1)."""
    cfg, params = granite  # n_repeat == 2 == slots below
    slots, w = 2, 32
    batched = init_cache(cfg, slots, w)
    batch = {"tokens": jnp.asarray(_prompt(6)[None, :], jnp.int32)}
    cache1 = init_cache(cfg, 1, w)
    _, _, cache1 = forward(cfg, params, batch, mode="prefill", cache=cache1)
    out = cache_insert(batched, cache1, 1, slots)
    k_big = np.asarray(out["body"][0]["k"])
    k_one = np.asarray(cache1["body"][0]["k"])
    np.testing.assert_array_equal(k_big[:, 1], k_one[:, 0])
    assert not k_big[:, 0].any()


# ---------------------------------------------------------------------------
# bucketed prefill
# ---------------------------------------------------------------------------


def test_bucketed_prefill_matches_unpadded(granite):
    """End-padding to a bucket must not change the last true token's logits
    or the decoded continuation."""
    cfg, params = granite
    w, plen = 64, 11
    prompt = _prompt(plen)
    bucket = prompt_bucket(plen)
    assert bucket == 16

    exact_logits, _ = prefill_step(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :], jnp.int32)},
        window=w)
    padded = np.zeros((1, bucket), np.int32)
    padded[0, :plen] = prompt
    tok, bucket_logits, cache = bucketed_prefill_step(
        cfg, params, {"tokens": jnp.asarray(padded)}, np.int32(plen), window=w)
    np.testing.assert_allclose(np.asarray(bucket_logits), np.asarray(exact_logits),
                               atol=1e-5, rtol=1e-5)
    assert int(tok[0]) == int(jnp.argmax(exact_logits[0]))
    assert int(cache["pos"][0]) == plen


def test_bucketed_prefill_single_trace(granite):
    """Acceptance probe: every prompt length inside one power-of-two bucket
    shares exactly one trace of the prefill step."""
    cfg, params = granite
    eng = make_engine(cfg, params, slots=4, window=128, chunk_prefill=0)
    for i, plen in enumerate((9, 12, 15, 16)):
        assert eng.try_admit(make_request(i, _prompt(plen, seed=i), 4), 0.0)
    assert eng.prefill_traces == 1
    # a new bucket costs exactly one more trace
    eng2 = make_engine(cfg, params, slots=4, window=128, chunk_prefill=0)
    for i, plen in enumerate((9, 17)):
        assert eng2.try_admit(make_request(i, _prompt(plen, seed=i), 4), 0.0)
    assert eng2.prefill_traces == 2


def test_bucketed_engine_outputs_match_exact(granite):
    """Whole-engine check: bucketing on vs off produces identical streams."""
    cfg, params = granite
    out = {}
    for bucketed in (True, False):
        req = make_request(0, _prompt(13), max_new_tokens=6)
        _run(cfg, params, [req], slots=2, window=64,
             bucket_prompts=bucketed, chunk_prefill=0)
        out[bucketed] = req.output
    assert out[True] == out[False]


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_single_shot_cache(granite):
    """Running a prompt through chunk steps must build the same KV cache
    (values, pos) and the same first token as one single-shot prefill."""
    cfg, params = granite
    w, plen, chunk = 64, 20, 8
    prompt = _prompt(plen)
    padded_len = 24  # padded to a multiple of the chunk
    padded = np.zeros((1, padded_len), np.int32)
    padded[0, :plen] = prompt

    cache = init_cache(cfg, 1, w)
    toks = jnp.asarray(padded)
    for off in range(0, padded_len, chunk):
        tok, _, cache = prefill_chunk_step(
            cfg, params, cache, toks[:, off:off + chunk], np.int32(plen))

    ref_cache = init_cache(cfg, 1, w)
    ref_logits, _, ref_cache = forward(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :], jnp.int32)},
        mode="prefill", cache=ref_cache)
    assert int(cache["pos"][0]) == int(ref_cache["pos"][0]) == plen
    np.testing.assert_allclose(
        np.asarray(cache["body"][0]["k"][:, :, :plen]),
        np.asarray(ref_cache["body"][0]["k"][:, :, :plen]),
        atol=1e-5, rtol=1e-5)
    assert int(tok[0]) == int(jnp.argmax(ref_logits[0, -1]))


def test_chunked_engine_outputs_match_single_shot(granite):
    """Long prompts admitted via interleaved chunks decode identically to
    single-shot admission."""
    cfg, params = granite
    out = {}
    for chunk in (16, 0):
        req = make_request(0, _prompt(40), max_new_tokens=6)
        _run(cfg, params, [req], slots=2, window=128, chunk_prefill=chunk)
        out[chunk] = req.output
    assert out[16] == out[0]


def test_admission_during_decode_no_interference(granite):
    """Acceptance: admitting a new (long, chunk-prefilled) request while >= 2
    slots are decoding changes no tokens of the in-flight requests."""
    cfg, params = granite

    def run_pair(with_admission):
        eng = make_engine(cfg, params, slots=3, window=128,
                          chunk_prefill=16, sync_every=4)
        a = make_request(0, _prompt(12, seed=1), max_new_tokens=24)
        b = make_request(1, _prompt(9, seed=2), max_new_tokens=24)
        assert eng.try_admit(a, 0.0) and eng.try_admit(b, 0.0)
        t = 0.0
        for _ in range(4):  # both slots decoding
            t += 1.0
            eng.step(t)
        late = None
        if with_admission:
            late = make_request(2, _prompt(48, seed=3), max_new_tokens=4)
            assert eng.try_admit(late, t)
            assert eng.n_prefilling == 1  # chunked: decode keeps running
        while not (a.done and b.done and (late is None or late.done)):
            t += 1.0
            eng.step(t)
        return a.output, b.output

    a0, b0 = run_pair(False)
    a1, b1 = run_pair(True)
    assert a0 == a1
    assert b0 == b1


# ---------------------------------------------------------------------------
# deferred sync / fused decode window
# ---------------------------------------------------------------------------


def test_deferred_sync_matches_per_tick(granite):
    """sync_every=N (with the fused scan window) and sync_every=1 produce
    identical token streams; N syncs the host ~1/N as often."""
    cfg, params = granite
    outs, engines = {}, {}
    for sync in (1, 8):
        req = make_request(0, _prompt(12), max_new_tokens=20)
        engines[sync] = _run(cfg, params, [req], slots=1, window=64,
                             sync_every=sync)
        outs[sync] = req.output
    assert outs[1] == outs[8]
    assert engines[8].metrics.host_syncs < engines[1].metrics.host_syncs


def test_mrope_decode_on_device(granite):
    """The mrope decode path builds positions from the cache's pos leaf on
    device (no per-tick host round-trip) and still decodes correctly."""
    cfg = get_config("qwen2-vl-7b").reduced()
    params = init_params(cfg, jax.random.key(0))
    req = make_request(0, _prompt(10), max_new_tokens=8)
    eng = _run(cfg, params, [req], slots=2, window=64, sync_every=4)
    assert len(req.output) == 8
    assert eng.metrics.host_syncs <= eng.metrics.decode_ticks / 2


# ---------------------------------------------------------------------------
# cost-model admission plan
# ---------------------------------------------------------------------------


def test_adaptive_slot_plan(granite):
    """slots=0 derives slot count + flush deadline from the cost model."""
    from repro.core.misd.batching import plan_admission

    cfg, params = granite
    eng = make_engine(cfg, params, slots=0, window=128, sla_s=0.05)
    # oracle plans with the engine's own chip count, so the tp8 matrix
    # cell (an 8-way replica plans bigger batches) validates too
    plan = plan_admission(cfg, context=128, sla_s=0.05,
                          n_chips=eng.config.n_chips)
    assert eng.slots == plan.slots > 0
    assert eng.admission.deadline_s == plan.flush_deadline_s > 0


def test_chunk_beyond_min_kv_ring_falls_back_to_single_shot(granite):
    """ROADMAP regression (rolling-window chunk safety): when a chunked
    prompt's padded length exceeds the SMALLEST KV ring (a local-attention
    block's window), multi-query chunks would alias overwritten ring slots
    — the engine must fall back to exact single-shot prefill and still
    produce correct streams. A prompt that does fit the ring keeps the
    chunked path."""
    import dataclasses

    cfg = dataclasses.replace(get_config("granite-8b").reduced(),
                              arch_type="hybrid",
                              block_pattern=("dense", "local_attn"),
                              local_window=16)
    params = init_params(cfg, jax.random.key(0))
    eng = make_engine(cfg, params, slots=2, window=128, chunk_prefill=8)
    assert not eng.paged and eng._min_window == 16  # ring < window
    # padded(40, 8) = 40 > 16: chunking would wrap the local ring
    unsafe = make_request(0, _prompt(40, seed=1), max_new_tokens=4)
    assert eng.try_admit(unsafe, 0.0)
    assert eng.n_prefilling == 0  # fell back: no chunk job was queued
    # padded(12, 8) = 16 <= 16: chunked path stays on
    safe = make_request(1, _prompt(12, seed=2), max_new_tokens=4)
    assert eng.try_admit(safe, 0.0)
    assert eng.n_prefilling == 1
    t = 0.0
    while not (unsafe.done and safe.done):
        t += 1.0
        eng.step(t)
    # both streams match a no-chunking engine exactly
    # same sampling identity as the chunked originals: the comparison is
    # chunking on/off, everything else equal
    ref_u = make_request(2, _prompt(40, seed=1), max_new_tokens=4,
                         sampling=unsafe.sampling)
    ref_s = make_request(3, _prompt(12, seed=2), max_new_tokens=4,
                         sampling=safe.sampling)
    _run(cfg, params, [ref_u, ref_s], slots=2, window=128, chunk_prefill=0)
    assert unsafe.output == ref_u.output
    assert safe.output == ref_s.output


def test_recurrent_arch_falls_back_to_exact_prefill(granite):
    """Archs with recurrent state (no end-paddable KV) must skip bucketing
    and chunking but still serve correctly."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = init_params(cfg, jax.random.key(0))
    eng = make_engine(cfg, params, slots=2, window=64)
    assert not eng.bucket_prompts and eng.chunk == 0
    req = make_request(0, _prompt(12), max_new_tokens=5)
    assert eng.try_admit(req, 0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        eng.step(t)
    assert len(req.output) == 5
