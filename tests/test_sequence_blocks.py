"""SSD / RG-LRU block math: chunked algorithms == naive step recurrences;
MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import rglru_scan, rglru_step
from repro.models.ssm import ssd_chunked


def test_ssd_chunked_matches_step_recurrence():
    b, s, h, p, n = 2, 48, 3, 8, 16
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    D = jnp.ones((h,))

    y, hT = ssd_chunked(x, dt, A, B, C, D, chunk=16)

    # naive recurrence: h_t = exp(dt A) h_{t-1} + B_t (dt*x)_t ; y = C_t h_t
    hn = np.zeros((b, h, p, n), np.float32)
    xn, dtn, An = np.asarray(x), np.asarray(dt), np.asarray(A)
    Bn, Cn, Dn = np.asarray(B), np.asarray(C), np.asarray(D)
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None])  # (b,h)
        xin = xn[:, t] * dtn[:, t][..., None]  # (b,h,p)
        hn = hn * decay[..., None, None] + np.einsum("bn,bhp->bhpn",
                                                     Bn[:, t], xin)
        yt = np.einsum("bn,bhpn->bhp", Cn[:, t], hn) + Dn[None, :, None] * xn[:, t]
        np.testing.assert_allclose(np.asarray(y[:, t]), yt, atol=2e-4,
                                   rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), hn, atol=2e-4, rtol=2e-3)


def test_ssd_chunk_size_invariance():
    b, s, h, p, n = 1, 64, 2, 4, 8
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    D = jnp.zeros((h,))
    y16, h16 = ssd_chunked(x, dt, A, B, C, D, chunk=16)
    y64, h64 = ssd_chunked(x, dt, A, B, C, D, chunk=64)
    y40, h40 = ssd_chunked(x, dt, A, B, C, D, chunk=40)  # padding path
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y40), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h40), atol=1e-4)


def test_rglru_scan_matches_step():
    cfg = get_config("recurrentgemma-9b").reduced()
    from repro.models.rglru import init_rglru

    p = init_rglru(cfg, jax.random.key(0), jnp.float32)
    b, s, lw = 2, 12, cfg.resolved_lru_width
    u = jax.random.normal(jax.random.key(1), (b, s, lw)) * 0.3
    y_scan, h_scan = rglru_scan(p, u)
    h = jnp.zeros((b, lw))
    for t in range(s):
        y_t, h = rglru_step(p, u[:, t:t + 1], h)
        np.testing.assert_allclose(np.asarray(y_scan[:, t]),
                                   np.asarray(y_t[:, 0]), atol=2e-5,
                                   rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h), atol=2e-5,
                               rtol=2e-4)


# --- MoE ---------------------------------------------------------------------


def _moe_cfg(**kw):
    cfg = get_config("grok-1-314b").reduced()
    return dataclasses.replace(cfg, **kw) if kw else cfg


def test_moe_capacity_drops_overflow():
    cfg = _moe_cfg(moe_capacity_factor=0.05)  # starve capacity
    p = init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    y, aux = apply_moe(cfg, p, x)
    assert y.shape == x.shape
    # with tiny capacity most tokens are dropped -> small output norm
    cfg_big = _moe_cfg(moe_capacity_factor=8.0)
    y_big, _ = apply_moe(cfg_big, p, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_big))


def test_moe_grouping_invariance_with_slack_capacity():
    cfg = _moe_cfg(moe_capacity_factor=8.0)
    p = init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    y1, _ = apply_moe(cfg, p, x, group_size=32)
    y2, _ = apply_moe(cfg, p, x, group_size=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)


def test_moe_aux_loss_prefers_balance():
    cfg = _moe_cfg(moe_capacity_factor=8.0)
    p = init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 128, cfg.d_model))
    _, aux_random = apply_moe(cfg, p, x)
    # collapse router to always pick expert 0 -> aux must grow
    p_collapsed = dict(p)
    router = np.zeros_like(np.asarray(p["router"]))
    router[:, 0] = 10.0
    p_collapsed["router"] = jnp.asarray(router)
    _, aux_collapsed = apply_moe(cfg, p_collapsed, x)
    assert float(aux_collapsed) > float(aux_random)


def test_moe_topk_uses_k_experts_per_token():
    cfg = _moe_cfg(moe_capacity_factor=8.0)
    assert cfg.experts_per_token == 2
    p = init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jnp.zeros((1, 4, cfg.d_model))
    x = x.at[0, 0, 0].set(1.0)
    y, _ = apply_moe(cfg, p, x)
    assert not jnp.isnan(y).any()
