"""Replica failure detection + failover: kill/hang/slow injection, the
staleness watchdog, retry budgets, and bit-identical replay."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    ClusterFrontend,
    EngineConfig,
    EngineFailure,
    FaultInjector,
    FaultyEngine,
    RequestState,
    SamplingParams,
    ServingEngine,
)

from conftest import make_request as Request


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _samp(seed):
    return SamplingParams(temperature=0.7, top_k=20, top_p=0.95, seed=seed)


def _workload(n, budget=5):
    """Sampled requests (stochastic streams: the strong replay claim)."""
    return [Request(i, _prompt(10 + i % 5, seed=i), max_new_tokens=budget,
                    sampling=_samp(500 + i)) for i in range(n)]


def _engines(cfg, params, n):
    return [ServingEngine(cfg, params, EngineConfig(slots=2, window=64, max_seq=128,
                          sync_every=1)) for _ in range(n)]


def _drive(fe, reqs, *, fault_at=None, max_steps=500):
    """Submit everything at t=0, optionally fire faults at virtual times
    via ``fault_at`` {t: callable}, collect every resolved request."""
    resolved, t = {}, 0.0
    for r in reqs:
        fe.submit(r, 0.0)
    while len(resolved) < len(reqs):
        t += 1.0
        if fault_at and t in fault_at:
            fault_at.pop(t)()
        for r in fe.step(t):
            resolved[r.rid] = r
        assert t < max_steps, f"{len(resolved)}/{len(reqs)} resolved"
    for r in fe.drain(t):
        resolved[r.rid] = r
    return resolved


def _reference(cfg, params, reqs):
    eng = _engines(cfg, params, 1)[0]
    fe = ClusterFrontend([eng], policy="round-robin", seed=0)
    res = _drive(fe, reqs)
    return {rid: list(r.output) for rid, r in res.items()}


# ---------------------------------------------------------------------------
# the proxy
# ---------------------------------------------------------------------------


def test_faulty_engine_is_transparent(granite):
    cfg, params = granite
    eng = _engines(cfg, params, 1)[0]
    proxy = FaultyEngine(eng)
    assert proxy.slots == eng.slots  # reads forward
    proxy.edf_backlog = True  # writes forward (ClusterFrontend does this)
    assert eng.edf_backlog is True
    assert proxy.engine is eng
    req = Request(0, _prompt(8), max_new_tokens=2)
    assert proxy.submit(req, 0.0)
    t = 0.0
    while not req.done:
        t += 1.0
        proxy.step(t)
        assert t < 50
    proxy.inject("kill")
    with pytest.raises(EngineFailure):
        proxy.step(t + 1.0)
    with pytest.raises(EngineFailure):
        proxy.submit(Request(1, _prompt(8, seed=1), 2), t + 1.0)
    proxy.inject("recover")
    proxy.step(t + 2.0)  # healthy again
    with pytest.raises(ValueError, match="unknown fault kind"):
        proxy.inject("meteor")


def test_fault_injector_schedule_is_deterministic(granite):
    cfg, params = granite
    proxy = FaultyEngine(_engines(cfg, params, 1)[0])
    inj = FaultInjector({"e0": proxy})
    inj.schedule(5.0, "e0", "hang")
    inj.schedule(2.0, "e0", "slow", slow_every=3)
    with pytest.raises(KeyError, match="no proxy"):
        inj.schedule(1.0, "nope", "kill")
    with pytest.raises(ValueError, match="unknown fault kind"):
        inj.schedule(1.0, "e0", "meteor")
    assert inj.tick(1.0) == [] and proxy.mode is None
    assert inj.tick(2.0) == [(2.0, "e0", "slow")]
    assert proxy.mode == "slow" and proxy.slow_every == 3
    assert inj.tick(10.0) == [(5.0, "e0", "hang")]  # late tick still fires
    assert proxy.mode == "hang" and inj.pending == 0
    assert inj.fired == [(2.0, "e0", "slow"), (5.0, "e0", "hang")]


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------


def test_kill_fails_over_bit_identical(granite):
    """A replica crash mid-workload loses nothing: the frontend harvests
    its outstanding ledger, survivors replay, and every stream —
    stochastic included — matches the failure-free run exactly."""
    cfg, params = granite
    reqs = _workload(8)
    reference = _reference(cfg, params, _workload(8))

    proxies = [FaultyEngine(e) for e in _engines(cfg, params, 2)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         max_retries=3)
    resolved = _drive(fe, reqs,
                      fault_at={2.0: lambda: proxies[0].inject("kill")})
    assert len(resolved) == 8
    assert all(r.state is RequestState.FINISHED for r in resolved.values())
    assert {rid: list(r.output) for rid, r in resolved.items()} == reference
    m = fe.merged_metrics()
    assert len(fe.failed) == 1 and fe.failed[0].failed
    assert m.failed_over > 0 and m.retried > 0
    assert max(r.retries for r in resolved.values()) <= 3
    # the survivor holds no leaked pages
    survivor = fe.instances[0].engine
    assert survivor.allocator.pages_in_use == 0
    assert survivor.allocator.total_refs == 0


def test_hang_detected_by_watchdog(granite):
    """A wedged replica raises nothing — it accepts work and makes no
    progress. Only the staleness watchdog can declare it dead; its
    requests then fail over and finish."""
    cfg, params = granite
    reqs = _workload(6)
    reference = _reference(cfg, params, _workload(6))
    proxies = [FaultyEngine(e) for e in _engines(cfg, params, 2)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         health_timeout_s=4.0, max_retries=3)
    resolved = _drive(fe, reqs,
                      fault_at={2.0: lambda: proxies[0].inject("hang")})
    assert len(resolved) == 6
    assert all(r.state is RequestState.FINISHED for r in resolved.values())
    assert {rid: list(r.output) for rid, r in resolved.items()} == reference
    assert len(fe.failed) == 1
    assert fe.merged_metrics().failed_over > 0


def test_slow_replica_is_not_declared_dead(granite):
    """Slow != dead: a replica making progress every k-th tick keeps its
    work (the closed-loop residual repels future load instead)."""
    cfg, params = granite
    proxies = [FaultyEngine(e) for e in _engines(cfg, params, 2)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         health_timeout_s=4.0, max_retries=3)
    resolved = _drive(fe, _workload(6),
                      fault_at={2.0: lambda: proxies[0].inject(
                          "slow", slow_every=3)})
    assert len(resolved) == 6
    assert all(r.state is RequestState.FINISHED for r in resolved.values())
    assert fe.failed == [] and fe.merged_metrics().failed_over == 0


def test_idle_hung_replica_stays_healthy_until_it_holds_work(granite):
    """Idle replicas are healthy by definition — a hang is only
    observable (and only matters) once work sinks into it."""
    cfg, params = granite
    proxies = [FaultyEngine(e) for e in _engines(cfg, params, 1)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         health_timeout_s=3.0, max_retries=3)
    proxies[0].inject("hang")
    for t in range(1, 8):  # idle well past the timeout: still trusted
        fe.step(float(t))
    assert fe.failed == [] and len(fe.instances) == 1
    req = Request(0, _prompt(8), max_new_tokens=2)
    fe.submit(req, 8.0)
    for t in range(9, 20):  # work sinks in; watchdog now trips
        fe.step(float(t))
        if fe.failed:
            break
    assert len(fe.failed) == 1
    assert req.retries == 1  # harvested and requeued (held: empty pool)
    # recovery: a fresh replica repopulates the pool; the request lands
    fe.add_engine(_engines(cfg, params, 1)[0])
    t = 20.0
    while not req.done:
        t += 1.0
        fe.step(t)
        assert t < 100
    assert req.state is RequestState.FINISHED and len(req.output) == 2


def test_retry_budget_exhaustion_resolves_failed(granite):
    """When no retry budget remains, a harvested request resolves FAILED
    (typed, with a reason) instead of looping or raising."""
    cfg, params = granite
    proxies = [FaultyEngine(e) for e in _engines(cfg, params, 1)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         max_retries=0)
    reqs = _workload(3)
    for r in reqs:
        fe.submit(r, 0.0)
    fe.step(0.0)  # dispatch: all three on the doomed replica's ledger
    proxies[0].inject("kill")
    resolved = {}
    for t in range(1, 10):
        for r in fe.step(float(t)):
            resolved[r.rid] = r
        if len(resolved) == 3:
            break
    assert len(resolved) == 3
    assert all(r.state is RequestState.FAILED for r in resolved.values())
    assert all("retry budget exhausted" in r.fail_reason
               for r in resolved.values())
    assert fe.merged_metrics().failed >= 3


def test_retry_backoff_delays_resubmission(granite):
    """With retry_backoff_s set, a failed-over request is held off the
    queue for base*2^(retries-1) before re-dispatch."""
    cfg, params = granite
    proxies = [FaultyEngine(e) for e in _engines(cfg, params, 2)]
    fe = ClusterFrontend(proxies, policy="round-robin", seed=0,
                         max_retries=3, retry_backoff_s=4.0)
    reqs = _workload(4)
    for r in reqs:
        fe.submit(r, 0.0)
    fe.step(0.0)
    proxies[0].inject("kill")
    fe.step(1.0)  # detection: harvested requests held until t=5
    held = [r for r in reqs if r.retries == 1 and not r.done]
    assert held and fe._held_retries
    assert not fe.idle  # held retries keep the cluster busy
    resolved = {}
    for t in range(2, 60):
        for r in fe.step(float(t)):
            resolved[r.rid] = r
        if len(resolved) == 4:
            break
    assert len(resolved) == 4
    assert all(r.state is RequestState.FINISHED for r in resolved.values())
    # replay could not have finished before the backoff released (t>=5)
    assert all(r.finish_time >= 5.0 for r in held)
