"""Stateful property tests over PageAllocator + PrefixIndex churn.

The hypothesis machine below drives random interleavings of
admit/share/register/free/evict/clear/lookup and checks the conservation
laws after every step:

  * the trash page (0) is never granted, shared, or indexed;
  * ``free_pages + pages_in_use == capacity`` (no page vanishes);
  * ``total_refs == slot-held refs + index-held refs`` (no refcount
    drift — this is the probe the chaos bench asserts hits zero);
  * the scale-page ledger stays in lockstep with page ownership: a
    quantized (int8) pool stores its fp32 dequant scales at the SAME
    page ids as the KV values, so every alloc/share/free that touches a
    KV page touches exactly that scale page — scale pages are never
    allocated, aliased, or freed independently;
  * draining every slot and clearing the index returns the pool to
    exactly empty.

hypothesis is optional tooling: when absent the machine skips and the
seeded churn test below covers the same invariants deterministically.
"""
import numpy as np
import pytest

from repro.serving import PageAllocator, PrefixIndex

PS = 4  # tokens per page: small so chains span several nodes
POOL = 33  # 32 usable pages + the reserved trash page


def _refs_accounted(alloc, idx, slots):
    """The conservation law: every live reference is held by a slot or
    by the index, and nothing else."""
    slot_refs = sum(len(alloc.owned(s)) for s in slots)
    return alloc.total_refs == slot_refs + idx.cached_pages


def _check_universe(alloc, idx, slots):
    assert alloc.free_pages + alloc.pages_in_use == alloc.capacity
    assert alloc.refcount(PageAllocator.TRASH_PAGE) == 0
    for s in slots:
        assert PageAllocator.TRASH_PAGE not in alloc.owned(s)
    assert _refs_accounted(alloc, idx, slots)


def _drain(alloc, idx, slots):
    for s in list(slots):
        alloc.free_slot(s)
    slots.clear()
    idx.clear()
    assert alloc.pages_in_use == 0
    assert alloc.total_refs == 0
    assert alloc.free_pages == alloc.capacity
    assert idx.cached_pages == 0


class _Churn:
    """Shared driver: the hypothesis rules and the seeded loop both call
    these operations so the two tests stay in lockstep."""

    def __init__(self):
        self.alloc = PageAllocator(POOL, PS)
        self.idx = PrefixIndex(self.alloc, PS)
        self.slots = {}  # slot -> prompt (unique token streams per slot)
        # Mirrored int8 scale-page ledger: quantized pools address their
        # fp32 scale pages by the SAME ids as the KV pages (one pool
        # array per leaf, no separate allocation), so the host-side
        # conservation rule is lockstep — a slot's scale pages are
        # exactly its owned KV pages at every step.
        self.scale_pages = {}  # slot -> page ids whose scales it holds
        self.uid = 0

    def admit(self, n_pages):
        self.uid += 1
        slot = self.uid
        # unique tokens per slot: radix keys collide only via share()
        prompt = [slot * 10_000 + i for i in range(n_pages * PS)]
        pages = self.alloc.alloc(slot, n_pages)
        if pages is None:
            assert n_pages > self.alloc.free_pages  # all-or-nothing
            return
        assert PageAllocator.TRASH_PAGE not in pages
        assert all(self.alloc.refcount(p) == 1 for p in pages)
        self.slots[slot] = prompt
        self.scale_pages[slot] = list(pages)  # scales ride the same ids

    def share(self, src):
        """A second holder aliases src's pages (the COW admit path)."""
        self.uid += 1
        slot = self.uid
        pages = self.alloc.owned(src)
        before = [self.alloc.refcount(p) for p in pages]
        self.alloc.share(slot, pages)
        after = [self.alloc.refcount(p) for p in pages]
        assert after == [r + 1 for r in before]
        self.slots[slot] = self.slots[src]  # same stream, same keys
        # COW aliasing shares values AND scales under one refcount
        self.scale_pages[slot] = list(pages)

    def register(self, slot):
        prompt = self.slots[slot]
        pages = self.alloc.owned(slot)
        added = self.idx.register(prompt, pages)
        assert 0 <= added <= len(prompt) // PS

    def lookup(self, slot):
        hit = self.idx.lookup(self.slots[slot])
        if hit is not None:
            assert hit.tokens <= len(self.slots[slot]) - 1
            for p in hit.full_pages:
                assert p != PageAllocator.TRASH_PAGE
                assert self.alloc.refcount(p) >= 1

    def free(self, slot):
        owned = self.scale_pages.pop(slot)
        freed = self.alloc.free_slot(slot)
        assert all(self.alloc.refcount(p) == 0 for p in freed)
        # a freed KV page frees exactly its scale page, never another's
        assert set(freed) <= set(owned)
        del self.slots[slot]

    def evict(self, n):
        freed = self.idx.evict(n)
        assert freed >= 0

    def clear(self):
        self.idx.clear()
        assert self.idx.cached_pages == 0

    def check(self):
        _check_universe(self.alloc, self.idx, self.slots)
        # scale-page conservation: per slot, scale ids == owned KV ids
        assert sorted(self.scale_pages) == sorted(self.slots)
        for s, pages in self.scale_pages.items():
            assert sorted(pages) == sorted(self.alloc.owned(s))
            assert PageAllocator.TRASH_PAGE not in pages


def test_seeded_churn_conserves_pages_and_refs():
    """Deterministic twin of the hypothesis machine (runs everywhere)."""
    rng = np.random.default_rng(42)
    for trial in range(8):
        ch = _Churn()
        for _ in range(120):
            live = list(ch.slots)
            op = rng.integers(0, 7)
            if op <= 1 or not live:
                ch.admit(int(rng.integers(1, 5)))
            elif op == 2:
                ch.share(live[int(rng.integers(len(live)))])
            elif op == 3:
                ch.register(live[int(rng.integers(len(live)))])
            elif op == 4:
                ch.lookup(live[int(rng.integers(len(live)))])
            elif op == 5:
                ch.free(live[int(rng.integers(len(live)))])
            else:
                ch.evict(int(rng.integers(1, 9))) if rng.integers(2) \
                    else ch.clear()
            ch.check()
        _drain(ch.alloc, ch.idx, ch.slots)


# A bare ``pytest.importorskip`` at module scope would skip the seeded
# twin above as well, so the machine is gated on a soft import instead.
try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        invariant,
        precondition,
        rule,
    )

    class PagingMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.ch = _Churn()

        def _pick(self, i):
            live = sorted(self.ch.slots)
            return live[i % len(live)]

        @rule(n=st.integers(min_value=1, max_value=5))
        def admit(self, n):
            self.ch.admit(n)

        @precondition(lambda self: self.ch.slots)
        @rule(i=st.integers(min_value=0, max_value=10**6))
        def share(self, i):
            self.ch.share(self._pick(i))

        @precondition(lambda self: self.ch.slots)
        @rule(i=st.integers(min_value=0, max_value=10**6))
        def register(self, i):
            self.ch.register(self._pick(i))

        @precondition(lambda self: self.ch.slots)
        @rule(i=st.integers(min_value=0, max_value=10**6))
        def lookup(self, i):
            self.ch.lookup(self._pick(i))

        @precondition(lambda self: self.ch.slots)
        @rule(i=st.integers(min_value=0, max_value=10**6))
        def free(self, i):
            self.ch.free(self._pick(i))

        @rule(n=st.integers(min_value=1, max_value=8))
        def evict(self, n):
            self.ch.evict(n)

        @rule()
        def clear(self):
            self.ch.clear()

        @invariant()
        def conservation(self):
            self.ch.check()

        def teardown(self):
            _drain(self.ch.alloc, self.ch.idx, self.ch.slots)

    PagingMachine.TestCase.settings = settings(
        max_examples=40, stateful_step_count=40, deadline=None)

    TestPagingChurn = PagingMachine.TestCase
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_paging_churn_hypothesis():
        """Placeholder so the skipped property test stays visible."""
