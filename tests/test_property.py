"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import WorkEstimate, estimate_decode
from repro.core.misd.interference import progress_rates
from repro.core.misd.scheduler import Device, FIFOScheduler, Job, MISDSimulator
from repro.core.simd.offload import zipf_hit_rate
from repro.models.layers import block_attention, dense_attention

demand = st.tuples(st.floats(0.01, 1.0), st.floats(0.01, 1.0))


@given(st.lists(demand, min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_progress_rates_valid(demands):
    rates = progress_rates(demands)
    assert len(rates) == len(demands)
    assert all(0 < r <= 1.0 for r in rates)
    # adding a tenant never speeds anyone up
    if len(demands) > 1:
        fewer = progress_rates(demands[:-1])
        assert all(a <= b + 1e-12 for a, b in zip(rates, fewer))


@given(st.lists(st.tuples(st.floats(0.001, 0.1), st.floats(0.0, 0.5)),
                min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_simulator_conservation(specs):
    """Every job completes exactly once, never before arrival+service."""
    jobs = [Job(i, "m", (0.5, 0.5), s, arrival=a)
            for i, (s, a) in enumerate(specs)]
    res = MISDSimulator([Device("d", 3)], FIFOScheduler()).run(jobs)
    assert len(res.completed) == len(specs)
    ids = sorted(j.jid for j in res.completed)
    assert ids == list(range(len(specs)))
    for j in res.completed:
        assert j.finish >= j.arrival + j.service_s - 1e-9


@given(st.integers(1, 1000), st.integers(1, 1000))
@settings(max_examples=50, deadline=None)
def test_zipf_hit_rate_bounds(cache, total):
    h = zipf_hit_rate(cache, total)
    assert 0.0 <= h <= 1.0
    if cache >= total:
        assert h == 1.0


@given(st.integers(1, 256), st.integers(128, 8192))
@settings(max_examples=30, deadline=None)
def test_decode_estimate_monotone(batch, context):
    from repro.configs import get_config

    cfg = get_config("granite-8b")
    e1 = estimate_decode(cfg, batch, context)
    e2 = estimate_decode(cfg, batch + 1, context)
    e3 = estimate_decode(cfg, batch, context + 128)
    assert e2.flops > e1.flops
    assert e3.hbm_bytes >= e1.hbm_bytes
    assert e1.latency_s > 0
    assert e1.bottleneck in ("compute", "memory", "collective")


@given(
    st.sampled_from([64, 128, 256]),
    st.sampled_from([32, 64]),
    st.booleans(),
    st.integers(0, 2 ** 31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_block_attention_matches_dense(s, d, causal, seed):
    """The flat block-pair online-softmax scan == plain masked attention."""
    b, h = 1, 2
    key = jax.random.key(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = block_attention(q, k, v, causal=causal, chunk=32)
    want = dense_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-4)


@given(st.sampled_from([128, 256]), st.sampled_from([32, 64, 96]))
@settings(max_examples=8, deadline=None)
def test_block_attention_window(s, w):
    """Sliding-window block attention == dense with the same band mask."""
    b, h, d = 1, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = block_attention(q, k, v, causal=True, window=w, chunk=32)
    want = dense_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-4)


@given(st.floats(1e6, 1e15), st.floats(1e3, 1e12), st.floats(0, 1e12))
@settings(max_examples=50, deadline=None)
def test_work_estimate_roofline(flops, hbm, coll):
    e = WorkEstimate(flops, hbm, coll)
    assert e.latency_s >= max(e.compute_s, e.memory_s, e.collective_s)
    c, m = e.demand
    assert 0 <= c <= 1 and 0 <= m <= 1
