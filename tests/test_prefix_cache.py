"""Shared-prefix KV cache: refcounted page sharing, the radix PrefixIndex
(lookup / register / COW tail / LRU eviction), suffix-offset prefill
stream identity, trace stability across hit lengths, eviction under pool
pressure, and zero-leak drains."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    EngineConfig,
    PageAllocator,
    PrefixIndex,
    ServingEngine,
)

# Requests ride the CI config matrix: under REPRO_ENGINE_SAMPLING=sampled
# every request in this suite samples with a rid-stable seed
# (conftest.make_request shares Request's positional signature), so the
# prefix-cache hit/COW/eviction invariants — including warm-vs-cold
# stream identity — are exercised under stochastic decode as well.
from conftest import make_request as Request


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _drive(eng, reqs, t=0.0):
    for r in reqs:
        assert eng.try_admit(r, t)
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t)
    return t


def _serve_each(eng, prompts, budget=4, t=0.0):
    """Admit + fully serve prompts one at a time; returns requests."""
    out = []
    for i, p in enumerate(prompts):
        r = Request(1000 + i, np.asarray(p, np.int32), max_new_tokens=budget)
        t = _drive(eng, [r], t) + 1.0
        out.append(r)
    return out


# ---------------------------------------------------------------------------
# refcounted allocator edges
# ---------------------------------------------------------------------------


def test_share_then_free_in_both_orders():
    """Double-share then free in both orders: a page returns to the free
    list exactly when its LAST holder drops, regardless of order."""
    for first, second in ((0, 1), (1, 0)):
        a = PageAllocator(9, 16)
        pages = a.alloc(0, 2)
        a.share(1, pages)
        assert all(a.refcount(p) == 2 for p in pages)
        assert a.pages_in_use == 2
        assert a.free_slot(first) == []  # still held by the other slot
        assert a.pages_in_use == 2
        assert all(a.refcount(p) == 1 for p in pages)
        freed = a.free_slot(second)
        assert sorted(freed) == sorted(pages)
        assert a.pages_in_use == 0 and a.total_refs == 0


def test_share_and_retain_reject_dead_pages():
    a = PageAllocator(5, 16)
    pages = a.alloc(0, 1)
    with pytest.raises(ValueError, match="not live"):
        a.share(1, [pages[0] + 1])  # never granted
    with pytest.raises(ValueError, match="not live"):
        a.retain(a.TRASH_PAGE)
    a.retain(pages[0])
    a.free_slot(0)
    assert a.refcount(pages[0]) == 1  # the retain survives the slot
    assert a.release(pages[0]) is True
    with pytest.raises(ValueError, match="not live"):
        a.release(pages[0])


def test_alloc_exclusive_vs_shared_accounting():
    """alloc spends pool pages; share does not (aliases cost nothing)."""
    a = PageAllocator(5, 16)  # 4 usable
    pages = a.alloc(0, 4)
    assert a.free_pages == 0
    a.share(1, pages)
    a.share(2, pages[:2])
    assert a.free_pages == 0 and a.pages_in_use == 4
    assert a.owned(1) == pages and a.owned(2) == pages[:2]
    a.free_slot(0)
    a.free_slot(1)
    assert a.pages_in_use == 2  # slot 2 still aliases two pages
    a.free_slot(2)
    assert a.pages_in_use == 0


# ---------------------------------------------------------------------------
# PrefixIndex (host-side radix tree)
# ---------------------------------------------------------------------------


def _mk_index(ps=4, pool=33):
    a = PageAllocator(pool, ps)
    return a, PrefixIndex(a, ps)


def test_index_register_lookup_full_and_tail():
    a, idx = _mk_index(ps=4)
    prompt = np.arange(12, dtype=np.int32)  # 3 full pages
    pages = a.alloc(0, 3)
    assert idx.register(prompt, pages) == 3
    assert idx.cached_pages == 3 and idx.cached_tokens == 12
    # full match capped at plen-1: the last page converts to a COW tail
    hit = idx.lookup(prompt)
    assert hit.tokens == 11
    assert list(hit.full_pages) == pages[:2]
    assert hit.tail_page == pages[2] and hit.tail_tokens == 3
    # longer prompt sharing 2 pages + 2 tokens of the third
    other = np.concatenate([np.arange(10), [99, 98, 97, 96]]).astype(np.int32)
    hit = idx.lookup(other)
    assert hit.tokens == 10 and list(hit.full_pages) == pages[:2]
    assert hit.tail_page == pages[2] and hit.tail_tokens == 2
    # diverging first page: no full page matches -> miss
    assert idx.lookup(np.asarray([7, 7, 7, 7, 8], np.int32)) is None
    # match_len mirrors lookup without LRU/counter effects
    assert idx.match_len(prompt) == 11 and idx.match_len(other) == 10
    assert idx.match_len(np.asarray([7, 7, 7, 7, 8], np.int32)) == 0


def test_index_register_keeps_existing_nodes():
    """A concurrent duplicate registration must not replace the cached
    chain (the second requester's private pages stay exclusive)."""
    a, idx = _mk_index(ps=4)
    prompt = np.arange(8, dtype=np.int32)
    first = a.alloc(0, 2)
    idx.register(prompt, first)
    second = a.alloc(1, 2)
    assert idx.register(prompt, second) == 0  # nothing new
    assert list(idx.lookup(prompt).full_pages) == first[:1]
    assert a.refcount(second[0]) == 1  # no index hold on the duplicate


def test_eviction_never_frees_shared_pages():
    """Satellite: eviction only reclaims pages whose sole reference is
    the index's own — a chain aliased by a live slot survives any evict."""
    a, idx = _mk_index(ps=4, pool=9)
    p1 = a.alloc(0, 2)
    idx.register(np.arange(8, dtype=np.int32), p1)
    a.free_slot(0)  # now held only by the index
    p2 = a.alloc(1, 2)
    idx.register(np.arange(100, 108, dtype=np.int32), p2)
    # slot 1 stays live: its chain must survive any eviction demand
    freed = idx.evict(100)
    assert freed == 2  # only the idle chain went
    assert a.pages_in_use == 2
    assert idx.lookup(np.arange(100, 108, dtype=np.int32)) is not None
    assert idx.lookup(np.arange(8, dtype=np.int32)) is None
    a.free_slot(1)
    assert idx.evict(100) == 2
    assert a.pages_in_use == 0 and a.total_refs == 0


def test_eviction_lru_order_leaves_first():
    """Oldest-stamped chains evict first, leaves inward; a lookup hit
    refreshes the chain so hot templates survive."""
    a, idx = _mk_index(ps=4, pool=17)
    old = a.alloc(0, 2)
    idx.register(np.arange(8, dtype=np.int32), old)
    new = a.alloc(1, 2)
    idx.register(np.arange(50, 58, dtype=np.int32), new)
    a.free_slot(0)
    a.free_slot(1)
    idx.lookup(np.arange(8, dtype=np.int32))  # refresh the OLD chain
    assert idx.match_len(np.arange(50, 58, dtype=np.int32)) == 7
    assert idx.evict(1) == 1  # one page: the now-older 50.. chain's LEAF
    # the evicted chain shrank to its surviving root page; the refreshed
    # chain is untouched
    assert idx.match_len(np.arange(50, 58, dtype=np.int32)) == 4
    assert idx.match_len(np.arange(8, dtype=np.int32)) == 7


# ---------------------------------------------------------------------------
# engine: suffix-offset prefill correctness
# ---------------------------------------------------------------------------


def test_prefix_hit_streams_identical_sync_suffix(granite):
    """Acceptance: template+suffix admissions served from the cache are
    bit-identical to a cold engine's streams (synchronous suffix path)."""
    cfg, params = granite
    tpl = _prompt(48, seed=3)
    prompts = [tpl] + [np.concatenate([tpl, _prompt(n, seed=10 + n)])
                       for n in (5, 9, 17)]
    kw = dict(slots=1, window=64, max_seq=128, chunk_prefill=0, sync_every=2)
    cold = ServingEngine(cfg, params, EngineConfig(**kw))
    warm = ServingEngine(cfg, params, EngineConfig(prefix_cache=True, **kw))
    rc = _serve_each(cold, prompts)
    rw = _serve_each(warm, prompts)
    assert [r.output for r in rw] == [r.output for r in rc]
    assert [r.prefix_hit_tokens for r in rw] == [0, 48, 48, 48]
    assert warm.metrics.prefix_hits == 3
    assert warm.metrics.prefix_hit_tokens == 144


def test_prefix_hit_streams_identical_chunked_suffix(granite):
    """A long suffix behind a cached template rides the interleaved
    chunk path from a nonzero offset — streams still bit-identical."""
    cfg, params = granite
    tpl = _prompt(64, seed=4)
    long = np.concatenate([tpl, _prompt(40, seed=5)])
    kw = dict(slots=2, window=64, max_seq=256, chunk_prefill=16)
    cold = ServingEngine(cfg, params, EngineConfig(**kw))
    warm = ServingEngine(cfg, params, EngineConfig(prefix_cache=True, **kw))
    rc = _serve_each(cold, [tpl, long])
    rw = _serve_each(warm, [tpl, long])
    assert [r.output for r in rw] == [r.output for r in rc]
    assert rw[1].prefix_hit_tokens == 64
    assert warm.metrics.prefill_chunks < cold.metrics.prefill_chunks


def test_cow_tail_page_shared_three_ways(granite):
    """Satellite: three concurrent requests aliasing one tail page each
    get a private copy-on-write replacement; the shared page itself is
    never written (the original owner's stream and later hits stay
    intact), and refcounts drain to the index's hold alone."""
    cfg, params = granite
    p = _prompt(32, seed=6)  # exactly 2 pages: duplicates share a COW tail
    kw = dict(slots=3, window=64, chunk_prefill=0, sync_every=2)
    cold = ServingEngine(cfg, params, EngineConfig(**kw))
    ref = [Request(i, p.copy(), max_new_tokens=6) for i in range(3)]
    _drive(cold, ref)

    warm = ServingEngine(cfg, params, EngineConfig(prefix_cache=True, **kw))
    primer = Request(9, p.copy(), max_new_tokens=1)
    assert warm.try_admit(primer, 0.0)  # registers both pages, releases
    tail = warm.prefix_index.lookup(p).tail_page
    reqs = [Request(i, p.copy(), max_new_tokens=6) for i in range(3)]
    for r in reqs:
        assert warm.try_admit(r, 0.0)
    # all three alias the first page and drew a COW copy of the tail:
    # first page refcount = index + 3 slots; tail page stays index-only +
    # the three transient gathers already released
    first_page = warm.prefix_index.lookup(p).full_pages[0]
    assert warm.allocator.refcount(first_page) == 4
    assert warm.allocator.refcount(tail) == 1
    t = 0.0
    while not all(r.done for r in reqs):
        t += 1.0
        warm.step(t)
    warm.drain(t)
    assert [r.output for r in reqs] == [r.output for r in ref]
    assert all(r.prefix_hit_tokens == 31 for r in reqs)
    # drained: only the index holds pages; a fresh duplicate still hits
    assert warm.allocator.refcount(first_page) == 1
    assert warm.allocator.pages_in_use == warm.prefix_index.cached_pages
    # same sampling identity as the rid-0 reference (different rid =>
    # different matrix seed would legitimately change the stream)
    again = Request(20, p.copy(), max_new_tokens=6,
                    sampling=reqs[0].sampling)
    _drive(warm, [again], t + 1.0)
    assert again.output == ref[0].output


def test_suffix_prefill_reuses_bucket_traces(granite):
    """Acceptance probe: hit admissions cost one seed/suffix trace per
    SUFFIX bucket — different hit lengths and suffix lengths inside one
    bucket must not retrace (prefill_traces stays flat)."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, max_seq=128,
                        chunk_prefill=0, prefix_cache=True))
    base = _prompt(48, seed=7)
    _serve_each(eng, [base], budget=2)
    _serve_each(eng, [np.concatenate([base, _prompt(3, seed=70)])], budget=2)
    flat = eng.prefill_traces  # cold bucket + first suffix bucket
    hits = [np.concatenate([base, _prompt(n, seed=71 + n)])
            for n in (5, 9, 11, 14)]
    reqs = _serve_each(eng, hits, budget=2)
    assert all(r.prefix_hit_tokens > 0 for r in reqs)
    assert eng.prefill_traces == flat  # zero new compiles across hits


def test_eviction_under_pool_pressure_admits(granite):
    """A pool filled with cached prefixes must evict (oldest chain first)
    to admit fresh work rather than backpressure forever."""
    cfg, params = granite
    # 1 slot x 4 pages working set + tiny cache headroom
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, pool_pages=7,
                        chunk_prefill=0, prefix_cache=True))
    a = _prompt(30, seed=8)
    _serve_each(eng, [a], budget=2)
    assert eng.prefix_index.cached_pages == 1  # 30 tokens -> 1 full page
    # an unrelated prompt needing the whole pool forces eviction
    b = Request(50, _prompt(40, seed=9), max_new_tokens=20)
    assert eng.try_admit(b, 0.0)
    t = 0.0
    while not b.done:
        t += 1.0
        eng.step(t)
    eng.drain(t)
    assert eng.metrics.prefix_hits == 0  # b was cold
    assert eng.allocator.pages_in_use == eng.prefix_index.cached_pages


def test_zero_leaks_after_churned_workload(granite):
    """Satellite: waves of mixed cold/hit/evict traffic conserve pages
    exactly — after drain the pool holds only the index's pages, and a
    cache clear returns every refcount to zero."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=2, window=64, max_seq=64,
                        pool_pages=17, chunk_prefill=0, sync_every=2,
                        prefix_cache=True))
    tpls = [_prompt(32, seed=s) for s in (20, 21)]
    rng = np.random.default_rng(0)
    t = 0.0
    for wave in range(4):
        reqs = []
        for i in range(3):
            tpl = tpls[int(rng.integers(0, 2))]
            sfx = rng.integers(0, 500, int(rng.integers(0, 9)))
            p = np.concatenate([tpl, sfx]).astype(np.int32)
            reqs.append(Request(100 * wave + i, p,
                                max_new_tokens=int(rng.integers(1, 5))))
        for r in reqs:
            eng.submit(r, t)
        while not all(r.done for r in reqs):
            t += 1.0
            eng.step(t)
        eng.drain(t)
        assert eng.allocator.pages_in_use == eng.prefix_index.cached_pages
    assert eng.metrics.prefix_hits > 0
    freed = eng.clear_prefix_cache()
    assert freed >= 0 and eng.allocator.pages_in_use == 0
    assert eng.allocator.total_refs == 0 and eng.allocator.free_pages == 16


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------


def test_prefix_cache_requires_paged():
    cfg = get_config("recurrentgemma-9b").reduced()
    params = init_params(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="prefix_cache"):
        ServingEngine(cfg, params, EngineConfig(slots=1, prefix_cache=True))


def test_load_report_and_reset_prefix_stats(granite):
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(slots=1, window=64, chunk_prefill=0,
                        prefix_cache=True))
    p = _prompt(32, seed=11)
    _serve_each(eng, [p, p], budget=2)
    rep = eng.load_report()
    assert rep.prefix_hits == 1 and rep.prefix_hit_tokens == 31
    assert rep.prefix_cached_pages == eng.prefix_index.cached_pages > 0
    assert rep.prefix_cached_tokens == rep.prefix_cached_pages * 16
    assert eng.prefix_match_len(p) == 31
    eng.reset()  # clears the index and every refcount
    assert eng.allocator.pages_in_use == 0 and eng.allocator.total_refs == 0
    rep = eng.load_report()
    assert rep.prefix_cached_pages == 0 and rep.prefix_hits == 0
