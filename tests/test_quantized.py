"""Quantized serving path: int8 KV-cache pages + fused-dequant paged
decode + weight-only int8, behind the frozen ``PrecisionConfig`` API.

Coverage: config validation fails BEFORE any trace; the fused-dequant
kernel matches the sort-free ref.py oracle (including exact score ties);
the dequant-attention error is bounded by the closed-form sort-free
bounds across page counts / head dims / scale granularities (hypothesis
when available, seeded sweep always); the engine contracts (determinism,
exact first token, prefix-cache hits and preempt/restore without page
leaks) hold over quantized pools; and the capacity math (per-pool
``kv_bytes_per_token`` -> ~2x admission slots) that motivates all of it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import kv_bytes_per_token
from repro.core.misd.batching import plan_admission
from repro.kernels import ops, ref
from repro.models import init_params, layers as L, quantize_weights
from repro.models.blocks import dequantize_kv, quantize_kv
from repro.serving import (
    DeviceTopology,
    EngineConfig,
    LoadReport,
    PrecisionConfig,
    Request,
    SamplingParams,
    ServingEngine,
)

INT8_KV = PrecisionConfig(kv_cache_dtype="int8")
F32 = jnp.float32


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    return cfg, init_params(cfg, jax.random.key(0))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, n).astype(np.int32)


def _mk(key, shape, dtype=F32):
    return jax.random.normal(jax.random.key(key), shape, F32).astype(dtype)


def _drive(eng, reqs, t0=0.0):
    t = t0
    while not all(r.done for r in reqs):
        t += 1.0
        eng.step(t)
    eng.drain(t)
    return t


# ---------------------------------------------------------------------------
# PrecisionConfig: frozen value object, validation before any trace
# ---------------------------------------------------------------------------


def test_precision_config_frozen_validated_hashable():
    p = PrecisionConfig(kv_cache_dtype="int8", weight_dtype="int8")
    assert p.quantized_kv and p.quantized_weights
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.kv_cache_dtype = ""
    with pytest.raises(ValueError, match="kv_cache_dtype"):
        PrecisionConfig(kv_cache_dtype="fp4")
    with pytest.raises(ValueError, match="weight_dtype"):
        PrecisionConfig(weight_dtype="int4")
    with pytest.raises(ValueError, match="kv_scale_granularity"):
        PrecisionConfig(kv_scale_granularity="tensor")
    # precision participates in EngineConfig value semantics
    a, b = EngineConfig(precision=INT8_KV), EngineConfig(precision=INT8_KV)
    assert a == b and hash(a) == hash(b)
    assert a != EngineConfig()


def test_validate_rejects_unservable_precision_before_trace():
    """Every unsupported (precision, arch, layout) combination fails at
    validate()/construction time with the fix in the message — never as
    an XLA dtype error mid-trace."""
    dense = get_config("granite-8b").reduced()
    # quantized KV needs paged pools: rolling cache is out...
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(paged=False, precision=INT8_KV).validate(dense)
    # ...and so is every arch with rolling/recurrent-cache blocks
    for arch in ("recurrentgemma_9b", "mamba2_1_3b", "hubert_xlarge"):
        with pytest.raises(ValueError, match="pageable"):
            EngineConfig(precision=INT8_KV).validate(
                get_config(arch).reduced())
    # weight-only int8 serves WEIGHT_QUANT_BLOCKS archs only
    int8_w = PrecisionConfig(weight_dtype="int8")
    for arch, bad in (("grok-1-314b", "moe"), ("mamba2_1_3b", "ssd")):
        with pytest.raises(ValueError, match=bad):
            EngineConfig(precision=int8_w).validate(
                get_config(arch).reduced())
    if jax.local_device_count() >= 8:  # topology check fires first
        with pytest.raises(ValueError, match="sharded"):
            EngineConfig(topology=DeviceTopology(tp=8),
                         precision=int8_w).validate(dense)
    # the supported combinations validate chainably
    c = EngineConfig(precision=PrecisionConfig(kv_cache_dtype="int8",
                                               weight_dtype="int8"))
    assert c.validate(dense) is c


def test_engine_construction_rejects_rolling_plus_int8(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params,
                      EngineConfig(paged=False, precision=INT8_KV))


# ---------------------------------------------------------------------------
# capacity math: per-pool byte cost -> admission slots
# ---------------------------------------------------------------------------


def test_kv_bytes_per_token_is_a_per_pool_property():
    cfg = get_config("granite-8b").reduced()
    full = kv_bytes_per_token(cfg)
    quant = kv_bytes_per_token(cfg, "int8")
    # int8 values + one fp32 scale per (token, kv-head) vector: at least
    # the >= 1.8x capacity headline, approaching 4x as hd grows
    assert full / quant >= 1.8
    with pytest.raises(AssertionError, match="over-admit"):
        kv_bytes_per_token(cfg, "fp8")


def test_plan_admission_int8_roughly_doubles_memory_bound_slots():
    """With the KV HBM budget binding (huge SLA, huge max_slots), int8
    pages must buy >= 1.8x the concurrent slots of the f32 pool — the
    regression probe for the old fixed bytes-per-token assumption."""
    cfg = get_config("granite-8b").reduced()
    budget = kv_bytes_per_token(cfg) * 512 * 8  # 8 f32 slots' worth
    kw = dict(context=512, sla_s=1e9, max_slots=4096,
              kv_hbm_budget_bytes=budget)
    f32_plan = plan_admission(cfg, **kw)
    i8_plan = plan_admission(cfg, **kw, kv_cache_dtype="int8")
    assert f32_plan.slots == 8
    assert i8_plan.slots / f32_plan.slots >= 1.8


# ---------------------------------------------------------------------------
# fused-dequant kernel vs the sort-free oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq", [1, 4])
@pytest.mark.parametrize("d", [32, 64])
def test_paged_decode_int8_kernel_matches_oracle(sq, d):
    """Scattered/permuted page tables, one partial slot, one fully
    resident slot — the int8 kernel must match dequantize-then-exact."""
    b, h, kv = 2, 4, 2
    ps, pool_p = 16, 12
    q = _mk(30, (b, sq, h, d))
    kq, ks = quantize_kv(_mk(31, (pool_p, ps, kv, d)))
    vq, vs = quantize_kv(_mk(32, (pool_p, ps, kv, d)))
    table = jnp.asarray([[7, 3, 11, 0], [2, 9, 4, 6]], jnp.int32)
    pos = jnp.asarray([ps * 2 + 5, ps * 4], jnp.int32)
    out = ops.paged_decode_attention_int8(q, kq, vq, ks, vs, table, pos,
                                          interpret=True)
    want = ref.ref_paged_decode_attention_int8(q, kq, vq, ks, vs, table,
                                               pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_int8_kernel_value_ties():
    """Exactly tied scores (duplicate key vectors across pages) must not
    depend on visit order: every valid token gets the same softmax
    weight, so the output is the mean of the dequantized values — and
    the kernel, the sort-free oracle, and that closed form all agree."""
    b, h, kv, d, ps = 1, 4, 2, 32, 8
    pool_p, n_valid = 6, 12  # pages 3 and 5, second one partial
    q = _mk(33, (b, 1, h, d))
    k = jnp.ones((pool_p, ps, kv, d), F32) * 0.5  # all keys identical
    kq, ks = quantize_kv(k)
    assert int(jnp.max(jnp.abs(dequantize_kv(kq, ks, F32) - k))) == 0
    vq, vs = quantize_kv(_mk(34, (pool_p, ps, kv, d)))
    table = jnp.asarray([[3, 5]], jnp.int32)
    pos = jnp.asarray([n_valid], jnp.int32)
    out = ops.paged_decode_attention_int8(q, kq, vq, ks, vs, table, pos,
                                          interpret=True)
    want = ref.ref_paged_decode_attention_int8(q, kq, vq, ks, vs, table,
                                               pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    vd = dequantize_kv(vq, vs, F32)
    rows = jnp.take(vd, table[0], axis=0).reshape(-1, kv, d)[:n_valid]
    mean = jnp.mean(rows, axis=0)  # (kv, d): uniform tied weights
    for hh in range(h):
        np.testing.assert_allclose(np.asarray(out[0, 0, hh]),
                                   np.asarray(mean[hh // (h // kv)]),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# error-vs-bound property: dequant attention stays inside the sort-free
# closed-form bounds across page counts / head dims / scale granularity
# ---------------------------------------------------------------------------


def _bound_case(seed, n_pages, d, per_page_scales):
    """One draw: exact f32 paged attention vs the int8 path, errors
    checked against the score and output bounds from kernels/ref.py."""
    b, h, kv, ps = 2, 4, 2, 8
    w = ps * n_pages
    rng = np.random.default_rng(seed)
    scale_mag = float(rng.uniform(0.2, 4.0))  # vary dynamic range
    q = _mk(seed * 3 + 1, (b, 1, h, d)) * scale_mag
    kc = _mk(seed * 3 + 2, (b, w, kv, d)) * scale_mag
    vc = _mk(seed * 3 + 3, (b, w, kv, d)) * scale_mag
    k_pool = kc.reshape(b * n_pages, ps, kv, d)
    v_pool = vc.reshape(b * n_pages, ps, kv, d)
    table = jnp.arange(b * n_pages, dtype=jnp.int32).reshape(b, n_pages)
    pos = jnp.asarray([int(rng.integers(1, w + 1)), w], jnp.int32)
    group = ps if per_page_scales else 0
    kq, ks = quantize_kv(k_pool, group)
    vq, vs = quantize_kv(v_pool, group)

    # score bound: mask-agnostic, so check it over EVERY (q, k) pair
    g = h // kv
    k_deq = dequantize_kv(kq, ks, F32).reshape(b, w, kv, d)
    sc = lambda kk: jnp.einsum(
        "bqhd,bwhd->bhqw", q, jnp.repeat(kk, g, axis=2)) * d ** -0.5
    score_err = float(jnp.max(jnp.abs(sc(k_deq) - sc(kc))))
    eps = float(ref.int8_attention_score_bound(q, ks))
    assert score_err <= eps + 1e-6, (score_err, eps)

    # output bound: quantized-path output vs the exact f32 oracle
    exact = ref.ref_paged_decode_attention(q, k_pool, v_pool, table, pos)
    quant = ref.ref_paged_decode_attention_int8(q, kq, vq, ks, vs, table,
                                                pos)
    out_err = float(jnp.max(jnp.abs(quant - exact)))
    v_deq = dequantize_kv(vq, vs, F32)
    bound = float(ref.int8_attention_output_bound(q, ks, vs, v_deq))
    assert out_err <= bound + 1e-6, (out_err, bound)
    assert out_err < bound  # conservative: never tight to the last ulp


@pytest.mark.parametrize("n_pages", [1, 2, 4])
@pytest.mark.parametrize("d", [32, 64])
@pytest.mark.parametrize("per_page_scales", [False, True],
                         ids=["token-scales", "page-scales"])
def test_int8_attention_error_within_bound_seeded(n_pages, d,
                                                  per_page_scales):
    """Deterministic sweep (runs everywhere) of the hypothesis property
    below: page counts x head dims x scale granularity x seeds."""
    for seed in (1, 7, 23):
        _bound_case(seed, n_pages, d, per_page_scales)


def test_int8_attention_error_within_bound_property():
    """hypothesis: for random shapes/magnitudes the dequant-attention
    error never exceeds the closed-form sort-free bounds."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 10 ** 6), st.sampled_from([1, 2, 4]),
           st.sampled_from([32, 64]), st.booleans())
    @settings(max_examples=25, deadline=None)
    def prop(seed, n_pages, d, per_page_scales):
        _bound_case(seed, n_pages, d, per_page_scales)

    prop()


# ---------------------------------------------------------------------------
# weight-only int8: kernels/int8_matmul.py semantics through layers
# ---------------------------------------------------------------------------


def test_quantize_weights_matches_int8_matmul_semantics(granite):
    cfg, params = granite
    qp = quantize_weights(cfg, params)
    stacked = qp["body"][0]["attn"]["wq"]  # scanned body: (layers, d, e)
    assert stacked["w_q"].dtype == jnp.int8
    # per-OUTPUT-channel scales, keepdims so scan slicing still works
    assert stacked["scale"].dtype == F32 and stacked["scale"].shape[-2] == 1
    w = params["body"][0]["attn"]["wq"][0]  # one scanned layer, (d, e)
    leaf = {"w_q": stacked["w_q"][0], "scale": stacked["scale"][0]}
    d, e = w.shape
    x = _mk(40, (2, 3, d))
    got = L.linear(x, leaf, "bsd,de->bse")
    # same math as the int8_matmul reference (matmul-then-scale, f32 acc)
    want = ref.ref_int8_matmul(x.reshape(-1, d), leaf["w_q"],
                               leaf["scale"][0])
    np.testing.assert_allclose(np.asarray(got.reshape(-1, e)),
                               np.asarray(want), atol=1e-4, rtol=1e-4)
    # and close to the f32 layer: per-output-channel scales keep the
    # relative error at int8 rounding level
    exact = L.linear(x, w, "bsd,de->bse")
    rel = float(jnp.max(jnp.abs(got - exact)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.02, rel
    # non-matmul leaves are untouched (embed/lm_head/norms stay f32)
    assert qp["embed"] is params["embed"]
    assert qp["body"][0]["norm1"] is params["body"][0]["norm1"]


# ---------------------------------------------------------------------------
# engine contracts over quantized pools
# ---------------------------------------------------------------------------


def _run_stream(cfg, params, ec, *, n=24, budget=8, sampling=None):
    eng = ServingEngine(cfg, params, ec)
    req = Request(0, _prompt(n), max_new_tokens=budget,
                  sampling=sampling or SamplingParams())
    assert eng.try_admit(req, 0.0)
    _drive(eng, [req])
    return list(req.output), eng


@pytest.mark.parametrize("sampling", [None,
                         SamplingParams(temperature=0.7, top_k=20,
                                        top_p=0.95, seed=11)],
                         ids=["greedy", "sampled"])
def test_engine_int8_deterministic_exact_first_token(granite, sampling):
    """Prefill attends over EXACT pre-quantization K/V (only the cache
    writes quantize), so token 1 matches the f32 engine bit-exactly;
    the int8 stream itself is bit-identical across runs."""
    cfg, params = granite
    kw = dict(slots=2, window=64, chunk_prefill=0)
    f32_out, _ = _run_stream(cfg, params, EngineConfig(paged=True, **kw),
                             sampling=sampling)
    i8_out, eng = _run_stream(
        cfg, params, EngineConfig(paged=True, precision=INT8_KV, **kw),
        sampling=sampling)
    again, _ = _run_stream(
        cfg, params, EngineConfig(paged=True, precision=INT8_KV, **kw),
        sampling=sampling)
    assert i8_out[0] == f32_out[0]
    assert i8_out == again
    assert eng.kv_dtype == "int8"
    assert eng.cache["body"][0]["k"].dtype == jnp.int8
    assert eng.cache["body"][0]["k_scale"].dtype == jnp.float32


def test_engine_int8_prefix_cache_hits_and_no_leaks(granite):
    """Prefix sharing over quantized pools: aliased int8 pages (values +
    scales travel together under the same page ids) still hit, and the
    drain + clear returns the pool to exactly empty."""
    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, prefix_cache=True, precision=INT8_KV,
        chunk_prefill=0))
    tpl = _prompt(40, seed=3)
    a = Request(0, tpl.copy(), max_new_tokens=4)
    assert eng.try_admit(a, 0.0)
    _drive(eng, [a])
    b = Request(1, np.concatenate([tpl, _prompt(6, seed=4)]),
                max_new_tokens=4)
    assert eng.try_admit(b, 0.0)
    _drive(eng, [b])
    assert eng.metrics.prefix_hits >= 1
    assert b.prefix_hit_tokens > 0
    rep = eng.load_report()
    assert rep.kv_cache_dtype == "int8"
    assert eng.allocator.pages_in_use == eng.prefix_index.cached_pages
    eng.clear_prefix_cache()
    assert eng.allocator.pages_in_use == 0
    assert eng.allocator.total_refs == 0


def test_engine_int8_preempt_restore_leak_free_and_deterministic(granite):
    """Preemption over quantized pools. Unlike the lossless engine
    (bit-identical restore, asserted in test_lifecycle.py), int8 restore
    is NOT bit-identical to the undisturbed stream by construction: the
    recompute's hidden states attend over exact pre-quantization K/V
    where the original decode saw dequantized pages. The int8 contract
    is therefore: tokens generated BEFORE the preemption are kept
    verbatim, the whole disturbed run is deterministic (identical on
    rerun), and no page or refcount survives the churn."""
    cfg, params = granite
    kw = dict(slots=1, window=64, max_seq=64, sync_every=1,
              chunk_prefill=0, precision=INT8_KV)
    samp = SamplingParams(temperature=0.7, top_k=20, top_p=0.95, seed=77)
    ref_out, _ = _run_stream(cfg, params, EngineConfig(**kw), n=20,
                             budget=10, sampling=samp)

    def disturbed():
        eng = ServingEngine(cfg, params, EngineConfig(
            **kw, preemption=True, prefix_cache=True))
        victim = Request(0, _prompt(20), max_new_tokens=10, sampling=samp,
                         ttft_slo_s=100.0)
        assert eng.try_admit(victim, 0.0)
        for t in (1.0, 2.0, 3.0):
            eng.step(t)
        pre = len(victim.output)
        assert pre >= 2  # mid-decode when the preemptor lands
        hot = Request(1, _prompt(10, seed=9), max_new_tokens=3,
                      priority=1, ttft_slo_s=1.0,
                      sampling=SamplingParams(temperature=0.7, top_k=20,
                                              top_p=0.95, seed=78))
        eng.submit(hot, 3.0)
        t = 3.0
        while not (victim.done and hot.done):
            t += 1.0
            eng.step(t)
        eng.drain(t)
        assert victim.preemptions >= 1
        # pre-preemption tokens are preserved, not regenerated
        assert list(victim.output[:pre]) == ref_out[:pre]
        assert len(victim.output) == 10
        eng.clear_prefix_cache()
        assert eng.allocator.pages_in_use == 0
        assert eng.allocator.total_refs == 0
        return list(victim.output)

    assert disturbed() == disturbed()  # quantized restore is deterministic


# ---------------------------------------------------------------------------
# LoadReport v5: precision on the wire
# ---------------------------------------------------------------------------


def test_load_report_v5_precision_fields(granite):
    import json

    cfg, params = granite
    eng = ServingEngine(cfg, params, EngineConfig(
        slots=2, window=64, paged=True, precision=INT8_KV))
    rep = eng.load_report()
    assert rep.kv_cache_dtype == "int8" and rep.weight_dtype == ""
    assert rep.kv_bytes_per_token == kv_bytes_per_token(cfg, "int8")
    assert LoadReport.from_dict(json.loads(json.dumps(rep.to_dict()))) \
        == rep


def test_load_report_v4_upgrade_defaults_precision_fields():
    """A v4 (overload-control era) wire dict upgrades through the table:
    the v5 fields backfill to 'unknown, assume model dtype'."""
    v4 = {"slots": 4, "free_slots": 4, "queued_requests": 0,
          "queued_prefill_tokens": 0, "decode_tokens_remaining": 0,
          "free_pages": -1, "total_pages": 0, "backlog_s": 0.0,
          "tick_est_s": 0.01, "queued_prefill_s": 0.0,
          "schema_version": 4, "browned_out": 3,
          "tenant_stats": [["t0", [1, 1, 8, 0, 0, 0, 0, 0, 0], []]]}
    rep = LoadReport.from_dict(v4)
    assert rep.kv_bytes_per_token == 0.0
    assert rep.kv_cache_dtype == "" and rep.weight_dtype == ""
    assert rep.browned_out == 3  # v4 payload rides through untouched
