"""MISD quadrant: interference model, schedulers, meshlets, batching.
Validates the survey's §3 qualitative claims on our own stack."""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import estimate_decode
from repro.core.misd import (
    Device,
    FIFOScheduler,
    InterferenceAwareScheduler,
    Job,
    MeshPartitioner,
    MISDSimulator,
    PremaScheduler,
    SJFScheduler,
    adaptive_batch_size,
    pairwise_degradation,
    progress_rates,
)
from repro.core.sisd import run_multi_tenant, run_single_tenant

COMPUTE = (0.92, 0.25)  # compute-bound demand vector
MEMORY = (0.18, 0.90)  # memory-bound demand vector


def _jobs(n, demand, service=0.01, gap=0.004, **kw):
    return [Job(i, "m", demand, service, arrival=i * gap, **kw) for i in range(n)]


def test_rates_bounds_and_monotonicity():
    r1 = progress_rates([COMPUTE])[0]
    r2 = progress_rates([COMPUTE, COMPUTE])[0]
    r3 = progress_rates([COMPUTE, COMPUTE, COMPUTE])[0]
    assert 0 < r3 < r2 < r1 <= 1.0


def test_complementary_pairs_interfere_less():
    """Survey §3.2.1: compute+memory co-location beats compute+compute."""
    mixed = pairwise_degradation(COMPUTE, MEMORY)
    same = pairwise_degradation(COMPUTE, COMPUTE)
    assert mixed < same
    assert mixed < 1.35  # within the Fig. 3b 90th-percentile band


def test_colocation_raises_throughput_with_bounded_latency():
    """Fig. 3a: throughput up >= 25%, per-job latency degradation bounded."""
    jobs = [Job(i, "m", COMPUTE if i % 2 else MEMORY, 0.01,
                arrival=i * 0.002) for i in range(200)]
    single = run_single_tenant(copy.deepcopy(jobs))
    multi = run_multi_tenant(copy.deepcopy(jobs), max_tenants=2)
    assert multi.qps > 1.25 * single.qps
    assert multi.mean_slowdown() < 1.35


def test_all_jobs_complete_and_conserve():
    jobs = _jobs(50, COMPUTE)
    res = MISDSimulator([Device("d0", 4)], FIFOScheduler()).run(
        copy.deepcopy(jobs))
    assert len(res.completed) == 50
    for j in res.completed:
        assert j.finish >= j.start >= 0
        assert j.finish - j.start >= j.service_s - 1e-9  # no free lunch


def test_sjf_beats_fifo_on_mean_jct():
    rng = np.random.default_rng(0)
    jobs = [Job(i, "m", COMPUTE, float(rng.uniform(0.002, 0.05)),
                arrival=0.0) for i in range(40)]
    fifo = MISDSimulator([Device("d0", 1)], FIFOScheduler()).run(
        copy.deepcopy(jobs))
    sjf = MISDSimulator([Device("d0", 1)], SJFScheduler()).run(
        copy.deepcopy(jobs))
    assert sjf.mean_jct() < fifo.mean_jct()


def test_prema_prioritizes_high_priority_jobs():
    """PREMA [5]: high-priority JCT improves vs FIFO under load."""
    def mk():
        jobs = _jobs(60, COMPUTE, service=0.02, gap=0.001)
        for j in jobs[::6]:
            j.priority = 8
        return jobs

    fifo = MISDSimulator([Device("d0", 2)], FIFOScheduler()).run(mk())
    prema = MISDSimulator([Device("d0", 2)], PremaScheduler()).run(mk())

    def hi_jct(res):
        hi = [j for j in res.completed if j.priority > 0]
        return np.mean([j.finish - j.arrival for j in hi])

    assert len(prema.completed) == 60
    assert hi_jct(prema) < hi_jct(fifo)
    assert any(j.preemptions > 0 for j in prema.completed)


def test_interference_aware_reduces_slowdown():
    jobs = _jobs(80, COMPUTE, service=0.01, gap=0.0005)
    fifo = MISDSimulator([Device("d0", 4), Device("d1", 4)],
                         FIFOScheduler()).run(copy.deepcopy(jobs))
    ia = MISDSimulator([Device("d0", 4), Device("d1", 4)],
                       InterferenceAwareScheduler()).run(copy.deepcopy(jobs))
    assert len(ia.completed) == 80
    assert ia.mean_slowdown() <= fifo.mean_slowdown() + 1e-9


# --- meshlets ---------------------------------------------------------------


def test_partitioner_plans_within_pod():
    part = MeshPartitioner((16, 16))
    cfg_small = get_config("chatglm3-6b")
    cfg_large = get_config("phi3-medium-14b")
    plan = part.plan([
        {"name": "chat", "cfg": cfg_small, "batch": 16, "context": 2048,
         "sla_s": 0.05},
        {"name": "code", "cfg": cfg_large, "batch": 8, "context": 4096,
         "sla_s": 0.02},
    ])
    total = sum(m.n_chips for m in plan.meshlets)
    assert total <= 256
    assert set(plan.assignment) == {"chat", "code"}
    assert plan.reconfig_cost_s == 0.0  # first configuration is free
    plan2 = part.plan([{"name": "chat", "cfg": cfg_small, "batch": 16,
                        "context": 2048, "sla_s": 0.05}])
    assert plan2.reconfig_cost_s > 0  # repartition pays the MIG-style cost


def test_size_for_sla_monotone():
    part = MeshPartitioner((16, 16))
    cfg = get_config("phi3-medium-14b")
    loose = part.size_for_sla(cfg, batch=32, context=8192, sla_s=1.0)
    tight = part.size_for_sla(cfg, batch=32, context=8192, sla_s=0.005)
    assert tight >= loose


# --- adaptive batching -------------------------------------------------------


def test_adaptive_batch_respects_sla():
    cfg = get_config("granite-8b")
    b, lat = adaptive_batch_size(cfg, context=4096, sla_s=0.05, n_chips=8)
    assert b >= 1 and lat <= 0.05
    b2, _ = adaptive_batch_size(cfg, context=4096, sla_s=0.5, n_chips=8)
    assert b2 >= b  # looser SLA admits bigger batches


def test_batching_amortizes_weights():
    """Throughput/chip rises with batch until compute-bound (the Fig. 4
    GPU-vs-CPU mechanism)."""
    cfg = get_config("granite-8b")
    lat1 = estimate_decode(cfg, 1, 4096, n_chips=8).latency_s
    lat64 = estimate_decode(cfg, 64, 4096, n_chips=8).latency_s
    tput1, tput64 = 1 / lat1, 64 / lat64
    assert tput64 > 10 * tput1
